//! End-to-end driver (DESIGN.md "E2E"): train Hoeffding tree regressors —
//! one per attribute-observer configuration — prequentially on the
//! Friedman #1 stream, log the loss curves, and compare accuracy, memory
//! and throughput. This exercises the full stack the paper motivates:
//! stream -> tree -> per-leaf observers -> split decisions.
//!
//! Run: `cargo run --release --example e2e_tree_regression [instances]`
//! Results land in `results/e2e/`.

use qostream::bench_suite::report::Report;
use qostream::common::table::{fnum, Table};
use qostream::eval::{prequential, MeanRegressor};
use qostream::observer::paper_lineup;
use qostream::stream::Friedman1;
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

fn main() -> anyhow::Result<()> {
    let instances: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let seed = 1u64;
    println!("== qostream end-to-end: Friedman #1, {instances} instances, prequential ==\n");

    let report = Report::create("e2e")?;
    let mut summary = Table::new(vec![
        "model", "MAE", "RMSE", "R2", "time_s", "inst/s", "elements", "leaves",
    ]);

    // baseline
    {
        let mut model = MeanRegressor::new();
        let r = prequential(&mut model, &mut Friedman1::new(seed, 1.0), instances, 0);
        summary.row(vec![
            "mean-baseline".to_string(),
            fnum(r.metrics.mae()),
            fnum(r.metrics.rmse()),
            fnum(r.metrics.r2()),
            fnum(r.seconds),
            fnum(r.throughput()),
            "1".to_string(),
            "-".to_string(),
        ]);
    }

    let mut curves = Table::new(vec!["model", "instances", "mae", "rmse"]);
    for fac in paper_lineup() {
        let name = format!("htr[{}]", fac.name());
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
        let r = prequential(
            &mut tree,
            &mut Friedman1::new(seed, 1.0),
            instances,
            instances / 20,
        );
        println!("{name}:");
        for &(n, mae, rmse) in &r.curve {
            println!("  after {n:>7}: MAE {mae:.4}  RMSE {rmse:.4}");
            curves.row(vec![name.clone(), n.to_string(), fnum(mae), fnum(rmse)]);
        }
        println!(
            "  final: {} leaves, {} splits, {} stored elements, {:.0} inst/s\n",
            tree.n_leaves(),
            tree.n_splits(),
            tree.total_elements(),
            r.throughput()
        );
        summary.row(vec![
            name,
            fnum(r.metrics.mae()),
            fnum(r.metrics.rmse()),
            fnum(r.metrics.r2()),
            fnum(r.seconds),
            fnum(r.throughput()),
            tree.total_elements().to_string(),
            tree.n_leaves().to_string(),
        ]);
    }

    println!("{}", summary.render());
    report.write_table("summary", &summary)?;
    report.write_table("curves", &curves)?;
    println!("written to results/e2e/");
    Ok(())
}
