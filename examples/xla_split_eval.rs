//! The three-layer stack end to end: Quantization Observers (rust, L3)
//! feed their slot tables to the AOT-compiled JAX/Pallas split evaluator
//! (L2+L1) running on the PJRT CPU client — and the answers match the
//! native rust query path exactly.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example xla_split_eval`

use qostream::common::timing::{bench, human_time};
use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, QuantizationObserver};
use qostream::runtime::{find_artifacts_dir, Manifest, SlotTable, XlaQuantizeEngine, XlaSplitEngine};

fn main() -> anyhow::Result<()> {
    let dir = find_artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let client = xla::PjRtClient::cpu()?;
    println!("PJRT platform: {}", client.platform_name());

    // --- split evaluation ---------------------------------------------
    let split_engine = XlaSplitEngine::load(&client, &manifest)?;
    println!("split_eval artifact: F={} S={}", split_engine.f, split_engine.s);

    let mut rng = Rng::new(3);
    let observers: Vec<QuantizationObserver> = (0..split_engine.f)
        .map(|f| {
            let mut qo = QuantizationObserver::with_radius(0.05);
            for _ in 0..30_000 {
                let x = rng.normal(0.0, 1.0);
                let y = if x <= 0.2 * f as f64 - 0.5 { -1.0 } else { 1.0 };
                qo.observe(x, y + rng.normal(0.0, 0.1), 1.0);
            }
            qo
        })
        .collect();

    let tables: Vec<SlotTable> = observers.iter().map(SlotTable::from_qo).collect();
    let xla_results = split_engine.best_splits(&tables)?;
    for (f, (qo, res)) in observers.iter().zip(&xla_results).enumerate() {
        let native = qo.best_split(&VarianceReduction).unwrap();
        let x = res.unwrap();
        println!(
            "  feature {f}: XLA c={:+.4} vr={:.4} | native c={:+.4} vr={:.4} | slots={}",
            x.threshold,
            x.merit,
            native.threshold,
            native.merit,
            qo.n_elements()
        );
        assert!((x.threshold - native.threshold).abs() < 1e-9);
    }

    // batched-vs-native timing (XLA amortizes across F features per call)
    let refs: Vec<&QuantizationObserver> = observers.iter().collect();
    let xla_stats = bench(3, 20, || split_engine.best_splits_for_observers(&refs).unwrap());
    let native_stats = bench(3, 20, || {
        refs.iter().map(|qo| qo.best_split(&VarianceReduction)).collect::<Vec<_>>()
    });
    println!(
        "\nsplit query x{} features: XLA {} / call, native {} / call",
        split_engine.f,
        human_time(xla_stats.mean),
        human_time(native_stats.mean)
    );

    // --- bulk quantization ingest --------------------------------------
    let quant_engine = XlaQuantizeEngine::load(&client, &manifest)?;
    println!("\nquantize artifact: B={} S={}", quant_engine.b, quant_engine.s);
    let xs: Vec<f64> = (0..4096).map(|_| rng.normal(0.0, 1.0)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x * x).collect();
    let bulk = quant_engine.build_observer(&xs, &ys, 0.1)?;
    let mut streaming = QuantizationObserver::with_radius(0.1);
    for (&x, &y) in xs.iter().zip(&ys) {
        streaming.observe(x, y, 1.0);
    }
    println!(
        "bulk-ingested {} points -> {} slots (streaming observer: {} slots)",
        xs.len(),
        bulk.n_elements(),
        streaming.n_elements()
    );
    assert_eq!(bulk.n_elements(), streaming.n_elements());
    let (sb, ss) = (
        bulk.best_split(&VarianceReduction).unwrap(),
        streaming.best_split(&VarianceReduction).unwrap(),
    );
    println!(
        "bulk split c={:.4} vr={:.4} | streaming split c={:.4} vr={:.4}",
        sb.threshold, sb.merit, ss.threshold, ss.merit
    );
    assert!((sb.threshold - ss.threshold).abs() < 1e-9);
    println!("\nthree-layer stack verified: rust -> PJRT -> (JAX+Pallas AOT) -> rust");
    Ok(())
}
