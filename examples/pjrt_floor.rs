//! Measure the PJRT dispatch floor: round-trip time of a trivial
//! f64[8,256] `a + 1` computation built with the XlaBuilder. This is the
//! fixed overhead every `XlaSplitEngine` call pays regardless of the
//! kernel's work — the denominator of the §Perf roofline analysis
//! (EXPERIMENTS.md).
//!
//! Run: `cargo run --release --example pjrt_floor`

use qostream::common::timing::{bench, human_time};

fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("floor");
    let shape = xla::Shape::array::<f64>(vec![8, 256]);
    let p = builder.parameter_s(0, &shape, "a")?;
    let one = builder.constant_r0(1f64)?;
    let comp = p.add_(&one)?.build()?;
    let exe = client.compile(&comp)?;

    let data = vec![1.0f64; 8 * 256];
    let lit = xla::Literal::vec1(&data).reshape(&[8, 256])?;
    let stats = bench(5, 50, || {
        exe.execute::<xla::Literal>(std::slice::from_ref(&lit))
            .unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
    });
    println!(
        "trivial f64[8,256] round-trip on {}: {}",
        client.platform_name(),
        human_time(stats.mean)
    );
    println!("(compare with `cargo bench --bench xla_vs_native` per-call times)");
    Ok(())
}
