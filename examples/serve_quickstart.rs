//! Serve quickstart: start the learn/predict server in-process, train a
//! forest over a real TCP socket, take a checkpoint, restore it into a
//! second server, and verify both answer a held-out batch bit-for-bit
//! identically — the full serve/persist loop in one file.
//!
//! Run: `cargo run --release --example serve_quickstart`

use qostream::eval::Regressor;
use qostream::forest::{ArfOptions, ArfRegressor};
use qostream::observer::ObserverSpec;
use qostream::persist::Model;
use qostream::serve::{ServeClient, ServeOptions, Server};
use qostream::stream::{Friedman1, Stream};

fn main() -> anyhow::Result<()> {
    // 1. a 5-member ARF behind the server, snapshots hot-swapped every
    //    250 applied learns
    let model = Model::Arf(ArfRegressor::new(
        10,
        ArfOptions { n_members: 5, seed: 7, ..Default::default() },
        ObserverSpec::from_label("QO_s2").expect("paper label").to_factory(),
    ));
    let server = Server::start(
        model,
        "127.0.0.1:0", // ephemeral port
        ServeOptions { snapshot_every: 250, ..Default::default() },
    )?;
    println!("serving on {}", server.addr());

    // 2. train over the wire: 5000 Friedman #1 instances
    let mut client = ServeClient::connect(server.addr())?;
    let mut stream = Friedman1::new(3, 1.0);
    for _ in 0..5000 {
        let inst = stream.next_instance().expect("endless stream");
        client.learn(&inst.x, inst.y)?;
    }

    // 3. reads come from the hot-swapped snapshot, concurrent with training
    let probe = [0.5; 10];
    println!("prediction at x=0.5…: {:.4}", client.predict(&probe)?);

    // 4. checkpoint: drains this connection's learns, publishes, returns
    //    the full model as canonical JSON
    let checkpoint = client.snapshot()?;
    println!("checkpoint: {} bytes", checkpoint.len());

    // 5. restore into a brand-new server and compare a held-out batch
    let restored = Model::from_text(&checkpoint)?;
    let server_b = Server::start(restored, "127.0.0.1:0", ServeOptions::default())?;
    let mut client_b = ServeClient::connect(server_b.addr())?;
    let mut held_out = Friedman1::new(0xBEEF, 0.0);
    let batch: Vec<Vec<f64>> =
        (0..50).map(|_| held_out.next_instance().unwrap().x).collect();
    let live = client.predict_batch(&batch)?;
    let cold = client_b.predict_batch(&batch)?;
    let identical =
        live.iter().zip(&cold).all(|(a, b)| a.to_bits() == b.to_bits());
    println!("restored server bit-identical on 50 held-out probes: {identical}");

    // 6. clean shutdown; join returns the final trained model
    client.shutdown()?;
    client_b.shutdown()?;
    let final_model = server.join()?;
    server_b.join()?;
    println!(
        "final model: {} ({} elements)",
        final_model.name(),
        final_model.n_elements()
    );
    Ok(())
}
