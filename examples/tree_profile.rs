//! Profiling driver: train one QO_σ÷2 Hoeffding tree on 200k Friedman #1
//! instances and report throughput. Used with `perf record` for the
//! §Perf pass (EXPERIMENTS.md) — kept as a reproducible harness.
//!
//! Run: `cargo run --release --example tree_profile`
//! Profile: `perf record ./target/release/examples/tree_profile`

use qostream::eval::Regressor;
use qostream::observer::{factory, QuantizationObserver, RadiusPolicy};
use qostream::stream::{Friedman1, Stream};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200_000);
    let fac = factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    });
    let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
    let mut stream = Friedman1::new(1, 1.0);
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        tree.learn_one(&inst.x, inst.y);
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{} instances in {secs:.3}s = {} inst/s ({} leaves, {} elements)",
        n,
        (n as f64 / secs) as u64,
        tree.n_leaves(),
        tree.total_elements()
    );
}
