//! Quickstart: monitor a numerical feature with the Quantization Observer
//! and ask it for the best split — the paper's Algs. 1 and 2 in ten lines.
//!
//! Run: `cargo run --release --example quickstart`

use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, EBst, QuantizationObserver, RadiusPolicy};

fn main() {
    // A stream where the target jumps at x = 0.3: the split every observer
    // should find.
    let mut rng = Rng::new(42);
    let sample: Vec<(f64, f64)> = (0..50_000)
        .map(|_| {
            let x = rng.uniform(-1.0, 1.0);
            let y = if x <= 0.3 { 1.0 } else { 4.0 } + rng.normal(0.0, 0.2);
            (x, y)
        })
        .collect();

    // The paper's QO with a dynamic radius (sigma/2) ...
    let mut qo = QuantizationObserver::new(RadiusPolicy::std_fraction(2.0));
    // ... and the classical E-BST it replaces.
    let mut ebst = EBst::new();

    for &(x, y) in &sample {
        qo.observe(x, y, 1.0); // O(1): hash slot floor(x/r)
        ebst.observe(x, y, 1.0); // O(log n): BST insert
    }

    let criterion = VarianceReduction;
    let qo_split = qo.best_split(&criterion).expect("split");
    let ebst_split = ebst.best_split(&criterion).expect("split");

    println!("monitored {} instances", sample.len());
    println!(
        "QO    : split at x <= {:.4} (VR {:.4}) using {:>6} slots, radius {:.4}",
        qo_split.threshold,
        qo_split.merit,
        qo.n_elements(),
        qo.radius().unwrap()
    );
    println!(
        "E-BST : split at x <= {:.4} (VR {:.4}) using {:>6} nodes",
        ebst_split.threshold,
        ebst_split.merit,
        ebst.n_elements()
    );
    println!(
        "-> same decision from {}x less memory",
        ebst.n_elements() / qo.n_elements().max(1)
    );
    assert!((qo_split.threshold - ebst_split.threshold).abs() < 0.1);
}
