//! Distributed attribute observation: shard a stream across worker
//! threads, observe in parallel with per-shard Quantization Observers, and
//! merge the partial hashes with the paper's Sec. 3 Chan formulas — the
//! merged observer answers split queries identically to a single-threaded
//! one.
//!
//! Run: `cargo run --release --example distributed_observer [instances]`

use qostream::common::timing::human_time;
use qostream::coordinator::{CoordinatorConfig, Partitioner, ShardedObserverCoordinator};
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, QuantizationObserver};
use qostream::stream::{Friedman1, Stream};

fn main() {
    let instances: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300_000);
    let radius = 0.02;

    // single-threaded reference
    let mut single: Vec<QuantizationObserver> =
        (0..10).map(|_| QuantizationObserver::with_radius(radius)).collect();
    let mut stream = Friedman1::new(5, 1.0);
    let start = std::time::Instant::now();
    for _ in 0..instances {
        let inst = stream.next_instance().unwrap();
        for (f, qo) in single.iter_mut().enumerate() {
            qo.observe(inst.x[f], inst.y, 1.0);
        }
    }
    let single_secs = start.elapsed().as_secs_f64();
    println!("single-threaded: {instances} instances in {}", human_time(single_secs));

    for shards in [1, 2, 4] {
        let coordinator = ShardedObserverCoordinator::new(
            10,
            CoordinatorConfig {
                n_shards: shards,
                radius,
                batch_size: 512,
                channel_capacity: 16,
                partitioner: Partitioner::RoundRobin,
            },
        );
        let mut stream = Friedman1::new(5, 1.0);
        let report = coordinator.run(&mut stream, instances);
        println!(
            "{shards} shard(s): {} ({} inst/s), per-shard {:?}",
            human_time(report.seconds),
            (report.instances as f64 / report.seconds) as u64,
            report.per_shard
        );

        // the merged result must match the single-threaded observers
        for f in 0..10 {
            let a = report.merged[f].best_split(&VarianceReduction);
            let b = single[f].best_split(&VarianceReduction);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert!(
                        (a.threshold - b.threshold).abs() < 1e-9,
                        "feature {f}: {} vs {}",
                        a.threshold,
                        b.threshold
                    );
                }
                (None, None) => {}
                _ => panic!("feature {f}: split disagreement"),
            }
            assert_eq!(report.merged[f].n_elements(), single[f].n_elements());
        }
        println!("  merged observers identical to single-threaded (all 10 features)");
    }
    println!("\nsplit decisions (feature, threshold, VR):");
    for (f, qo) in single.iter().enumerate().take(5) {
        if let Some(s) = qo.best_split(&VarianceReduction) {
            println!("  x[{f}] <= {:.4}  (VR {:.4}, {} slots)", s.threshold, s.merit, qo.n_elements());
        }
    }
}
