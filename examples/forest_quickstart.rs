//! Forest quickstart: an Adaptive Random Forest Regressor vs a single
//! Hoeffding tree on a Friedman #1 stream whose concept abruptly changes
//! halfway — the ensemble detects the drift per member (ADWIN on the
//! prequential error), swaps in background trees, and recovers while the
//! single tree is stuck with a stale structure.
//!
//! Run: `cargo run --release --example forest_quickstart [instances]`

use qostream::eval::{prequential, Regressor};
use qostream::forest::{ArfOptions, ArfRegressor, SubspaceSize};
use qostream::observer::{factory, ObserverFactory, QuantizationObserver, RadiusPolicy};
use qostream::stream::{AbruptDrift, Friedman1};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

fn qo_factory() -> Box<dyn ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

fn drift_stream(position: usize) -> AbruptDrift {
    AbruptDrift::new(
        Box::new(Friedman1::new(1, 1.0)),
        Box::new(Friedman1::swapped(2, 1.0)),
        position,
    )
}

fn main() {
    let instances: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);
    let drift_at = instances / 2;
    println!(
        "== forest quickstart: Friedman #1 with an abrupt concept swap at {drift_at} ==\n"
    );

    let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory());
    let r_tree = prequential(&mut tree, &mut drift_stream(drift_at), instances, 0);

    let mut arf = ArfRegressor::new(
        10,
        ArfOptions { n_members: 10, subspace: SubspaceSize::Sqrt, ..Default::default() },
        qo_factory(),
    );
    let r_arf = prequential(&mut arf, &mut drift_stream(drift_at), instances, 0);

    println!(
        "single tree : MAE {:.4}  RMSE {:.4}  ({:.0} inst/s, {} elements)",
        r_tree.metrics.mae(),
        r_tree.metrics.rmse(),
        r_tree.throughput(),
        tree.total_elements(),
    );
    println!(
        "ARF x{}     : MAE {:.4}  RMSE {:.4}  ({:.0} inst/s, {} elements, {} warnings, {} drifts)",
        arf.n_members(),
        r_arf.metrics.mae(),
        r_arf.metrics.rmse(),
        r_arf.throughput(),
        arf.n_elements(),
        arf.n_warnings(),
        arf.n_drifts(),
    );
    println!(
        "\n-> ensemble MAE is {:.1}% of the single tree's on the drifting stream",
        100.0 * r_arf.metrics.mae() / r_tree.metrics.mae()
    );
}
