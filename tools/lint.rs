//! `tools/lint` — the repo lint gate's CLI entry point.
//!
//! Runs [`qostream::audit::lint`] over the repository and prints every
//! finding as `RULE file:line message` (or NDJSON with `--json`),
//! exiting 1 when anything is flagged — the `static-analysis` CI job's
//! first step. Rules and the `audit:allow(<rule>)` escape hatch are
//! documented in the `audit::lint` module and `docs/INVARIANTS.md`.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let root = args
        .iter()
        .position(|a| a == "--root")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let findings = match qostream::audit::lint::run(&root) {
        Ok(findings) => findings,
        Err(e) => {
            eprintln!("lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        if json {
            println!("{}", f.to_json().to_compact());
        } else {
            println!("{f}");
        }
    }
    if findings.is_empty() {
        eprintln!("lint: clean ({} rules over {})", 6, root.display());
        ExitCode::SUCCESS
    } else {
        eprintln!("lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
