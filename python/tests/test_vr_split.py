"""vr_split Pallas kernel vs the sequential Chan-merge oracle (ref.py).

The kernel uses the closed-form cumulative-sum formulation; the oracle does
the literal Alg. 2 loop with Chan merges/subtractions. Agreement across
shapes, dtyped extremes and adversarial slot layouts is the core L1
correctness signal.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import vr_split as vk


def make_slots(rng, f, s, max_valid=None, loc_scale=5.0, y_scale=3.0):
    """Random packed slot tables built from actual (x, y) draws so the
    statistics are internally consistent."""
    max_valid = max_valid or s
    n = np.zeros((f, s))
    sx = np.zeros((f, s))
    mean = np.zeros((f, s))
    m2 = np.zeros((f, s))
    for fi in range(f):
        valid = int(rng.integers(0, max_valid + 1))
        keys = np.sort(rng.normal(0.0, loc_scale, valid))
        for i in range(valid):
            cnt = int(rng.integers(1, 12))
            ys = rng.normal(rng.normal(0, y_scale), 1.0, cnt)
            xs = keys[i] + rng.uniform(-0.01, 0.01, cnt)
            n[fi, i] = cnt
            sx[fi, i] = xs.sum()
            mean[fi, i] = ys.mean()
            m2[fi, i] = ((ys - ys.mean()) ** 2).sum()
    return n, sx, mean, m2


def assert_matches_ref(n, sx, mean, m2, rtol=1e-9):
    vr_k, split_k = vk.vr_split(n, sx, mean, m2)
    vr_k, split_k = np.asarray(vr_k), np.asarray(split_k)
    vr_r, split_r = ref.vr_split_ref(n, sx, mean, m2)
    assert np.array_equal(np.isfinite(vr_k), np.isfinite(vr_r))
    fin = np.isfinite(vr_r)
    scale = max(1.0, np.max(np.abs(mean)) ** 2, np.max(m2, initial=1.0))
    np.testing.assert_allclose(vr_k[fin], vr_r[fin], rtol=rtol, atol=rtol * scale)
    np.testing.assert_allclose(split_k, split_r, rtol=1e-12, atol=1e-12)


class TestVrSplitBasic:
    def test_two_clusters_split_found(self):
        """Two well-separated target clusters: best boundary must sit
        between them and VR must approach the total variance."""
        f, s = 8, 256
        n = np.zeros((f, s))
        sx = np.zeros((f, s))
        mean = np.zeros((f, s))
        m2 = np.zeros((f, s))
        # 4 slots: x prototypes at -2,-1,1,2; y = 0 on the left, 10 right
        for fi in range(f):
            n[fi, :4] = 5.0
            sx[fi, :4] = np.array([-2.0, -1.0, 1.0, 2.0]) * 5.0
            mean[fi, :4] = np.array([0.0, 0.0, 10.0, 10.0])
            m2[fi, :4] = 0.0
        vr, split = vk.vr_split(n, sx, mean, m2)
        vr, split = np.asarray(vr), np.asarray(split)
        best = np.argmax(vr, axis=1)
        assert np.all(best == 1), best
        np.testing.assert_allclose(split[:, 1], 0.0, atol=1e-12)
        # total variance of 10 zeros + 10 tens
        total_var = np.var([0.0] * 10 + [10.0] * 10, ddof=1)
        np.testing.assert_allclose(vr[:, 1], total_var, rtol=1e-12)

    def test_empty_features(self):
        z = np.zeros((8, 256))
        vr, split = vk.vr_split(z, z, z, z)
        assert np.all(np.asarray(vr) == -np.inf)
        assert np.all(np.asarray(split) == 0.0)

    def test_single_slot_no_boundary(self):
        f, s = 8, 256
        n = np.zeros((f, s))
        n[:, 0] = 7.0
        sx = n * 1.5
        mean = np.ones((f, s))
        m2 = np.zeros((f, s))
        vr, _ = vk.vr_split(n, sx, mean, m2)
        assert np.all(np.asarray(vr) == -np.inf)

    def test_constant_target_zero_merit(self):
        f, s = 8, 256
        n = np.zeros((f, s))
        n[:, :10] = 3.0
        sx = np.cumsum(np.ones((f, s)), axis=1) * n
        mean = np.where(n > 0, 4.2, 0.0)
        m2 = np.zeros((f, s))
        vr, _ = vk.vr_split(n, sx, mean, m2)
        vr = np.asarray(vr)
        fin = np.isfinite(vr)
        assert fin[:, :9].all()
        np.testing.assert_allclose(vr[fin], 0.0, atol=1e-12)

    def test_matches_ref_random(self):
        rng = np.random.default_rng(42)
        for _ in range(5):
            assert_matches_ref(*make_slots(rng, 8, 256))

    def test_matches_ref_full_occupancy(self):
        rng = np.random.default_rng(7)
        assert_matches_ref(*make_slots(rng, 8, 256, max_valid=256))

    def test_large_offset_targets(self):
        """Big common offset in y: the f64 sum-of-squares path must still
        agree with the Chan-merge oracle to ~1e-6 relative."""
        rng = np.random.default_rng(3)
        n, sx, mean, m2 = make_slots(rng, 8, 256, max_valid=64)
        mean = mean + 1e6
        assert_matches_ref(n, sx, mean, m2, rtol=1e-5)

    def test_weighted_counts(self):
        """Fractional weights (instance weighting) work."""
        rng = np.random.default_rng(11)
        n, sx, mean, m2 = make_slots(rng, 8, 256, max_valid=32)
        n *= 0.5
        sx *= 0.5
        m2 *= 0.5
        assert_matches_ref(n, sx, mean, m2)


class TestVrSplitHypothesis:
    @given(
        seed=st.integers(0, 2**31 - 1),
        f_pow=st.integers(0, 2),
        s=st.sampled_from([8, 64, 128, 256]),
        max_valid=st.integers(0, 32),
    )
    @settings(max_examples=30, deadline=None)
    def test_shapes_and_values(self, seed, f_pow, s, max_valid):
        f = vk.F_BLOCK * (2**f_pow)
        rng = np.random.default_rng(seed)
        assert_matches_ref(*make_slots(rng, f, s, max_valid=min(max_valid, s)))

    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-6, 1.0, 1e4]))
    @settings(max_examples=20, deadline=None)
    def test_scale_invariance_of_argmax(self, seed, scale):
        """Scaling y by c scales VR by c^2 but must not move the argmax."""
        rng = np.random.default_rng(seed)
        n, sx, mean, m2 = make_slots(rng, 8, 128, max_valid=24)
        vr1, _ = vk.vr_split(n, sx, mean, m2)
        vr2, _ = vk.vr_split(n, sx, mean * scale, m2 * scale * scale)
        vr1, vr2 = np.asarray(vr1), np.asarray(vr2)
        for fi in range(8):
            if np.isfinite(vr1[fi]).sum() >= 2:
                # compare argmax only when the max is unique enough
                srt = np.sort(vr1[fi][np.isfinite(vr1[fi])])
                if len(srt) >= 2 and srt[-1] - srt[-2] > 1e-9 * max(1.0, abs(srt[-1])):
                    assert np.argmax(vr1[fi]) == np.argmax(vr2[fi])


class TestAgainstRawDataOracle:
    """End-to-end: aggregate raw (x, y) into slots, run the kernel, and
    compare the winning split's VR against a direct numpy computation on
    the raw sample."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_best_split_merit_matches_raw(self, seed):
        rng = np.random.default_rng(seed)
        n_pts = 2000
        x = rng.normal(0, 1, n_pts)
        y = 3.0 * x + rng.normal(0, 0.1, n_pts)
        r = 0.1
        codes = np.floor(x / r).astype(int)
        uniq = np.sort(np.unique(codes))
        s = 256
        f = 8
        n = np.zeros((f, s))
        sx = np.zeros((f, s))
        mean = np.zeros((f, s))
        m2 = np.zeros((f, s))
        for i, c in enumerate(uniq):
            sel = codes == c
            ys = y[sel]
            n[:, i] = sel.sum()
            sx[:, i] = x[sel].sum()
            mean[:, i] = ys.mean()
            m2[:, i] = ((ys - ys.mean()) ** 2).sum()
        vr, split = vk.vr_split(n, sx, mean, m2)
        vr, split = np.asarray(vr), np.asarray(split)
        b = np.argmax(vr[0])
        c_star = split[0, b]
        left = y[x <= c_star]
        right = y[x > c_star]
        direct_vr = (
            np.var(y, ddof=1)
            - len(left) / n_pts * np.var(left, ddof=1)
            - len(right) / n_pts * np.var(right, ddof=1)
        )
        # slot boundaries only approximate the raw <=c partition; the slot
        # radius is fine (0.1 on a N(0,1) feature), so merit is close.
        np.testing.assert_allclose(vr[0, b], direct_vr, rtol=0.05)
        # for y = 3x the best split is near the median -> near 0
        assert abs(c_star) < 0.5
