"""quantize/segsum Pallas kernel vs the scatter oracle (ref.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import quantize as qk
from compile.kernels import ref


class TestSegsum:
    def test_basic(self):
        codes = np.array([0, 1, 1, 3], dtype=np.int32)
        x = np.array([1.0, 2.0, 3.0, 4.0])
        y = np.array([10.0, 20.0, 30.0, 40.0])
        vals = np.stack([np.ones(4), x, y, y * y], axis=1)
        out = np.asarray(qk.segsum(codes, vals, num_slots=8))
        expected = ref.segsum_ref(codes, x, y, 8)
        np.testing.assert_allclose(out, expected)
        assert out[1, 0] == 2.0 and out[1, 2] == 50.0

    def test_out_of_range_dropped(self):
        codes = np.array([-1, 0, 8, 100], dtype=np.int32)
        x = np.ones(4)
        y = np.ones(4)
        vals = np.stack([np.ones(4), x, y, y * y], axis=1)
        out = np.asarray(qk.segsum(codes, vals, num_slots=8))
        assert out[:, 0].sum() == 1.0  # only code 0 lands

    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.sampled_from([8, 128, 1024]),
        s=st.sampled_from([16, 256]),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, seed, b, s):
        rng = np.random.default_rng(seed)
        codes = rng.integers(-3, s + 3, b).astype(np.int32)
        x = rng.normal(0, 10, b)
        y = rng.normal(-5, 100, b)
        vals = np.stack([np.ones(b), x, y, y * y], axis=1)
        out = np.asarray(qk.segsum(codes, vals, num_slots=s))
        expected = ref.segsum_ref(codes, x, y, s)
        scale = max(1.0, np.max(np.abs(y)) ** 2)
        np.testing.assert_allclose(out, expected, rtol=1e-9, atol=1e-9 * scale)


class TestQuantizeIngest:
    def test_codes_match_floor(self):
        x = np.array([-0.31, -0.01, 0.0, 0.09, 0.11, 1.0])
        codes = ref.quantize_codes_ref(x, 0.1)
        np.testing.assert_array_equal(codes, [-4, -1, 0, 0, 1, 10])

    @given(seed=st.integers(0, 2**31 - 1), r=st.sampled_from([0.01, 0.1, 0.5, 2.0]))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, seed, r):
        rng = np.random.default_rng(seed)
        x = rng.normal(0, 1, qk.DEFAULT_B)
        y = rng.normal(0, 3, qk.DEFAULT_B)
        base, table = model.quantize_ingest(x, y, np.float64(r))
        base_r, table_r = ref.quantize_ingest_ref(x, y, r, qk.DEFAULT_S)
        assert int(base) == base_r
        np.testing.assert_allclose(np.asarray(table), table_r, rtol=1e-9, atol=1e-9)

    def test_total_mass_conserved(self):
        """When the code range fits in S slots, every point is counted."""
        rng = np.random.default_rng(0)
        x = rng.uniform(-1, 1, qk.DEFAULT_B)
        y = rng.normal(0, 1, qk.DEFAULT_B)
        _, table = model.quantize_ingest(x, y, np.float64(0.05))  # 40 codes max
        table = np.asarray(table)
        assert table[:, 0].sum() == qk.DEFAULT_B
        np.testing.assert_allclose(table[:, 1].sum(), x.sum(), rtol=1e-12)
        np.testing.assert_allclose(table[:, 2].sum(), y.sum(), rtol=1e-12)


class TestComposition:
    """Alg. 1 -> Alg. 2 composed: batch-quantize raw data, then find the
    best split — the full QO path on the XLA side."""

    def test_step_function_recovered(self):
        rng = np.random.default_rng(5)
        x = rng.uniform(-1, 1, qk.DEFAULT_B)
        y = np.where(x <= 0.25, -2.0, 2.0) + rng.normal(0, 0.05, qk.DEFAULT_B)
        base, table = model.quantize_ingest(x, y, np.float64(0.05))
        table = np.asarray(table)
        occupied = table[:, 0] > 0
        k = int(occupied.sum())
        f, s = 8, 256
        n = np.zeros((f, s))
        sx = np.zeros((f, s))
        mean = np.zeros((f, s))
        m2 = np.zeros((f, s))
        cnt = table[occupied, 0]
        n[0, :k] = cnt
        sx[0, :k] = table[occupied, 1]
        mean[0, :k] = table[occupied, 2] / cnt
        m2[0, :k] = np.maximum(table[occupied, 3] - table[occupied, 2] ** 2 / cnt, 0.0)
        _, _, best_idx, best_vr, best_split = model.split_eval(n, sx, mean, m2)
        c = float(np.asarray(best_split)[0])
        assert abs(c - 0.25) < 0.05, c
        assert float(np.asarray(best_vr)[0]) > 3.0
