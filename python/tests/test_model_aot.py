"""L2 graph + AOT lowering tests: the artifacts the rust runtime loads."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import quantize as qk
from compile.kernels import ref
from compile.kernels import vr_split as vk


def _example_slots(seed=0, f=8, s=256):
    rng = np.random.default_rng(seed)
    n = np.zeros((f, s))
    sx = np.zeros((f, s))
    mean = np.zeros((f, s))
    m2 = np.zeros((f, s))
    for fi in range(f):
        valid = int(rng.integers(2, 40))
        keys = np.sort(rng.normal(0, 3, valid))
        n[fi, :valid] = rng.integers(1, 9, valid).astype(float)
        sx[fi, :valid] = keys * n[fi, :valid]
        mean[fi, :valid] = rng.normal(0, 2, valid)
        m2[fi, :valid] = rng.uniform(0, 4, valid)
    return n, sx, mean, m2


class TestSplitEvalGraph:
    def test_outputs_consistent_with_ref(self):
        args = _example_slots()
        vr, split, best_idx, best_vr, best_split = model.split_eval(*args)
        idx_r, vr_r, split_r = ref.best_split_ref(*args)
        np.testing.assert_array_equal(np.asarray(best_idx), idx_r)
        np.testing.assert_allclose(np.asarray(best_vr), vr_r, rtol=1e-9)
        np.testing.assert_allclose(np.asarray(best_split), split_r, rtol=1e-12)

    def test_jit_matches_eager(self):
        args = _example_slots(seed=3)
        eager = model.split_eval(*args)
        jitted = jax.jit(model.split_eval)(*args)
        for a, b in zip(eager, jitted):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_best_fields_dtypes(self):
        args = _example_slots(seed=1)
        _, _, best_idx, best_vr, best_split = model.split_eval(*args)
        assert np.asarray(best_idx).dtype == np.int32
        assert np.asarray(best_vr).dtype == np.float64
        assert np.asarray(best_split).dtype == np.float64


class TestAotLowering:
    def test_split_eval_hlo_text(self):
        text = aot.lower_split_eval(vk.DEFAULT_F, vk.DEFAULT_S)
        assert text.startswith("HloModule")
        assert "f64[8,256]" in text
        # return_tuple=True: entry layout must be a tuple of 5 results
        assert "s32[8]" in text

    def test_quantize_hlo_text(self):
        text = aot.lower_quantize(qk.DEFAULT_B)
        assert text.startswith("HloModule")
        assert "f64[1024]" in text
        assert "f64[256,4]" in text

    def test_build_writes_manifest(self, tmp_path):
        written = aot.build(str(tmp_path), 8, 256, 1024)
        assert set(written) == {
            "split_eval_f8_s256.hlo.txt",
            "quantize_b1024_s256.hlo.txt",
            "manifest.txt",
        }
        manifest = (tmp_path / "manifest.txt").read_text()
        assert "split_eval.s=256" in manifest
        assert "quantize.b=1024" in manifest
        for name in written:
            assert (tmp_path / name).stat().st_size > 0

    def test_hlo_text_reparses_and_executes(self):
        """Round-trip the HLO text through the XLA client the way the rust
        runtime does: parse text -> compile -> execute -> compare."""
        from jax._src.lib import xla_client as xc

        args = _example_slots(seed=9)
        text = aot.lower_split_eval(vk.DEFAULT_F, vk.DEFAULT_S)
        backend = jax.devices("cpu")[0].client
        comp = xc.XlaComputation(
            xc._xla.hlo_module_proto_from_text(text).SerializeToString()
            if hasattr(xc._xla, "hlo_module_proto_from_text")
            else None
        ) if False else None
        # jax's python client cannot parse HLO text in all versions; the
        # real text round-trip is exercised by the rust runtime tests.
        # Here we instead verify the lowered computation itself executes
        # via jax and matches eager.
        lowered = jax.jit(model.split_eval).lower(
            *(jnp.asarray(a) for a in args)
        )
        compiled = lowered.compile()
        out = compiled(*args)
        eager = model.split_eval(*args)
        for a, b in zip(out, eager):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
