"""Properties of the robust statistics oracle (paper Sec. 3, Eqs. 2-7).

These pin down the math that BOTH the Pallas kernels and the rust
`qostream::stats` module implement; the rust unit tests mirror them.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

finite = st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False)
samples = st.lists(finite, min_size=1, max_size=200)


def welford_of(values):
    s = (0.0, 0.0, 0.0)
    for v in values:
        s = ref.welford_update(s, v)
    return s


class TestWelford:
    def test_single_observation(self):
        s = welford_of([3.5])
        assert s == (1.0, 3.5, 0.0)

    def test_mean_matches_numpy(self):
        vals = [1.0, 2.0, 4.0, 8.0]
        n, mean, m2 = welford_of(vals)
        assert n == 4.0
        np.testing.assert_allclose(mean, np.mean(vals))
        np.testing.assert_allclose(m2 / (n - 1), np.var(vals, ddof=1))

    @given(samples)
    @settings(max_examples=150, deadline=None)
    def test_matches_numpy_anywhere(self, vals):
        n, mean, m2 = welford_of(vals)
        scale = max(1.0, np.max(np.abs(vals)))
        np.testing.assert_allclose(mean, np.mean(vals), rtol=1e-9, atol=1e-9 * scale)
        if len(vals) > 1:
            np.testing.assert_allclose(
                m2 / (n - 1), np.var(vals, ddof=1), rtol=1e-7, atol=1e-7 * scale**2
            )

    def test_weighted_update(self):
        # weight w is equivalent to w unit repeats
        s_w = ref.welford_update((0.0, 0.0, 0.0), 5.0, w=3.0)
        s_r = welford_of([5.0, 5.0, 5.0])
        np.testing.assert_allclose(s_w, s_r)

    def test_cancellation_robustness(self):
        # The classic naive-sum failure: huge offset, tiny variance.
        # Naive sum-of-squares loses all signal in f64; Welford keeps it.
        offset = 1e9
        vals = [offset + v for v in (0.0, 0.1, 0.2, 0.3)]
        n, mean, m2 = welford_of(vals)
        np.testing.assert_allclose(m2 / (n - 1), np.var(vals, ddof=1), rtol=1e-4)
        # and the reference variance is ~0.0167, not 0 or garbage
        assert 0.001 < m2 / (n - 1) < 0.1


class TestChanMerge:
    @given(samples, samples)
    @settings(max_examples=150, deadline=None)
    def test_merge_equals_concat(self, a, b):
        merged = ref.chan_merge(welford_of(a), welford_of(b))
        direct = welford_of(a + b)
        scale = max(1.0, np.max(np.abs(a + b)))
        np.testing.assert_allclose(merged[0], direct[0])
        np.testing.assert_allclose(merged[1], direct[1], rtol=1e-9, atol=1e-9 * scale)
        np.testing.assert_allclose(merged[2], direct[2], rtol=1e-6, atol=1e-6 * scale**2)

    @given(samples, samples, samples)
    @settings(max_examples=100, deadline=None)
    def test_merge_associative(self, a, b, c):
        sa, sb, sc = welford_of(a), welford_of(b), welford_of(c)
        left = ref.chan_merge(ref.chan_merge(sa, sb), sc)
        right = ref.chan_merge(sa, ref.chan_merge(sb, sc))
        scale = max(1.0, np.max(np.abs(a + b + c)))
        np.testing.assert_allclose(left, right, rtol=1e-8, atol=1e-8 * scale**2)

    def test_merge_identity(self):
        s = welford_of([1.0, 2.0])
        assert ref.chan_merge(s, (0.0, 0.0, 0.0)) == s
        assert ref.chan_merge((0.0, 0.0, 0.0), s) == s


class TestChanSubtract:
    @given(samples, samples)
    @settings(max_examples=150, deadline=None)
    def test_subtract_inverts_merge(self, a, b):
        """The paper's extension: A = (A+B) - B (Eqs. 6-7)."""
        sa, sb = welford_of(a), welford_of(b)
        sab = ref.chan_merge(sa, sb)
        recovered = ref.chan_subtract(sab, sb)
        scale = max(1.0, np.max(np.abs(a + b)))
        np.testing.assert_allclose(recovered[0], sa[0])
        np.testing.assert_allclose(recovered[1], sa[1], rtol=1e-7, atol=1e-7 * scale)
        np.testing.assert_allclose(recovered[2], sa[2], rtol=1e-5, atol=1e-5 * scale**2)

    def test_subtract_to_empty(self):
        s = welford_of([1.0, 2.0, 3.0])
        assert ref.chan_subtract(s, s) == (0.0, 0.0, 0.0)

    def test_m2_never_negative(self):
        s = welford_of([1.0, 1.0])
        out = ref.chan_subtract(s, welford_of([1.0]))
        assert out[2] >= 0.0


class TestVarianceReduction:
    def test_perfect_split(self):
        # Two well-separated clusters: splitting between them removes all
        # variance; VR == total variance.
        left = welford_of([0.0] * 10)
        right = welford_of([10.0] * 10)
        total = ref.chan_merge(left, right)
        vr = ref.variance_reduction(total, left, right)
        np.testing.assert_allclose(vr, ref.variance(total))

    def test_useless_split(self):
        # Identical halves: VR ~ 0 (slightly positive from the df change).
        vals = [1.0, 2.0, 3.0, 4.0]
        left = welford_of(vals)
        right = welford_of(vals)
        total = ref.chan_merge(left, right)
        vr = ref.variance_reduction(total, left, right)
        assert abs(vr) < ref.variance(total) * 0.2

    @given(samples, samples)
    @settings(max_examples=100, deadline=None)
    def test_vr_bounded_by_total_variance(self, a, b):
        la, lb = welford_of(a), welford_of(b)
        total = ref.chan_merge(la, lb)
        vr = ref.variance_reduction(total, la, lb)
        scale = max(1.0, float(np.max(np.abs(a + b)))) ** 2
        assert vr <= ref.variance(total) + 1e-7 * scale
