"""Build-time compile path: Pallas kernels (L1), JAX graphs (L2), AOT (aot.py).

Nothing in this package is imported at runtime by the rust coordinator; it
exists to author and lower the HLO artifacts under ``artifacts/``.

The whole stack runs in float64: the rust side keeps f64 statistics and the
artifact round-trip tests compare against rust math at tight tolerances, so
x64 must be enabled before any jax import downstream.
"""

import jax

jax.config.update("jax_enable_x64", True)
