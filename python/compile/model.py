"""L2 JAX graphs: the computations the rust runtime executes.

Two graphs are AOT-lowered to HLO text (see ``aot.py``):

* ``split_eval`` — evaluate every split candidate of a batch of features
  (the paper's Alg. 2 batched over leaves x features) and reduce to the
  best candidate per feature. Calls the ``vr_split`` Pallas kernel; the
  argmax reduction stays at L2 so XLA fuses it with the kernel output.
* ``quantize_ingest`` — bulk Quantization-Observer update (paper Alg. 1)
  over a batch of (x, y) pairs, producing a dense slot table. Calls the
  ``quantize.segsum`` Pallas kernel.

The rust side pads its inputs to the fixed AOT shapes; both graphs are
pure functions of their arguments (no captured state), so one compiled
executable serves every leaf of every tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import quantize as qk
from compile.kernels import vr_split as vk


def split_eval(n, sum_x, mean, m2):
    """Best split per feature from packed slot statistics.

    Args:
      n, sum_x, mean, m2: (F, S) float64 packed slot statistics (sorted by
        key, padding slots trailing with n == 0).

    Returns a 5-tuple:
      vr:         (F, S) float64 merit of each boundary (-inf where invalid)
      split:      (F, S) float64 candidate split points
      best_idx:   (F,)   int32   argmax boundary per feature
      best_vr:    (F,)   float64 merit of the best boundary
      best_split: (F,)   float64 split point of the best boundary
    """
    vr, split = vk.vr_split(n, sum_x, mean, m2)
    best_idx = jnp.argmax(vr, axis=1).astype(jnp.int32)
    rows = jnp.arange(vr.shape[0])
    best_vr = vr[rows, best_idx]
    best_split = split[rows, best_idx]
    return vr, split, best_idx, best_vr, best_split


def quantize_ingest(x, y, r):
    """Bulk QO update; see ``kernels.quantize.quantize_ingest``.

    Returns (base_code:int32 scalar, table:(S,4) float64).
    """
    base, table = qk.quantize_ingest(x, y, r, num_slots=qk.DEFAULT_S)
    return base, table


def split_eval_example_args(f: int = vk.DEFAULT_F, s: int = vk.DEFAULT_S):
    spec = jax.ShapeDtypeStruct((f, s), jnp.float64)
    return (spec, spec, spec, spec)


def quantize_example_args(b: int = qk.DEFAULT_B):
    vec = jax.ShapeDtypeStruct((b,), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    return (vec, vec, scalar)
