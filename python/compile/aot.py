"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla_extension 0.5.1
bundled with the published ``xla`` crate rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Lowering goes stablehlo -> XlaComputation (``return_tuple=True`` so the rust
side unwraps a tuple) -> ``as_hlo_text()``.

Usage:  python -m compile.aot --out-dir ../artifacts
Emits:  split_eval_f{F}_s{S}.hlo.txt, quantize_b{B}_s{S}.hlo.txt,
        manifest.txt (shape metadata the rust runtime parses).
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import quantize as qk
from compile.kernels import vr_split as vk


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_split_eval(f: int, s: int) -> str:
    lowered = jax.jit(model.split_eval).lower(*model.split_eval_example_args(f, s))
    return to_hlo_text(lowered)


def lower_quantize(b: int) -> str:
    lowered = jax.jit(model.quantize_ingest).lower(*model.quantize_example_args(b))
    return to_hlo_text(lowered)


def build(out_dir: str, f: int, s: int, b: int) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []

    split_name = f"split_eval_f{f}_s{s}.hlo.txt"
    with open(os.path.join(out_dir, split_name), "w") as fh:
        fh.write(lower_split_eval(f, s))
    written.append(split_name)

    quant_name = f"quantize_b{b}_s{s}.hlo.txt"
    with open(os.path.join(out_dir, quant_name), "w") as fh:
        fh.write(lower_quantize(b))
    written.append(quant_name)

    # Plain key=value manifest (the rust side has no serde; keep it trivial).
    with open(os.path.join(out_dir, "manifest.txt"), "w") as fh:
        fh.write(f"split_eval={split_name}\n")
        fh.write(f"split_eval.f={f}\n")
        fh.write(f"split_eval.s={s}\n")
        fh.write(f"quantize={quant_name}\n")
        fh.write(f"quantize.b={b}\n")
        fh.write(f"quantize.s={s}\n")
    written.append("manifest.txt")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--features", type=int, default=vk.DEFAULT_F)
    ap.add_argument("--slots", type=int, default=vk.DEFAULT_S)
    ap.add_argument("--batch", type=int, default=qk.DEFAULT_B)
    args = ap.parse_args()
    written = build(args.out_dir, args.features, args.slots, args.batch)
    for name in written:
        path = os.path.join(args.out_dir, name)
        print(f"wrote {os.path.getsize(path)} bytes to {path}")


if __name__ == "__main__":
    main()
