"""L1 Pallas kernel: batched quantization update (paper Alg. 1) as a
segment-sum.

The streaming update of the Quantization Observer is a hash insert per
element. For bulk ingestion (replay buffers, warm-start, the coordinator's
batch path) the same math is a *segment reduction*: every element lands in
slot ``floor(x / r)`` and contributes (1, x, y, y^2) to that slot.

TPU adaptation: a scatter-add is hostile to the MXU, but the identity

    out[S, K] = one_hot(codes)[B, S]^T  @  vals[B, K]

turns the histogram into a (S, B) x (B, K) matmul — exactly what the
systolic array is built for (the paper's hash insert becomes a matmul, the
same trick LightGBM-on-GPU uses for histogram building). Codes outside
[0, S) produce an all-zero one-hot row and are dropped; the caller windows
the batch so nothing is lost.

interpret=True (CPU PJRT); f64 accumulate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT shapes.
DEFAULT_B = 1024
DEFAULT_S = 256
STAT_K = 4  # [count, sum_x, sum_y, sum_y2]


def _segsum_kernel(codes_ref, vals_ref, out_ref):
    codes = codes_ref[...]          # (B,) int32
    vals = vals_ref[...]            # (B, K) f64
    b = codes.shape[0]
    s = out_ref.shape[0]
    # one_hot: (B, S) f64 — rows with out-of-range codes are all zero.
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, s), 1)
    onehot = (codes[:, None] == iota).astype(vals.dtype)
    # (S, B) @ (B, K) -> (S, K): the MXU does the segment reduction.
    out_ref[...] = jnp.dot(onehot.T, vals, preferred_element_type=vals.dtype)


@functools.partial(jax.jit, static_argnames=("num_slots",))
def segsum(codes, vals, *, num_slots: int = DEFAULT_S):
    """Segment-sum ``vals`` rows into ``num_slots`` buckets by ``codes``.

    Args:
      codes: (B,) int32 rebased bucket codes; out-of-range rows are dropped.
      vals:  (B, K) float64 per-element statistics rows.

    Returns:
      (num_slots, K) float64 aggregated table.
    """
    b, k = vals.shape
    return pl.pallas_call(
        _segsum_kernel,
        out_shape=jax.ShapeDtypeStruct((num_slots, k), vals.dtype),
        interpret=True,
    )(codes, vals)


@functools.partial(jax.jit, static_argnames=("num_slots",))
def quantize_ingest(x, y, r, *, num_slots: int = DEFAULT_S):
    """Full batched QO update: codes, rebase to the batch's min code,
    aggregate into a dense slot table.

    Args:
      x, y: (B,) float64 feature / target batches.
      r: scalar float64 quantization radius.

    Returns:
      (base_code, table): base_code is int32 (the code of slot 0); table is
      (num_slots, 4) float64 [count, sum_x, sum_y, sum_y2].
    """
    codes = jnp.floor(x / r).astype(jnp.int32)
    base = jnp.min(codes)
    rebased = codes - base
    ones = jnp.ones_like(x)
    vals = jnp.stack([ones, x, y, y * y], axis=1)
    table = segsum(rebased, vals, num_slots=num_slots)
    return base, table
