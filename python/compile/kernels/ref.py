"""Pure-numpy correctness oracles for the Pallas kernels.

These are the trusted, slow implementations of the paper's math:

* Welford / Chan et al. robust mean-variance statistics (paper Sec. 3,
  Eqs. 2-7), implemented sequentially over slots.
* The Quantization Observer split-candidate query (paper Alg. 2): prefix
  Chan-merge over the sorted slots, complement-by-subtraction for the
  right-hand side, Variance Reduction merit (Eq. 1, sign-corrected as in
  FIMT) for every boundary candidate.
* The batched quantization update (paper Alg. 1): bucket code
  ``floor(x / r)`` and per-slot aggregation of (count, sum_x, sum_y,
  sum_y2).

Everything is float64: the rust coordinator keeps f64 statistics, and the
pytest suite asserts near-exact agreement between kernel, oracle and the
rust-side math.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Robust statistics (paper Sec. 3)
# ---------------------------------------------------------------------------


def welford_update(stats, y: float, w: float = 1.0):
    """One Welford step (Eqs. 2-3), weighted.

    ``stats`` is the triple (n, mean, M2).
    """
    n, mean, m2 = stats
    n_new = n + w
    delta = y - mean
    mean_new = mean + (w / n_new) * delta
    m2_new = m2 + w * delta * (y - mean_new)
    return (n_new, mean_new, m2_new)


def chan_merge(a, b):
    """Chan et al. parallel merge (Eqs. 4-5)."""
    na, ma, m2a = a
    nb, mb, m2b = b
    n = na + nb
    if n <= 0.0:
        return (0.0, 0.0, 0.0)
    if na == 0.0:
        return b
    if nb == 0.0:
        return a
    delta = mb - ma
    mean = (na * ma + nb * mb) / n
    m2 = m2a + m2b + delta * delta * (na * nb / n)
    return (n, mean, m2)


def chan_subtract(ab, b):
    """Complement of a partial estimate (Eqs. 6-7): returns A = AB - B."""
    nab, mab, m2ab = ab
    nb, mb, m2b = b
    na = nab - nb
    if na <= 0.0:
        return (0.0, 0.0, 0.0)
    ma = (nab * mab - nb * mb) / na
    delta = mb - ma
    m2a = m2ab - m2b - delta * delta * (na * nb / nab)
    return (na, ma, max(m2a, 0.0))


def variance(stats) -> float:
    """Sample variance s^2 = M2 / (n - 1) (0 for n <= 1)."""
    n, _, m2 = stats
    if n <= 1.0:
        return 0.0
    return m2 / (n - 1.0)


def variance_reduction(total, left, right) -> float:
    """VR merit (paper Eq. 1, sign-corrected to the FIMT form):

    VR = s2(d) - (|l-|/|d|) s2(l-) - (|l+|/|d|) s2(l+)
    """
    n = total[0]
    if n <= 0.0:
        return 0.0
    return (
        variance(total)
        - (left[0] / n) * variance(left)
        - (right[0] / n) * variance(right)
    )


# ---------------------------------------------------------------------------
# Split-candidate query oracle (paper Alg. 2), batched over features
# ---------------------------------------------------------------------------

NEG_INF = -np.inf


def vr_split_ref(n, sum_x, mean, m2):
    """Reference for the vr_split kernel.

    Args:
      n, sum_x, mean, m2: float64 arrays of shape (F, S). Slots are sorted
        by quantization key and packed to the front; padding slots have
        n == 0 and MUST be trailing.

    Returns:
      vr:    (F, S) float64 — merit of splitting *after* slot i (boundary
             between slot i and slot i+1); -inf where there is no boundary.
      split: (F, S) float64 — candidate split point, the midpoint of the
             prototypes (sum_x/n) of slots i and i+1; 0 where invalid.
    """
    n = np.asarray(n, dtype=np.float64)
    sum_x = np.asarray(sum_x, dtype=np.float64)
    mean = np.asarray(mean, dtype=np.float64)
    m2 = np.asarray(m2, dtype=np.float64)
    F, S = n.shape
    vr = np.full((F, S), NEG_INF, dtype=np.float64)
    split = np.zeros((F, S), dtype=np.float64)
    for f in range(F):
        valid = int(np.sum(n[f] > 0.0))
        if valid < 2:
            continue
        total = (0.0, 0.0, 0.0)
        for i in range(valid):
            total = chan_merge(total, (n[f, i], mean[f, i], m2[f, i]))
        left = (0.0, 0.0, 0.0)
        for i in range(valid - 1):
            left = chan_merge(left, (n[f, i], mean[f, i], m2[f, i]))
            right = chan_subtract(total, left)
            vr[f, i] = variance_reduction(total, left, right)
            proto_i = sum_x[f, i] / n[f, i]
            proto_j = sum_x[f, i + 1] / n[f, i + 1]
            split[f, i] = 0.5 * (proto_i + proto_j)
    return vr, split


def best_split_ref(n, sum_x, mean, m2):
    """argmax over the vr_split_ref outputs: (best_idx, best_vr, best_split)."""
    vr, split = vr_split_ref(n, sum_x, mean, m2)
    idx = np.argmax(vr, axis=1)
    rows = np.arange(vr.shape[0])
    return idx, vr[rows, idx], split[rows, idx]


# ---------------------------------------------------------------------------
# Batched quantization-update oracle (paper Alg. 1)
# ---------------------------------------------------------------------------


def quantize_codes_ref(x, r: float):
    """Bucket codes h = floor(x / r) (int64)."""
    return np.floor(np.asarray(x, dtype=np.float64) / r).astype(np.int64)


def segsum_ref(codes, x, y, num_slots: int):
    """Reference for the quantize/segment-sum kernel.

    ``codes`` are already rebased to [0, num_slots); out-of-range codes are
    dropped (the rust side windows the batch so this never loses data).

    Returns stacked (num_slots, 4): [count, sum_x, sum_y, sum_y2].
    """
    codes = np.asarray(codes)
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    out = np.zeros((num_slots, 4), dtype=np.float64)
    for c, xi, yi in zip(codes, x, y):
        if 0 <= c < num_slots:
            out[c, 0] += 1.0
            out[c, 1] += xi
            out[c, 2] += yi
            out[c, 3] += yi * yi
    return out


def quantize_ingest_ref(x, y, r: float, num_slots: int):
    """Full ingest oracle: codes, rebase to min code, aggregate.

    Returns (base_code, table) where table is (num_slots, 4).
    """
    codes = quantize_codes_ref(x, r)
    base = int(codes.min()) if codes.size else 0
    return base, segsum_ref(codes - base, x, y, num_slots)
