"""L1 Pallas kernel: batched Variance-Reduction split-candidate evaluation.

This is the compute hot-spot of the Quantization Observer's split query
(paper Alg. 2), restated as a data-parallel computation so that *all*
boundary candidates of *many* features are evaluated in one pass:

  given per-slot statistics (n, sum_x, mean_y, M2_y) sorted by quantization
  key and packed to the front of the slot axis, compute for every boundary
  ``i`` (between slot i and slot i+1):

    left  = prefix-merge(slots[0..=i])         (Chan et al. merge)
    right = total - left                       (Chan et al. subtraction)
    VR[i] = s2(total) - (nL/nT) s2(left) - (nR/nT) s2(right)
    split[i] = (prototype[i] + prototype[i+1]) / 2

The prefix Chan-merge has a closed form over cumulative sums: for a prefix
with count cn, y-sum cs and y-square-sum cq,

    mean = cs / cn          M2 = cq - cs^2 / cn

which turns the sequential merge loop of Alg. 2 into three ``cumsum``s plus
elementwise math — exactly the shape the VPU vectorizes over the slot axis.
All math is f64 (slot statistics are pre-aggregated, so the classic
naive-sum cancellation the paper warns about is bounded; the pytest suite
checks agreement with the sequential Chan-merge oracle to 1e-9).

TPU adaptation (DESIGN.md "Hardware adaptation"): the grid tiles the
feature axis; each block holds (F_BLOCK, S) f64 slabs in VMEM (~10 KiB per
feature at S=256), and S is kept a multiple of 128 so the per-boundary VR
math maps onto full lanes. interpret=True everywhere (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

# Default AOT shapes (rust runtime pads to these).
DEFAULT_F = 8
DEFAULT_S = 256
F_BLOCK = 8


def _safe_div(a, b):
    """a / b with 0 where b == 0 (padding slots)."""
    return jnp.where(b != 0.0, a / jnp.where(b != 0.0, b, 1.0), 0.0)


def _vr_split_kernel(n_ref, sum_x_ref, mean_ref, m2_ref, vr_ref, split_ref):
    n = n_ref[...]
    sum_x = sum_x_ref[...]
    mean = mean_ref[...]
    m2 = m2_ref[...]
    fb, s = n.shape

    # Slot-level sufficient statistics.
    sy = n * mean                # per-slot sum of y
    q = m2 + n * mean * mean     # per-slot sum of y^2

    # Prefix (left) statistics via cumulative sums == closed-form Chan
    # merge. (Perf note: a triangular-matmul formulation — MXU-shaped for
    # TPU — was tried and measured 2x SLOWER on the CPU runtime's older
    # XLA, so the cumsum lowering stays; see EXPERIMENTS.md §Perf.)
    cn = jnp.cumsum(n, axis=1)
    cs = jnp.cumsum(sy, axis=1)
    cq = jnp.cumsum(q, axis=1)

    # Totals: padding slots are all-zero, so the last prefix is the total.
    nt = cn[:, -1:]
    st = cs[:, -1:]
    qt = cq[:, -1:]

    def m2_of(cnt, ysum, ysq):
        return jnp.maximum(ysq - _safe_div(ysum * ysum, cnt), 0.0)

    def s2_of(cnt, ysum, ysq):
        denom = jnp.where(cnt > 1.0, cnt - 1.0, 1.0)
        return jnp.where(cnt > 1.0, m2_of(cnt, ysum, ysq) / denom, 0.0)

    s2_t = s2_of(nt, st, qt)

    nl = cn
    s2_l = s2_of(cn, cs, cq)
    nr = nt - cn
    s2_r = s2_of(nr, st - cs, qt - cq)

    frac_l = _safe_div(nl, jnp.broadcast_to(nt, nl.shape))
    frac_r = _safe_div(nr, jnp.broadcast_to(nt, nr.shape))
    vr = s2_t - frac_l * s2_l - frac_r * s2_r

    # A boundary after slot i exists iff slot i and slot i+1 are both
    # occupied (slots are packed, so occupancy is a prefix property).
    zeros_col = jnp.zeros((fb, 1), dtype=n.dtype)
    n_next = jnp.concatenate([n[:, 1:], zeros_col], axis=1)
    sum_x_next = jnp.concatenate([sum_x[:, 1:], zeros_col], axis=1)
    valid = (n > 0.0) & (n_next > 0.0)

    proto = _safe_div(sum_x, n)
    proto_next = _safe_div(sum_x_next, n_next)
    split = jnp.where(valid, 0.5 * (proto + proto_next), 0.0)
    vr = jnp.where(valid, vr, NEG_INF)

    vr_ref[...] = vr
    split_ref[...] = split


@functools.partial(jax.jit, static_argnames=("f_block",))
def vr_split(n, sum_x, mean, m2, *, f_block: int = F_BLOCK):
    """Evaluate all split candidates for a batch of features.

    Args:
      n, sum_x, mean, m2: (F, S) float64 packed slot statistics.
      f_block: feature-axis tile size (F must be a multiple).

    Returns:
      (vr, split): both (F, S) float64; ``vr`` is -inf at non-boundaries.
    """
    f, s = n.shape
    assert f % f_block == 0, (f, f_block)
    out_shape = [
        jax.ShapeDtypeStruct((f, s), jnp.float64),
        jax.ShapeDtypeStruct((f, s), jnp.float64),
    ]
    if f == f_block:
        # Single block: skip the grid machinery entirely. The interpret-
        # mode grid loop lowers to while/dynamic-slice HLO that the older
        # XLA bundled with the rust runtime (xla_extension 0.5.1)
        # optimizes poorly (~4x slower end-to-end; EXPERIMENTS.md §Perf).
        return pl.pallas_call(
            _vr_split_kernel,
            out_shape=out_shape,
            interpret=True,  # CPU PJRT; real-TPU would lower to Mosaic
        )(n, sum_x, mean, m2)
    grid = (f // f_block,)
    spec = pl.BlockSpec((f_block, s), lambda i: (i, 0))
    return pl.pallas_call(
        _vr_split_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT; real-TPU would lower to Mosaic
    )(n, sum_x, mean, m2)
