//! Minimal clean-room shim of the `anyhow` error-handling surface.
//!
//! The offline build has no crates.io access, so this path dependency
//! provides exactly the subset `qostream` uses: [`Error`], [`Result`],
//! the [`anyhow!`], [`ensure!`] and [`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Context is recorded by prefixing the wrapped error's message
//! (`"context: cause"`), which is how the real crate renders the chain in
//! its `{:#}` format; the full source-chain machinery is intentionally
//! not reproduced.

#![forbid(unsafe_code)]

use std::fmt;

/// A string-backed error value, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prefix the message with a context line.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket conversion below coherent (same as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn question_mark_converts_std_errors() {
        fn read_missing() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read_missing().is_err());
    }

    #[test]
    fn context_prefixes_message() {
        let e: Result<()> = Err(anyhow!("root cause"));
        let e = e.map_err(|e| e.context("while testing"));
        assert_eq!(format!("{}", e.unwrap_err()), "while testing: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let r = v.context("missing value");
        assert_eq!(format!("{}", r.unwrap_err()), "missing value");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert!(check(-1).is_err());
        assert!(check(200).is_err());
    }
}
