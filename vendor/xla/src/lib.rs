//! API-compatible offline stub of the PJRT/XLA binding surface `qostream`
//! programs against.
//!
//! The real backend links libxla and a PJRT CPU plugin; this container
//! build has neither, so [`PjRtClient::cpu`] reports the runtime as
//! unavailable and every consumer (the `runtime` module, the `xla`
//! CLI subcommand, `runtime_roundtrip` tests, `xla_vs_native` bench)
//! detects that and skips the PJRT path. Pure host-side [`Literal`]
//! construction is implemented for real so literal-handling code keeps
//! working; anything that would require a compiled executable returns
//! [`Error`].
//!
//! Swapping this stub for a real `xla` crate (same module paths) re-enables
//! the full AOT artifact path without touching `qostream` itself.

#![forbid(unsafe_code)]

use std::fmt;

/// Error type of the stubbed binding layer.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error::new(
        "PJRT runtime not available in this build (offline stub); \
         link the real xla crate to enable the AOT artifact path",
    )
}

/// Element types a [`Shape`] or [`Literal`] can carry.
pub trait NativeType: Copy + 'static {
    const NAME: &'static str;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
}

macro_rules! native {
    ($t:ty, $name:literal) => {
        impl NativeType for $t {
            const NAME: &'static str = $name;
            fn from_f64(v: f64) -> $t {
                v as $t
            }
            fn to_f64(self) -> f64 {
                self as f64
            }
        }
    };
}

native!(f64, "f64");
native!(f32, "f32");
native!(i64, "s64");
native!(i32, "s32");

/// Array shape: element type name + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    element: &'static str,
    dims: Vec<i64>,
}

impl Shape {
    pub fn array<E: NativeType>(dims: Vec<i64>) -> Shape {
        Shape { element: E::NAME, dims }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal: f64 storage plus dimensions (sufficient for the
/// argument-marshalling code paths exercised without a runtime).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Rank-0 scalar literal.
    pub fn scalar(v: f64) -> Literal {
        Literal { data: vec![v], dims: Vec::new() }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal. The stub never produces tuples (they only
    /// come back from executions), so this is always an error here.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<E: NativeType>(&self) -> Result<Vec<E>> {
        Ok(self.data.iter().map(|&v| E::from_f64(v)).collect())
    }
}

/// Parsed HLO module (stub: parsing requires the runtime's HLO parser).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Graph-construction builder (stub: all ops report the runtime missing).
pub struct XlaBuilder {
    _name: String,
}

impl XlaBuilder {
    pub fn new(name: &str) -> XlaBuilder {
        XlaBuilder { _name: name.to_string() }
    }

    pub fn parameter_s(&self, _id: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        Err(unavailable())
    }

    pub fn constant_r0<E: NativeType>(&self, _v: E) -> Result<XlaOp> {
        Err(unavailable())
    }
}

/// A node in a computation under construction.
pub struct XlaOp {
    _priv: (),
}

impl XlaOp {
    pub fn add_(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        Err(unavailable())
    }

    pub fn build(&self) -> Result<XlaComputation> {
        Err(unavailable())
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single entry point; in
/// this stub it always fails, which is how downstream code discovers the
/// runtime is absent.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// A compiled executable (unreachable in the stub: `compile` always errs).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device buffer (unreachable in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("not available"));
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(lit.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn scalar_shape() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.to_vec::<f64>().unwrap(), vec![2.5]);
        let shape = Shape::array::<f64>(vec![8, 256]);
        assert_eq!(shape.dims(), &[8, 256]);
    }
}
