//! End-to-end forest contracts (the PR acceptance criteria):
//!
//! 1. an [`ArfRegressor`] with ≥ 10 members beats a single
//!    `HoeffdingTreeRegressor` on MAE on a `stream::AbruptDrift` Friedman
//!    stream,
//! 2. the parallel fitting path produces predictions identical to
//!    sequential fitting with the same seed, and
//! 3. the sharded distributed forest (`coordinator::forest`) — trained
//!    members and the leader-merged distributed vote alike — is
//!    bit-for-bit identical to the sequential ensemble.

use qostream::coordinator::{fit_sharded_voting, ForestCoordinatorConfig, Partitioner};
use qostream::eval::{prequential, Regressor};
use qostream::forest::{
    fit_parallel, ArfOptions, ArfRegressor, OnlineBaggingRegressor, ParallelFitConfig,
    SubspaceSize,
};
use qostream::observer::{factory, ObserverFactory, QuantizationObserver, RadiusPolicy};
use qostream::stream::{AbruptDrift, Friedman1, Stream};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions, SplitBackendKind};

fn qo_factory() -> Box<dyn ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

/// Friedman #1 whose informative-feature roles swap abruptly at `position`
/// — same input distribution, genuinely different concept.
fn drift_stream(position: usize) -> AbruptDrift {
    AbruptDrift::new(
        Box::new(Friedman1::new(7, 1.0)),
        Box::new(Friedman1::swapped(8, 1.0)),
        position,
    )
}

#[test]
fn arf_beats_single_tree_on_drifting_friedman() {
    let n = 16_000;
    let drift_at = 8_000;

    let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory());
    let r_tree = prequential(&mut tree, &mut drift_stream(drift_at), n, 0);

    let mut arf = ArfRegressor::new(
        10,
        ArfOptions {
            n_members: 10,
            lambda: 6.0,
            subspace: SubspaceSize::Fraction(0.7),
            seed: 1,
            ..Default::default()
        },
        qo_factory(),
    );
    let r_arf = prequential(&mut arf, &mut drift_stream(drift_at), n, 0);

    assert!(
        r_arf.metrics.mae() < r_tree.metrics.mae(),
        "ARF MAE {} must beat the single tree's {} on the drifting stream",
        r_arf.metrics.mae(),
        r_tree.metrics.mae()
    );
    assert!(r_arf.metrics.r2() > 0.4, "ARF r2 = {}", r_arf.metrics.r2());
    assert!(arf.n_splits() >= arf.n_members(), "forest barely grew");
}

#[test]
fn parallel_arf_fit_identical_to_sequential() {
    let n = 6_000;
    let drift_at = 3_000;
    let opts = ArfOptions { n_members: 6, lambda: 4.0, seed: 99, ..Default::default() };

    let mut sequential = ArfRegressor::new(10, opts, qo_factory());
    let mut stream = drift_stream(drift_at);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        sequential.learn_one(&inst.x, inst.y);
    }

    let mut parallel = ArfRegressor::new(10, opts, qo_factory());
    let report = fit_parallel(
        &mut parallel,
        &mut drift_stream(drift_at),
        n,
        ParallelFitConfig { n_workers: 3, batch_size: 128, channel_capacity: 4 },
    );
    assert_eq!(report.instances, n);
    assert_eq!(report.n_workers, 3);
    assert_eq!(sequential.n_drifts(), parallel.n_drifts());
    assert_eq!(sequential.n_warnings(), parallel.n_warnings());

    let mut probe = Friedman1::new(4242, 0.0);
    for _ in 0..200 {
        let inst = probe.next_instance().unwrap();
        let a = sequential.predict(&inst.x);
        let b = parallel.predict(&inst.x);
        assert_eq!(a.to_bits(), b.to_bits(), "parallel {b} != sequential {a}");
    }
}

#[test]
fn parallel_bagging_fit_identical_to_sequential() {
    let n = 4_000;
    let mut sequential =
        OnlineBaggingRegressor::new(10, 5, 6.0, HtrOptions::default(), qo_factory(), 55);
    let mut stream = Friedman1::new(17, 1.0);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        sequential.learn_one(&inst.x, inst.y);
    }

    let mut parallel =
        OnlineBaggingRegressor::new(10, 5, 6.0, HtrOptions::default(), qo_factory(), 55);
    fit_parallel(
        &mut parallel,
        &mut Friedman1::new(17, 1.0),
        n,
        ParallelFitConfig { n_workers: 2, ..Default::default() },
    );

    let mut probe = Friedman1::new(31, 0.0);
    for _ in 0..100 {
        let inst = probe.next_instance().unwrap();
        assert_eq!(
            sequential.predict(&inst.x).to_bits(),
            parallel.predict(&inst.x).to_bits()
        );
    }
}

#[test]
fn batched_split_backend_bit_identical_to_per_observer_forest() {
    // the PR acceptance criterion at forest scale: with warnings, drifts
    // and background trees in play, the batched split-query backend must
    // reproduce the per-observer path bit-for-bit — same splits, same
    // detector signals, same predictions
    let n = 6_000;
    let drift_at = 3_000;
    let run = |kind: SplitBackendKind| {
        let mut arf = ArfRegressor::new(
            10,
            ArfOptions {
                n_members: 6,
                lambda: 6.0,
                seed: 5,
                tree: HtrOptions { split_backend: kind, ..Default::default() },
                ..Default::default()
            },
            qo_factory(),
        );
        let mut stream = drift_stream(drift_at);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        arf
    };
    let reference = run(SplitBackendKind::PerObserver);
    let batched = run(SplitBackendKind::NativeBatch);
    assert_eq!(reference.n_splits(), batched.n_splits());
    assert_eq!(reference.n_warnings(), batched.n_warnings());
    assert_eq!(reference.n_drifts(), batched.n_drifts());
    let mut probe = Friedman1::new(909, 0.0);
    for _ in 0..200 {
        let inst = probe.next_instance().unwrap();
        assert_eq!(
            reference.predict(&inst.x).to_bits(),
            batched.predict(&inst.x).to_bits(),
            "batched backend diverged from the per-observer path"
        );
    }
}

#[test]
fn sharded_forest_identical_to_sequential() {
    // the distributed-forest acceptance criterion, end to end: with
    // warnings, drifts and background trees in play, the leader/shard fit
    // and its leader-merged distributed vote must reproduce the sequential
    // ensemble bit-for-bit
    let n = 6_000;
    let drift_at = 3_000;
    let opts = ArfOptions { n_members: 6, lambda: 4.0, seed: 99, ..Default::default() };

    let mut sequential = ArfRegressor::new(10, opts, qo_factory());
    let mut stream = drift_stream(drift_at);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        sequential.learn_one(&inst.x, inst.y);
    }

    let mut probe = Friedman1::new(4242, 0.0);
    let probes: Vec<Vec<f64>> = (0..200).map(|_| probe.next_instance().unwrap().x).collect();

    for partitioner in [Partitioner::RoundRobin, Partitioner::IndexHash] {
        let mut sharded = ArfRegressor::new(10, opts, qo_factory());
        let (report, merged) = fit_sharded_voting(
            &mut sharded,
            &mut drift_stream(drift_at),
            n,
            &probes,
            ForestCoordinatorConfig {
                n_shards: 3,
                batch_size: 128,
                channel_capacity: 4,
                partitioner,
            },
        );
        assert_eq!(report.instances, n);
        assert!((1..=3).contains(&report.n_shards));
        assert_eq!(report.members_per_shard.iter().sum::<usize>(), 6);
        assert!(report.instances_per_shard.iter().all(|&c| c == n));
        // every shard batched its split attempts: at most one backend
        // round-trip per tick, and at least one over the whole run
        for (&calls, &members) in
            report.backend_calls_per_shard.iter().zip(&report.members_per_shard)
        {
            assert!(calls >= 1, "a {members}-member shard never flushed");
            assert!(calls <= n, "more than one backend round-trip per tick");
        }
        assert_eq!(sequential.n_splits(), sharded.n_splits());
        assert_eq!(sequential.n_warnings(), sharded.n_warnings());
        assert_eq!(sequential.n_drifts(), sharded.n_drifts());
        for (x, &vote) in probes.iter().zip(&merged) {
            let want = sequential.predict(x);
            assert_eq!(
                vote.to_bits(),
                want.to_bits(),
                "distributed vote {vote} != sequential {want} ({partitioner:?})"
            );
        }
    }
}

#[test]
fn sharded_bagging_identical_to_sequential() {
    let n = 4_000;
    let mut sequential =
        OnlineBaggingRegressor::new(10, 5, 6.0, HtrOptions::default(), qo_factory(), 55);
    let mut stream = Friedman1::new(17, 1.0);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        sequential.learn_one(&inst.x, inst.y);
    }

    let mut probe = Friedman1::new(31, 0.0);
    let probes: Vec<Vec<f64>> = (0..100).map(|_| probe.next_instance().unwrap().x).collect();
    let mut sharded =
        OnlineBaggingRegressor::new(10, 5, 6.0, HtrOptions::default(), qo_factory(), 55);
    let (report, merged) = fit_sharded_voting(
        &mut sharded,
        &mut Friedman1::new(17, 1.0),
        n,
        &probes,
        ForestCoordinatorConfig { n_shards: 2, ..Default::default() },
    );
    assert_eq!(report.instances, n);
    for (x, &vote) in probes.iter().zip(&merged) {
        assert_eq!(vote.to_bits(), sequential.predict(x).to_bits());
    }
}

#[test]
fn arf_detects_the_concept_swap() {
    // at least one member must raise a warning or drift after the swap —
    // the adaptation machinery has to actually engage on this workload
    let n = 12_000;
    let drift_at = 6_000;
    let mut arf = ArfRegressor::new(
        10,
        ArfOptions { n_members: 8, lambda: 6.0, seed: 3, ..Default::default() },
        qo_factory(),
    );
    let mut stream = drift_stream(drift_at);
    let mut before = (0, 0);
    for i in 0..n {
        let inst = stream.next_instance().unwrap();
        arf.learn_one(&inst.x, inst.y);
        if i + 1 == drift_at {
            before = (arf.n_warnings(), arf.n_drifts());
        }
    }
    let raised_after =
        (arf.n_warnings() + arf.n_drifts()) > (before.0 + before.1);
    assert!(
        raised_after,
        "no member reacted to the swap (warnings {} -> {}, drifts {} -> {})",
        before.0,
        arf.n_warnings(),
        before.1,
        arf.n_drifts()
    );
}
