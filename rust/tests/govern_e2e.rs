//! End-to-end memory-governance soak over a real TCP session: a leader
//! trained far past its byte budget on a drifting stream must publish
//! only governed state — every probe of the published snapshot stays
//! inside the budget — while prequential RMSE stays within tolerance of
//! an identically-driven unbounded leader. The weekly scheduled CI run
//! stretches the soak 10x via `GOVERN_SOAK_SCALE` (docs/MEMORY.md).

use qostream::common::json::Json;
use qostream::forest::{ArfOptions, ArfRegressor};
use qostream::observer::{factory, QuantizationObserver, RadiusPolicy};
use qostream::persist::Model;
use qostream::serve::{ServeClient, ServeOptions, Server};
use qostream::stream::{AbruptDrift, Friedman1, Stream};

/// Soak multiplier: CI's weekly `schedule:` run sets `GOVERN_SOAK_SCALE=10`
/// so the same test trains an order of magnitude longer, surfacing slow
/// leaks a PR-sized run misses. Defaults to 1 everywhere else.
fn soak_scale() -> usize {
    std::env::var("GOVERN_SOAK_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
        .max(1)
}

fn qo_factory() -> Box<dyn qostream::observer::ObserverFactory> {
    factory("QO_0.01", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::Fixed(0.01)))
    })
}

fn arf_model(seed: u64) -> Model {
    Model::Arf(ArfRegressor::new(
        10,
        ArfOptions { n_members: 3, lambda: 6.0, seed, ..Default::default() },
        qo_factory(),
    ))
}

/// Friedman1 with an abrupt mid-stream concept swap — drift forces fresh
/// leaf growth after the budget is already tight, so the escalation
/// ladder keeps getting re-triggered instead of enforcing once.
fn drifting_stream(seed: u64, instances: usize) -> AbruptDrift {
    AbruptDrift::new(
        Box::new(Friedman1::new(seed, 1.0)),
        Box::new(Friedman1::swapped(seed.wrapping_add(1), 1.0)),
        instances / 2,
    )
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(-1.0)
}

/// Drive one leader through the drifting stream over TCP, prequentially
/// scoring against the published snapshot after `skip` warmup learns.
/// When `budget > 0`, every probe (explicit snapshot = trainer sync
/// point, then `stats`) asserts the published footprint is inside the
/// budget and the `over_budget` flag is clear. Returns the prequential
/// RMSE and the final published `mem_bytes`.
fn run_pass(budget: usize, instances: usize, skip: usize, seed: u64) -> (f64, usize) {
    let server = Server::start(
        arf_model(seed),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 200, mem_budget: budget, ..Default::default() },
    )
    .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    let mut stream = drifting_stream(seed, instances);
    let probe_every = 500;
    let mut sq_err = 0.0;
    let mut scored = 0usize;
    for i in 0..instances {
        let inst = stream.next_instance().expect("stream instance");
        if i >= skip {
            let p = client.predict(&inst.x).expect("predict");
            assert!(p.is_finite(), "prediction went non-finite at instance {i}");
            let err = p - inst.y;
            sq_err += err * err;
            scored += 1;
        }
        client.learn(&inst.x, inst.y).expect("learn ack");
        if budget > 0 && (i + 1) % probe_every == 0 {
            // explicit snapshot: drains the trainer FIFO and publishes,
            // so the stats below describe exactly the governed state the
            // outside world (reads, followers, checkpoints) can see
            client.snapshot().expect("probe snapshot");
            let stats = client.stats().expect("probe stats");
            let mem = num(&stats, "mem_bytes");
            assert!(
                mem > 0.0 && mem <= budget as f64,
                "published snapshot breached the budget at instance {}: \
                 mem_bytes={mem}, budget={budget}",
                i + 1
            );
            assert_eq!(num(&stats, "mem_budget"), budget as f64, "{stats:?}");
            assert_eq!(
                stats.get("over_budget").and_then(Json::as_bool),
                Some(false),
                "ladder must reach the budget on this workload: {stats:?}"
            );
        }
    }
    client.snapshot().expect("final snapshot");
    let stats = client.stats().expect("final stats");
    let final_mem = num(&stats, "mem_bytes");
    assert!(final_mem > 0.0, "{stats:?}");
    client.shutdown().expect("shutdown ack");
    server.join().expect("clean exit");
    let rmse = (sq_err / scored.max(1) as f64).sqrt();
    (rmse, final_mem as usize)
}

/// The soak: an unbounded reference pass sizes the workload's natural
/// footprint, then an identically-driven leader runs under 7/10 of it.
/// Every probe must stay inside the budget and the governed RMSE must
/// land within tolerance of the unbounded reference.
#[test]
fn governed_leader_stays_inside_its_budget_over_the_wire() {
    let scale = soak_scale();
    let instances = 4000 * scale;
    let skip = instances / 10;

    let (unbounded_rmse, unbounded_bytes) = run_pass(0, instances, skip, 42);
    assert!(unbounded_rmse.is_finite() && unbounded_rmse > 0.0);

    let budget = unbounded_bytes * 7 / 10;
    assert!(budget > 0, "reference footprint too small to govern: {unbounded_bytes}");
    let (governed_rmse, governed_bytes) = run_pass(budget, instances, skip, 42);

    assert!(
        governed_bytes <= budget,
        "final governed footprint {governed_bytes} exceeds budget {budget}"
    );
    let ratio = governed_rmse / unbounded_rmse;
    // looser than the bench gate's in-process 1.10 ceiling: both passes
    // score against a snapshot trailing by up to snapshot_every learns,
    // which adds identical lag noise to numerator and denominator
    assert!(
        ratio <= 1.25,
        "governed RMSE drifted too far from unbounded: \
         {governed_rmse} vs {unbounded_rmse} (ratio {ratio:.3})"
    );
}

/// An impossible budget (1 byte) exhausts the whole escalation ladder:
/// the server must keep serving, raise the `over_budget` flag, and
/// report `degraded` through `health` with a reason an operator (or a
/// load balancer) can act on — never crash or stop publishing.
#[test]
fn impossible_budget_degrades_health_but_keeps_serving() {
    let server = Server::start(
        arf_model(7),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 100, mem_budget: 1, ..Default::default() },
    )
    .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    let mut stream = Friedman1::new(7, 1.0);
    for _ in 0..300 {
        let inst = stream.next_instance().expect("instance");
        client.learn(&inst.x, inst.y).expect("learn ack");
    }
    client.snapshot().expect("snapshot");

    let stats = client.stats().expect("stats");
    assert_eq!(
        stats.get("over_budget").and_then(Json::as_bool),
        Some(true),
        "a 1-byte budget must be reported as unmeetable: {stats:?}"
    );
    assert_eq!(num(&stats, "mem_budget"), 1.0, "{stats:?}");

    let health = client.health().expect("health");
    assert_eq!(
        health.get("status").and_then(Json::as_str),
        Some("degraded"),
        "{health:?}"
    );
    let reasons = health.get("reasons").and_then(Json::as_arr).expect("reasons array");
    assert!(
        reasons
            .iter()
            .any(|r| r.as_str().is_some_and(|s| s.contains("memory budget"))),
        "degraded health must name the budget breach: {health:?}"
    );

    // fully governed (pruned to one member, coldest leaves evicted, slot
    // tables compacted) the model still answers reads
    let p = client.predict(&[0.5; 10]).expect("predict while degraded");
    assert!(p.is_finite());
    client.shutdown().expect("shutdown ack");
    server.join().expect("clean exit");
}
