//! Corruption-detection property test for the `audit::invariants`
//! verifier (satellite contract): over the same model × observer corpus
//! the persist round-trip suite uses, every clean checkpoint must verify
//! with **zero findings**, and every single-field mutation — a bit-flipped
//! float, swapped arena children, a truncated QO slot table, a broken
//! delta hash — must be flagged with its designed rule id. In debug
//! builds the test additionally proves `Model::load` never *silently*
//! accepts a mutated file (the boundary hook turns findings into errors).

use std::collections::BTreeMap;

use qostream::audit::invariants;
use qostream::common::json::Json;
use qostream::common::Rng;
use qostream::eval::Regressor;
use qostream::forest::{ArfOptions, ArfRegressor, OnlineBaggingRegressor};
use qostream::observer::ObserverSpec;
use qostream::persist::codec::{ju64, jusize};
use qostream::persist::{delta, Model};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

/// One synthetic instance: 4 features, a piecewise target with noise
/// (the persist_roundtrip stream).
fn draw_instance(rng: &mut Rng) -> (Vec<f64>, f64) {
    let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let base = if x[0] <= 0.0 { 3.0 * x[1] } else { -2.0 + x[2] };
    let y = base + rng.normal(0.0, 0.2);
    (x, y)
}

/// Train one model of `kind` ("tree" | "arf" | "bagging") over `label`'s
/// observer for `n` instances.
fn trained(label: &str, kind: &str, rng: &mut Rng, n: usize) -> Model {
    let fac = || ObserverSpec::from_label(label).expect(label).to_factory();
    let tree_opts = HtrOptions { grace_period: 100, ..Default::default() };
    let mut model = match kind {
        "tree" => Model::Tree(HoeffdingTreeRegressor::new(4, tree_opts, fac())),
        "arf" => Model::Arf(ArfRegressor::new(
            4,
            ArfOptions {
                n_members: 2,
                lambda: 2.0,
                seed: rng.next_u64(),
                tree: tree_opts,
                ..Default::default()
            },
            fac(),
        )),
        "bagging" => Model::Bagging(OnlineBaggingRegressor::new(
            4,
            2,
            1.5,
            tree_opts,
            fac(),
            rng.next_u64(),
        )),
        other => panic!("unknown kind {other}"),
    };
    for _ in 0..n {
        let (x, y) = draw_instance(rng);
        model.learn_one(&x, y);
    }
    model
}

// -- mutable JSON navigation (the enum variants are public) ----------------

fn obj_mut(j: &mut Json) -> &mut BTreeMap<String, Json> {
    match j {
        Json::Obj(map) => map,
        other => panic!("expected a JSON object, got {}", other.to_compact()),
    }
}

fn arr_mut(j: &mut Json) -> &mut Vec<Json> {
    match j {
        Json::Arr(items) => items,
        other => panic!("expected a JSON array, got {}", other.to_compact()),
    }
}

fn nodes_mut(doc: &mut Json) -> &mut Vec<Json> {
    let model = obj_mut(doc).get_mut("model").expect("model payload");
    arr_mut(obj_mut(model).get_mut("nodes").expect("node arena"))
}

fn nodes(doc: &Json) -> &[Json] {
    doc.get("model")
        .and_then(|m| m.get("nodes"))
        .and_then(Json::as_arr)
        .expect("node arena")
}

/// Index of the first node holding a `leaf` / `split` payload.
fn first_with(doc: &Json, key: &str) -> usize {
    nodes(doc)
        .iter()
        .position(|n| n.get(key).is_some())
        .unwrap_or_else(|| panic!("trained tree should hold a {key} node"))
}

/// (node, observer) indexes of the first observer matching `pred`.
fn find_observer(doc: &Json, pred: impl Fn(&Json) -> bool) -> Option<(usize, usize)> {
    for (ni, node) in nodes(doc).iter().enumerate() {
        let Some(leaf) = node.get("leaf") else { continue };
        let Some(observers) = leaf.get("observers").and_then(Json::as_arr) else { continue };
        for (oi, o) in observers.iter().enumerate() {
            if pred(o) {
                return Some((ni, oi));
            }
        }
    }
    None
}

fn observer_mut(doc: &mut Json, ni: usize, oi: usize) -> &mut Json {
    let node = &mut nodes_mut(doc)[ni];
    let leaf = obj_mut(node).get_mut("leaf").expect("leaf payload");
    let observers = arr_mut(obj_mut(leaf).get_mut("observers").expect("observer list"));
    &mut observers[oi]
}

// -- assertions ------------------------------------------------------------

/// The mutated document must trip `rule` (other findings may ride along —
/// a truncated slot table also breaks the sum — but `rule` must be there).
fn assert_rule(doc: &Json, rule: &str, what: &str) {
    let findings = invariants::verify_checkpoint(doc);
    assert!(
        findings.iter().any(|f| f.rule == rule),
        "{what}: expected a {rule} finding, got {findings:?}"
    );
}

/// Debug builds must refuse to load the mutated file (release decoders
/// may accept value-level corruption; the audit hook is debug-gated).
#[cfg(debug_assertions)]
fn assert_load_rejects(doc: &Json, what: &str) {
    let path = std::env::temp_dir()
        .join(format!("qostream-audit-corrupt-{}-{what}.json", std::process::id()));
    std::fs::write(&path, format!("{}\n", doc.to_compact())).expect("write mutated checkpoint");
    let result = Model::load(&path);
    std::fs::remove_file(&path).ok();
    assert!(result.is_err(), "{what}: Model::load silently accepted a corrupted checkpoint");
}

#[cfg(not(debug_assertions))]
fn assert_load_rejects(_doc: &Json, _what: &str) {}

// -- the corpus is clean (zero false positives) ----------------------------

#[test]
fn clean_corpus_has_zero_findings() {
    let mut rng = Rng::new(0xC0FFEE);
    for label in ["QO_s2", "QO_0.05", "E-BST", "TE-BST_3", "Exhaustive"] {
        for kind in ["tree", "arf", "bagging"] {
            let n = if kind == "tree" { 900 } else { 500 };
            let model = trained(label, kind, &mut rng, n);
            let findings = invariants::verify_model(&model);
            assert!(
                findings.is_empty(),
                "false positives on a clean {kind}[{label}]: {findings:?}"
            );
        }
    }
}

// -- single-field mutations on a tree checkpoint ---------------------------

#[test]
fn envelope_and_stats_mutations_are_flagged() {
    let mut rng = Rng::new(0xBADF00D);
    let clean = trained("QO_s2", "tree", &mut rng, 1500).to_checkpoint().expect("encode");
    assert!(invariants::verify_checkpoint(&clean).is_empty());

    // unknown kind tag
    let mut doc = clean.clone();
    doc.set("kind", "mystery");
    assert_rule(&doc, invariants::CKPT_ENVELOPE, "kind tag");
    assert_load_rejects(&doc, "kind");

    // bit-flipped float: a leaf mean that decodes to NaN
    let mut doc = clean.clone();
    let li = first_with(&doc, "leaf");
    {
        let node = &mut nodes_mut(&mut doc)[li];
        let leaf = obj_mut(node).get_mut("leaf").expect("leaf");
        let stats = arr_mut(obj_mut(leaf).get_mut("stats").expect("stats"));
        stats[1] = Json::Str("NaN".into());
    }
    assert_rule(&doc, invariants::VARSTATS_INVALID, "NaN leaf mean");
    assert_load_rejects(&doc, "nan-mean");

    // negative sample count
    let mut doc = clean.clone();
    {
        let node = &mut nodes_mut(&mut doc)[li];
        let leaf = obj_mut(node).get_mut("leaf").expect("leaf");
        let stats = arr_mut(obj_mut(leaf).get_mut("stats").expect("stats"));
        stats[0] = Json::Num(-2.0);
    }
    assert_rule(&doc, invariants::VARSTATS_INVALID, "negative leaf n");
    assert_load_rejects(&doc, "neg-n");

    // declared leaf depth disagrees with the arena
    let mut doc = clean.clone();
    {
        let node = &mut nodes_mut(&mut doc)[li];
        let leaf = obj_mut(node).get_mut("leaf").expect("leaf");
        leaf.set("depth", jusize(60));
    }
    assert_rule(&doc, invariants::ARENA_DEPTH, "forged leaf depth");
    assert_load_rejects(&doc, "depth");

    // deferred-attempt queue pointing at a node that does not exist
    let mut doc = clean.clone();
    {
        let model = obj_mut(&mut doc).get_mut("model").expect("model");
        let pending = arr_mut(obj_mut(model).get_mut("pending").expect("pending queue"));
        pending.push(jusize(9999));
    }
    assert_rule(&doc, invariants::PENDING_LEAF, "dangling pending entry");
    assert_load_rejects(&doc, "pending");
}

#[test]
fn arena_child_mutations_are_flagged() {
    let mut rng = Rng::new(0x5EED);
    let clean = trained("QO_s2", "tree", &mut rng, 2500).to_checkpoint().expect("encode");
    assert!(invariants::verify_checkpoint(&clean).is_empty());
    let si = first_with(&clean, "split");

    // child pointing backwards (breaks the anti-cycle ordering)
    let mut doc = clean.clone();
    {
        let node = &mut nodes_mut(&mut doc)[si];
        let split = obj_mut(node).get_mut("split").expect("split");
        split.set("left", jusize(0));
    }
    assert_rule(&doc, invariants::ARENA_CHILD_ORDER, "backward child");
    assert_load_rejects(&doc, "backward-child");

    // both children aliased to one node (the sibling becomes an orphan)
    let mut doc = clean.clone();
    {
        let node = &mut nodes_mut(&mut doc)[si];
        let split = obj_mut(node).get_mut("split").expect("split");
        let right = split.get("right").cloned().expect("right child");
        split.set("left", right);
    }
    assert_rule(&doc, invariants::ARENA_CHILD_ORDER, "aliased children");
    assert_load_rejects(&doc, "aliased-children");
}

// -- QO slot-table mutations ----------------------------------------------

/// A frozen-radius QO observer with at least two slots (fixed-radius QO
/// freezes immediately, so `QO_0.05` always yields one).
fn frozen_qo(doc: &Json) -> (usize, usize) {
    find_observer(doc, |o| {
        o.get("type").and_then(Json::as_str) == Some("qo")
            && o.get("state").is_some_and(|s| s.get("frozen").is_some())
            && o.get("slots").and_then(Json::as_arr).is_some_and(|s| s.len() >= 2)
    })
    .expect("a frozen QO observer with >= 2 slots")
}

#[test]
fn qo_slot_table_mutations_are_flagged() {
    let mut rng = Rng::new(0x9005);
    let clean = trained("QO_0.05", "tree", &mut rng, 1500).to_checkpoint().expect("encode");
    assert!(invariants::verify_checkpoint(&clean).is_empty());
    let (ni, oi) = frozen_qo(&clean);

    // truncated slot table: the slot mass no longer sums to the total
    let mut doc = clean.clone();
    arr_mut(obj_mut(observer_mut(&mut doc, ni, oi)).get_mut("slots").expect("slots")).pop();
    assert_rule(&doc, invariants::QO_TOTAL_DRIFT, "truncated slot table");
    assert_load_rejects(&doc, "slot-truncated");

    // slots out of code order
    let mut doc = clean.clone();
    arr_mut(obj_mut(observer_mut(&mut doc, ni, oi)).get_mut("slots").expect("slots")).swap(0, 1);
    assert_rule(&doc, invariants::QO_SLOT_ORDER, "swapped slots");
    assert_load_rejects(&doc, "slot-order");

    // a slot claiming zero weight
    let mut doc = clean.clone();
    {
        let slots =
            arr_mut(obj_mut(observer_mut(&mut doc, ni, oi)).get_mut("slots").expect("slots"));
        let stats = arr_mut(&mut arr_mut(&mut slots[0])[2]);
        stats[0] = Json::Num(0.0);
    }
    assert_rule(&doc, invariants::QO_SLOT_WEIGHT, "weightless slot");
    assert_load_rejects(&doc, "slot-weight");
}

// -- E-BST ordering --------------------------------------------------------

#[test]
fn ebst_key_swap_is_flagged() {
    let mut rng = Rng::new(0xEB57);
    let clean = trained("E-BST", "tree", &mut rng, 1500).to_checkpoint().expect("encode");
    assert!(invariants::verify_checkpoint(&clean).is_empty());

    let none = u64::from(u32::MAX);
    let (ni, oi) = find_observer(&clean, |o| {
        o.get("type").and_then(Json::as_str) == Some("ebst")
            && o.get("nodes").and_then(Json::as_arr).is_some_and(|s| s.len() >= 2)
    })
    .expect("an E-BST observer with >= 2 nodes");

    // swap the root key with one of its children: the child now sits on
    // the wrong side of its own bound
    let mut doc = clean.clone();
    {
        let o = observer_mut(&mut doc, ni, oi);
        let root = o.get("root").and_then(Json::as_str).expect("root").parse::<u64>().expect("u64")
            as usize;
        let ebst_nodes = arr_mut(obj_mut(o).get_mut("nodes").expect("ebst nodes"));
        let row = ebst_nodes[root].as_arr().expect("row");
        let left = row[2].as_str().and_then(|s| s.parse::<u64>().ok()).expect("left");
        let right = row[3].as_str().and_then(|s| s.parse::<u64>().ok()).expect("right");
        let child = if left != none { left as usize } else { right as usize };
        let root_key = arr_mut(&mut ebst_nodes[root])[0].clone();
        let child_key = arr_mut(&mut ebst_nodes[child])[0].clone();
        arr_mut(&mut ebst_nodes[root])[0] = child_key;
        arr_mut(&mut ebst_nodes[child])[0] = root_key;
    }
    assert_rule(&doc, invariants::EBST_KEY_ORDER, "swapped E-BST keys");
    assert_load_rejects(&doc, "ebst-keys");
}

// -- delta chains ----------------------------------------------------------

#[test]
fn delta_chain_corruptions_are_flagged() {
    let mut rng = Rng::new(0xDE17A);
    let mut model = trained("QO_s2", "tree", &mut rng, 1200);
    let base = model.to_checkpoint().expect("encode base");

    let mut deltas = Vec::new();
    let mut prev = base.clone();
    for v in 0..3u64 {
        for _ in 0..200 {
            let (x, y) = draw_instance(&mut rng);
            model.learn_one(&x, y);
        }
        let next = model.to_checkpoint().expect("encode step");
        let mut wire = Json::obj();
        wire.set("from", ju64(v))
            .set("to", ju64(v + 1))
            .set("hash", ju64(delta::doc_hash(&next)))
            .set("ops", delta::diff(&prev, &next));
        deltas.push(wire);
        prev = next;
    }

    let findings = invariants::verify_delta_chain(&base, &deltas);
    assert!(findings.is_empty(), "false positives on a clean chain: {findings:?}");

    // advertised hash does not match the applied document
    let mut broken = deltas.clone();
    broken[1].set("hash", ju64(0xDEAD_BEEF));
    let findings = invariants::verify_delta_chain(&base, &broken);
    assert!(
        findings.iter().any(|f| f.rule == invariants::DELTA_HASH_CHAIN),
        "expected DELTA_HASH_CHAIN, got {findings:?}"
    );

    // a version gap (the middle delta went missing)
    let gapped = vec![deltas[0].clone(), deltas[2].clone()];
    let findings = invariants::verify_delta_chain(&base, &gapped);
    assert!(
        findings.iter().any(|f| f.rule == invariants::DELTA_VERSION_ORDER),
        "expected DELTA_VERSION_ORDER, got {findings:?}"
    );

    // a delta claiming to jump two versions at once
    let mut skipping = deltas.clone();
    skipping[2].set("to", ju64(9));
    let findings = invariants::verify_delta_chain(&base, &skipping);
    assert!(
        findings.iter().any(|f| f.rule == invariants::DELTA_VERSION_ORDER),
        "expected DELTA_VERSION_ORDER, got {findings:?}"
    );
}
