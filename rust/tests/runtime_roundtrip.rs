//! Integration: load the AOT artifacts (built by `make artifacts`) on the
//! PJRT CPU client and verify the XLA results match the native rust math
//! and the observers themselves.
//!
//! These tests need two optional pieces of environment: the compiled
//! artifacts (`artifacts/manifest.txt`, from `make artifacts`) and a real
//! PJRT runtime (the offline `xla` stub reports it as unavailable). When
//! either is missing the tests SKIP with a message instead of failing —
//! tier-1 must stay green on runtime-less containers.

use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, QuantizationObserver};
use qostream::runtime::split_engine::native_best_split;
use qostream::runtime::{find_artifacts_dir, Manifest, SlotTable, XlaQuantizeEngine, XlaSplitEngine};

/// The PJRT client plus parsed manifest, or `None` (with a note on stderr)
/// when the environment cannot run the XLA path.
fn runtime() -> Option<(xla::PjRtClient, Manifest)> {
    let dir = match find_artifacts_dir() {
        Ok(dir) => dir,
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            return None;
        }
    };
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            return None;
        }
    };
    match xla::PjRtClient::cpu() {
        Ok(c) => Some((c, manifest)),
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}

fn random_qo(seed: u64, n: usize, radius: f64) -> QuantizationObserver {
    let mut rng = Rng::new(seed);
    let mut qo = QuantizationObserver::with_radius(radius);
    for _ in 0..n {
        let x = rng.normal(0.0, 1.0);
        let y = 2.0 * x.powi(3) - x + rng.normal(0.0, 0.1);
        qo.observe(x, y, 1.0);
    }
    qo
}

#[test]
fn split_engine_matches_native_math() {
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaSplitEngine::load(&c, &manifest).expect("load split_eval");
    assert_eq!(engine.f, 8);
    assert_eq!(engine.s, 256);

    let tables: Vec<SlotTable> =
        (0..8).map(|i| SlotTable::from_qo(&random_qo(100 + i, 3000, 0.05))).collect();
    let results = engine.best_splits(&tables).expect("execute");
    assert_eq!(results.len(), 8);
    for (table, res) in tables.iter().zip(&results) {
        let native = native_best_split(table).expect("native split");
        let xla_res = res.expect("xla split");
        assert_eq!(xla_res.best_idx, native.best_idx, "argmax must agree");
        assert!(
            (xla_res.merit - native.merit).abs() <= 1e-9 * native.merit.abs().max(1.0),
            "merit {} vs {}",
            xla_res.merit,
            native.merit
        );
        assert!((xla_res.threshold - native.threshold).abs() < 1e-9);
    }
}

#[test]
fn split_engine_matches_observer_query() {
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaSplitEngine::load(&c, &manifest).expect("load split_eval");
    let qo = random_qo(7, 5000, 0.05);
    let res = engine
        .best_splits_for_observers(&[&qo])
        .expect("execute")[0]
        .expect("split found");
    let native = qo.best_split(&VarianceReduction).expect("native split");
    assert!(
        (res.threshold - native.threshold).abs() < 1e-9,
        "{} vs {}",
        res.threshold,
        native.threshold
    );
    assert!((res.merit - native.merit).abs() <= 1e-9 * native.merit.abs().max(1.0));
}

#[test]
fn split_engine_handles_more_features_than_f() {
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaSplitEngine::load(&c, &manifest).expect("load split_eval");
    // 19 tables -> 3 chunks of 8
    let tables: Vec<SlotTable> =
        (0..19).map(|i| SlotTable::from_qo(&random_qo(200 + i, 800, 0.1))).collect();
    let results = engine.best_splits(&tables).expect("execute");
    assert_eq!(results.len(), 19);
    for (table, res) in tables.iter().zip(&results) {
        let native = native_best_split(table).unwrap();
        assert_eq!(res.unwrap().best_idx, native.best_idx);
    }
}

#[test]
fn split_engine_skips_degenerate_tables() {
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaSplitEngine::load(&c, &manifest).expect("load split_eval");
    let empty = SlotTable::default();
    let single = SlotTable {
        n: vec![5.0],
        sum_x: vec![1.0],
        mean: vec![2.0],
        m2: vec![0.3],
    };
    let good = SlotTable::from_qo(&random_qo(3, 500, 0.1));
    let results = engine.best_splits(&[empty, single, good]).expect("execute");
    assert!(results[0].is_none());
    assert!(results[1].is_none());
    assert!(results[2].is_some());
}

#[test]
fn quantize_engine_matches_streaming_observer() {
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaQuantizeEngine::load(&c, &manifest).expect("load quantize");
    assert_eq!(engine.b, 1024);

    let mut rng = Rng::new(42);
    let n = 3000; // forces multiple batches incl. a partial one
    let xs: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 1.5)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x * x + 0.5).collect();
    let radius = 0.1;

    let bulk = engine.build_observer(&xs, &ys, radius).expect("bulk ingest");
    let mut streaming = QuantizationObserver::with_radius(radius);
    for (&x, &y) in xs.iter().zip(&ys) {
        streaming.observe(x, y, 1.0);
    }

    assert_eq!(bulk.n_elements(), streaming.n_elements(), "slot counts");
    assert!((bulk.total().n - streaming.total().n).abs() < 1e-6);
    assert!(
        (bulk.total().mean - streaming.total().mean).abs() < 1e-9,
        "{} vs {}",
        bulk.total().mean,
        streaming.total().mean
    );
    assert!(
        (bulk.total().m2 - streaming.total().m2).abs() / streaming.total().m2 < 1e-9,
        "m2 {} vs {}",
        bulk.total().m2,
        streaming.total().m2
    );
    let sb = bulk.best_split(&VarianceReduction).unwrap();
    let ss = streaming.best_split(&VarianceReduction).unwrap();
    assert!((sb.threshold - ss.threshold).abs() < 1e-9);
    assert!((sb.merit - ss.merit).abs() <= 1e-9 * ss.merit.abs().max(1.0));
}

#[test]
fn quantize_engine_wide_range_overflow_path() {
    // a sample whose code range exceeds S=256 in one batch exercises the
    // overflow/re-ingest loop
    let Some((c, manifest)) = runtime() else { return };
    let engine = XlaQuantizeEngine::load(&c, &manifest).expect("load quantize");
    let mut rng = Rng::new(77);
    let xs: Vec<f64> = (0..2000).map(|_| rng.uniform(-50.0, 50.0)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.signum()).collect();
    let radius = 0.1; // 1000 possible codes >> 256
    let bulk = engine.build_observer(&xs, &ys, radius).expect("bulk ingest");
    let mut streaming = QuantizationObserver::with_radius(radius);
    for (&x, &y) in xs.iter().zip(&ys) {
        streaming.observe(x, y, 1.0);
    }
    assert_eq!(bulk.n_elements(), streaming.n_elements());
    assert!((bulk.total().n - streaming.total().n).abs() < 1e-6);
}
