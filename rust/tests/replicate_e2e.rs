//! End-to-end replication tests over real sockets (the CI-pinned step):
//! a leader trains and publishes versioned delta checkpoints, followers
//! poll/apply them, and the acceptance contract holds — **bit-identical
//! predictions to the leader at every applied version**, gap detection →
//! full resync, follower kill/restart → clean re-bootstrap, a sharded
//! leader replicating exactly like a sequential one, and a poisoned
//! leader payload rejected with the broken invariant's rule id named in
//! `last_resync_cause` (docs/INVARIANTS.md).

use std::time::{Duration, Instant};

use qostream::common::json::Json;
use qostream::eval::Regressor;
use qostream::forest::{ArfOptions, ArfRegressor};
use qostream::observer::{factory, QuantizationObserver, RadiusPolicy};
use qostream::persist::Model;
use qostream::serve::{Follower, FollowerOptions, ServeClient, ServeOptions, Server};
use qostream::stream::{Friedman1, Stream};

fn qo_factory() -> Box<dyn qostream::observer::ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

fn arf(members: usize, seed: u64) -> ArfRegressor {
    ArfRegressor::new(
        10,
        ArfOptions { n_members: members, lambda: 3.0, seed, ..Default::default() },
        qo_factory(),
    )
}

fn probes(n: usize) -> Vec<Vec<f64>> {
    let mut held_out = Friedman1::new(0xFACE, 0.0);
    (0..n).map(|_| held_out.next_instance().unwrap().x).collect()
}

/// Block until the follower reaches `version` (bounded).
fn wait_version(follower: &Follower, version: u64) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while follower.version() < version {
        assert!(
            Instant::now() < deadline,
            "follower stuck at v{} waiting for v{version}",
            follower.version()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn follower_stat(client: &mut ServeClient, key: &str) -> f64 {
    client
        .stats()
        .expect("stats")
        .get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("stats missing {key:?}"))
}

/// The acceptance contract: with auto-publication off, every explicit
/// snapshot is one version; the follower must pass through each one and
/// answer **bit-identically to the leader at that version**.
#[test]
fn follower_bit_identical_at_every_version() {
    let server = Server::start(
        Model::Arf(arf(3, 7)),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let follower = Follower::start(
        &server.addr().to_string(),
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("follower");
    assert_eq!(follower.version(), 0, "bootstrap must land on the initial version");

    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut follower_client = ServeClient::connect(follower.addr()).expect("replica client");
    let mut stream = Friedman1::new(11, 1.0);
    let batch = probes(40);

    let rounds = 5u64;
    for round in 1..=rounds {
        for _ in 0..150 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
        // snapshot rides the trainer FIFO: the published version reflects
        // every acked learn, and bumps the leader to version `round`
        client.snapshot().expect("snapshot");
        wait_version(&follower, round);

        let leader_preds = client.predict_batch(&batch).expect("leader batch");
        let follower_preds =
            follower_client.predict_batch(&batch).expect("follower batch");
        for (i, (a, b)) in leader_preds.iter().zip(&follower_preds).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "v{round} probe {i}: leader {a} vs follower {b}"
            );
        }
    }

    // a healthy steady run replicates purely by deltas
    let stats = follower_client.stats().expect("stats");
    assert_eq!(stats.get("role").and_then(Json::as_str), Some("follower"));
    assert_eq!(follower_stat(&mut follower_client, "deltas_applied") as u64, rounds);
    assert_eq!(follower_stat(&mut follower_client, "full_resyncs") as u64, 0);

    follower_client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// Gap detection: a follower that falls further behind than the leader's
/// delta ring must full-resync (and still converge bit-identically); a
/// killed follower re-bootstraps cleanly from the current head.
#[test]
fn gap_forces_full_resync_and_restart_rebootstraps() {
    let server = Server::start(
        Model::Arf(arf(2, 3)),
        "127.0.0.1:0",
        // tiny ring: 2 retained deltas
        ServeOptions { snapshot_every: 0, delta_history: 2, ..Default::default() },
    )
    .expect("leader");
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(21, 1.0);

    // a slow follower: its poll interval is far longer than the burst of
    // publications below (generous margin — debug-build checkpoints are
    // slow), so its first real poll finds it 4 versions behind a 2-deep
    // ring
    let slow = Follower::start(
        &addr,
        "127.0.0.1:0",
        FollowerOptions {
            poll_interval: Duration::from_secs(3),
            ..Default::default()
        },
    )
    .expect("slow follower");
    assert_eq!(slow.version(), 0);

    for _ in 0..4 {
        for _ in 0..80 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
        client.snapshot().expect("snapshot");
    }
    wait_version(&slow, 4);
    let mut slow_client = ServeClient::connect(slow.addr()).expect("slow client");
    assert!(
        follower_stat(&mut slow_client, "full_resyncs") >= 1.0,
        "a 4-behind follower over a 2-deep ring must have full-resynced"
    );
    let batch = probes(30);
    let leader_preds = client.predict_batch(&batch).expect("leader batch");
    let slow_preds = slow_client.predict_batch(&batch).expect("slow batch");
    for (a, b) in leader_preds.iter().zip(&slow_preds) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-resync divergence");
    }
    // kill the follower
    slow_client.shutdown().expect("slow shutdown");
    slow.join().expect("slow exit");

    // leader keeps going while no follower exists
    for _ in 0..80 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn");
    }
    client.snapshot().expect("snapshot");

    // a fresh follower bootstraps straight to the current head
    let reborn = Follower::start(
        &addr,
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("reborn follower");
    assert_eq!(reborn.version(), 5, "bootstrap must land on the leader's head");
    let mut reborn_client = ServeClient::connect(reborn.addr()).expect("reborn client");
    let leader_preds = client.predict_batch(&batch).expect("leader batch");
    let reborn_preds = reborn_client.predict_batch(&batch).expect("reborn batch");
    for (a, b) in leader_preds.iter().zip(&reborn_preds) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-restart divergence");
    }
    // and from there it follows deltas again
    for _ in 0..80 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn");
    }
    client.snapshot().expect("snapshot");
    wait_version(&reborn, 6);
    assert!(follower_stat(&mut reborn_client, "deltas_applied") >= 1.0);

    reborn_client.shutdown().expect("reborn shutdown");
    reborn.join().expect("reborn exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// One endpoint fronting a sharded fleet: a leader training with
/// `shards > 1` must stay bit-identical to the sequential ensemble, and
/// its followers replicate that state exactly.
#[test]
fn sharded_leader_is_bit_identical_and_replicates() {
    let n = 600usize;
    // in-process sequential reference, same seeds, same stream
    let mut reference = arf(4, 9);
    let mut stream = Friedman1::new(13, 1.0);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        reference.learn_one(&inst.x, inst.y);
    }

    let server = Server::start(
        Model::Arf(arf(4, 9)),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, shards: 2, shard_batch: 64, ..Default::default() },
    )
    .expect("sharded leader");
    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(13, 1.0);
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn");
    }
    client.snapshot().expect("snapshot");

    let follower = Follower::start(
        &server.addr().to_string(),
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("follower");
    wait_version(&follower, 1);
    let mut follower_client = ServeClient::connect(follower.addr()).expect("replica");

    let batch = probes(40);
    let leader_preds = client.predict_batch(&batch).expect("leader batch");
    let follower_preds = follower_client.predict_batch(&batch).expect("follower batch");
    for ((x, served), replicated) in batch.iter().zip(&leader_preds).zip(&follower_preds)
    {
        let sequential = reference.predict(x);
        assert_eq!(
            served.to_bits(),
            sequential.to_bits(),
            "sharded serve diverged from sequential at {x:?}"
        );
        assert_eq!(
            replicated.to_bits(),
            sequential.to_bits(),
            "replica diverged from sequential at {x:?}"
        );
    }

    // stats surface the sharding config
    let stats = client.stats().expect("stats");
    assert_eq!(stats.get("shards").and_then(Json::as_f64), Some(2.0));

    follower_client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// Binary wire negotiation (`docs/FORMATS.md`): a `format:"binary"` poll
/// is answered with base64 envelopes (`full_b64` / per-delta `ops_b64`)
/// that decode to the **same bytes** as the inline-JSON answer to a
/// plain poll, and a binary-preferring follower and a JSON-fallback
/// follower track the same leader bit-identically version by version.
#[test]
fn binary_and_json_followers_replicate_bit_identically() {
    use qostream::common::b64;
    use qostream::persist::binary;

    let server = Server::start(
        Model::Arf(arf(2, 9)),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut probe = ServeClient::connect(server.addr()).expect("probe client");

    // --- wire shape, straight through the client API ---
    // bootstrap: binary answers `full_b64`, plain answers inline `full`,
    // and the envelope decodes to the identical canonical document
    let bin_boot = probe.repl_sync_format(None, true).expect("binary bootstrap");
    assert_eq!(bin_boot.get("format").and_then(Json::as_str), Some("binary"));
    assert!(bin_boot.get("full").is_none(), "binary answer must not inline JSON: {bin_boot:?}");
    let envelope = bin_boot
        .get("full_b64")
        .and_then(Json::as_str)
        .expect("binary bootstrap carries full_b64");
    let decoded = binary::decode_doc(&b64::decode(envelope).expect("valid base64"))
        .expect("envelope decodes");
    let json_boot = probe.repl_sync(None).expect("json bootstrap");
    assert!(json_boot.get("full_b64").is_none(), "plain poll must fall back to inline JSON");
    let inline = json_boot.get("full").expect("json bootstrap carries full");
    assert_eq!(
        decoded.to_compact(),
        inline.to_compact(),
        "both formats must carry the same canonical document"
    );
    assert_eq!(
        bin_boot.get("hash").and_then(Json::as_str),
        json_boot.get("hash").and_then(Json::as_str),
        "advertised hash is format-agnostic"
    );

    // --- end to end: one follower per format against the same leader ---
    let binary_follower = Follower::start(
        &addr,
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("binary follower");
    let json_follower = Follower::start(
        &addr,
        "127.0.0.1:0",
        FollowerOptions {
            poll_interval: Duration::from_millis(3),
            prefer_binary: false,
            ..Default::default()
        },
    )
    .expect("json follower");
    let mut binary_client = ServeClient::connect(binary_follower.addr()).expect("binary replica");
    let mut json_client = ServeClient::connect(json_follower.addr()).expect("json replica");

    let mut stream = Friedman1::new(17, 1.0);
    let batch = probes(40);
    let rounds = 4u64;
    for round in 1..=rounds {
        for _ in 0..120 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
        client.snapshot().expect("snapshot");

        // delta shape at this version: binary polls get `ops_b64`, plain
        // polls get inline `ops`, both decoding to the same operations
        let bin_sync = probe.repl_sync_format(Some(round - 1), true).expect("binary sync");
        let json_sync = probe.repl_sync(Some(round - 1)).expect("json sync");
        let bin_delta = bin_sync
            .get("deltas")
            .and_then(Json::as_arr)
            .and_then(|d| d.first())
            .expect("binary sync carries deltas");
        let json_delta = json_sync
            .get("deltas")
            .and_then(Json::as_arr)
            .and_then(|d| d.first())
            .expect("json sync carries deltas");
        assert!(bin_delta.get("ops").is_none(), "{bin_delta:?}");
        let ops_envelope = bin_delta
            .get("ops_b64")
            .and_then(Json::as_str)
            .expect("binary delta carries ops_b64");
        let ops = binary::decode_doc(&b64::decode(ops_envelope).expect("valid base64"))
            .expect("ops envelope decodes");
        assert_eq!(
            ops.to_compact(),
            json_delta.get("ops").expect("inline ops").to_compact(),
            "v{round}: delta operations must be format-agnostic"
        );

        wait_version(&binary_follower, round);
        wait_version(&json_follower, round);
        let leader_preds = client.predict_batch(&batch).expect("leader batch");
        let bin_preds = binary_client.predict_batch(&batch).expect("binary batch");
        let json_preds = json_client.predict_batch(&batch).expect("json batch");
        for (i, ((a, b), c)) in
            leader_preds.iter().zip(&bin_preds).zip(&json_preds).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "v{round} probe {i}: binary follower");
            assert_eq!(a.to_bits(), c.to_bits(), "v{round} probe {i}: json follower");
        }
    }

    // both replicas rode the delta path the whole way — the formats
    // differ on the wire, never in behavior
    for replica in [&mut binary_client, &mut json_client] {
        assert_eq!(follower_stat(replica, "deltas_applied") as u64, rounds);
        assert_eq!(follower_stat(replica, "full_resyncs") as u64, 0);
    }

    binary_client.shutdown().expect("binary shutdown");
    binary_follower.join().expect("binary exit");
    json_client.shutdown().expect("json shutdown");
    json_follower.join().expect("json exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// Followers are strictly read replicas: learns are rejected with an
/// error envelope, reads keep working, and the connection stays usable.
#[test]
fn follower_rejects_learns_but_serves_reads() {
    let server = Server::start(
        Model::Arf(arf(2, 1)),
        "127.0.0.1:0",
        ServeOptions::default(),
    )
    .expect("leader");
    let follower = Follower::start(
        &server.addr().to_string(),
        "127.0.0.1:0",
        FollowerOptions::default(),
    )
    .expect("follower");
    let mut client = ServeClient::connect(follower.addr()).expect("replica client");

    let response = client
        .raw_line("{\"cmd\":\"learn\",\"x\":[0,0,0,0,0,0,0,0,0,0],\"y\":1.0}")
        .expect("response");
    assert!(response.contains("\"ok\":false"), "{response}");
    assert!(response.contains("read-only"), "{response}");

    let p = client.predict(&[0.5; 10]).expect("predict still works");
    assert!(p.is_finite());
    let snapshot = client.raw_line("{\"cmd\":\"snapshot\"}").expect("snapshot");
    assert!(snapshot.contains("qostream-checkpoint"), "follower snapshot");

    client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
    let mut leader_client = ServeClient::connect(server.addr()).expect("leader client");
    leader_client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// Explainable divergence: a leader that serves a corrupted document —
/// with a *matching* hash, so only the decode/audit layer can object —
/// must not take the replica down. The replica rejects the payload,
/// names the broken invariant's rule id in `last_resync_cause`, and
/// recovers through the normal full-resync path.
#[test]
fn corrupted_leader_payload_is_rejected_and_explained() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use qostream::common::Rng;
    use qostream::persist::codec::{ju64, jusize};
    use qostream::persist::delta;
    use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

    // a tree with at least one split (the audit_corruption stream:
    // 4 features, piecewise target)
    let mut rng = Rng::new(0xFADE);
    let mut model = Model::Tree(HoeffdingTreeRegressor::new(
        4,
        HtrOptions { grace_period: 100, ..Default::default() },
        qo_factory(),
    ));
    for _ in 0..2500 {
        let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let base = if x[0] <= 0.0 { 3.0 * x[1] } else { -2.0 + x[2] };
        model.learn_one(&x, base + rng.normal(0.0, 0.2));
    }
    let valid = model.to_checkpoint().expect("checkpoint");

    // point the first split's left child back at the root: breaks
    // ARENA_CHILD_ORDER while the document stays well-formed JSON
    let mut corrupt = valid.clone();
    {
        let Json::Obj(doc) = &mut corrupt else { panic!("checkpoint object") };
        let Some(Json::Obj(tree)) = doc.get_mut("model") else { panic!("model") };
        let Some(Json::Arr(nodes)) = tree.get_mut("nodes") else { panic!("nodes") };
        let split = nodes
            .iter_mut()
            .find_map(|n| match n {
                Json::Obj(node) => node.get_mut("split"),
                _ => None,
            })
            .expect("trained tree should hold a split");
        let Json::Obj(split) = split else { panic!("split object") };
        split.insert("left".to_string(), jusize(0));
    }
    let h_valid = delta::doc_hash(&valid);
    let h_corrupt = delta::doc_hash(&corrupt);

    // canned repl_sync responses of a minimal fake leader
    let line = |version: u64, hash: u64, body: Option<(&str, Json)>| {
        let mut o = Json::obj();
        o.set("ok", true).set("version", ju64(version)).set("hash", ju64(hash));
        match body {
            Some((key, value)) => o.set(key, value),
            None => o.set("up_to_date", true),
        };
        o.to_compact()
    };
    struct FakeLeader {
        boot: String,
        poison: String,
        recover: String,
        up_to_date: String,
        bootstrapped: AtomicBool,
        poisoned: AtomicBool,
    }
    let leader = Arc::new(FakeLeader {
        boot: line(0, h_valid, Some(("full", valid.clone()))),
        poison: line(1, h_corrupt, Some(("full", corrupt))),
        recover: line(2, h_valid, Some(("full", valid))),
        up_to_date: line(2, h_valid, None),
        bootstrapped: AtomicBool::new(false),
        poisoned: AtomicBool::new(false),
    });

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fake leader");
    let leader_addr = listener.local_addr().expect("leader addr").to_string();
    {
        let leader = leader.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { continue };
                let leader = leader.clone();
                std::thread::spawn(move || {
                    let Ok(read_half) = stream.try_clone() else { return };
                    let mut stream = stream;
                    for req in BufReader::new(read_half).lines() {
                        let Ok(req) = req else { return };
                        let Ok(request) = Json::parse(&req) else { return };
                        let cmd = request.get("cmd").and_then(Json::as_str);
                        let reply = if cmd != Some("repl_sync") {
                            "{\"ok\":false,\"error\":\"fake leader only replicates\"}"
                        } else if request.get("have").is_none() {
                            // bootstrap first, then every forced full
                            // resync lands on the clean head
                            if leader.bootstrapped.swap(true, Ordering::SeqCst) {
                                &leader.recover
                            } else {
                                &leader.boot
                            }
                        } else if !leader.poisoned.swap(true, Ordering::SeqCst) {
                            // the one poisoned publication: hash matches
                            // the corrupted text, decode/audit must catch it
                            &leader.poison
                        } else if request.get("have").and_then(Json::as_str) == Some("2")
                        {
                            &leader.up_to_date
                        } else {
                            &leader.recover
                        };
                        if stream.write_all(reply.as_bytes()).is_err()
                            || stream.write_all(b"\n").is_err()
                            || stream.flush().is_err()
                        {
                            return;
                        }
                    }
                });
            }
        });
    }

    let follower = Follower::start(
        &leader_addr,
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("follower bootstraps from the fake leader");
    assert_eq!(follower.version(), 0);

    // first poll serves the corrupted v1; the replica must reject it and
    // reach the clean v2 via the forced full resync
    wait_version(&follower, 2);

    let mut client = ServeClient::connect(follower.addr()).expect("replica client");
    let stats = client.stats().expect("stats");
    let cause = stats
        .get("last_resync_cause")
        .and_then(Json::as_str)
        .expect("stats must report last_resync_cause")
        .to_string();
    assert!(
        cause.contains("ARENA_CHILD_ORDER"),
        "divergence must name the broken invariant, got {cause:?}"
    );
    assert!(
        follower_stat(&mut client, "full_resyncs") >= 1.0,
        "rejecting the poisoned payload must force a full resync"
    );
    assert!(follower_stat(&mut client, "poll_errors") >= 1.0);
    // the replica served throughout and still answers from the clean head
    let p = client.predict(&[0.25; 4]).expect("predict");
    assert!(p.is_finite());

    client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
}

/// Observability on the replica: follower `stats` reports leader-head
/// staleness in learns and the last resync cause, and the `metrics` /
/// `trace_splits` commands round-trip over the follower's socket exactly
/// like the leader's.
#[test]
fn follower_metrics_trace_and_staleness_round_trip() {
    let server = Server::start(
        Model::Arf(arf(2, 13)),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let follower = Follower::start(
        &server.addr().to_string(),
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("follower");

    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(17, 1.0);
    for _ in 0..400 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn");
    }
    client.snapshot().expect("publish v1");
    wait_version(&follower, 1);

    let mut follower_client = ServeClient::connect(follower.addr()).expect("replica client");
    // staleness: the follower is at the head and no learns arrived after
    // the publish, so it trails the leader by exactly zero learns
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let lag = follower_stat(&mut follower_client, "staleness_learns");
        if lag == 0.0 {
            break;
        }
        assert!(Instant::now() < deadline, "staleness_learns stuck at {lag}");
        std::thread::sleep(Duration::from_millis(3));
    }
    let stats = follower_client.stats().expect("stats");
    let cause = stats
        .get("last_resync_cause")
        .and_then(Json::as_str)
        .expect("stats must report last_resync_cause");
    assert!(!cause.is_empty());
    assert!(
        follower_stat(&mut follower_client, "mem_bytes") > 0.0,
        "replica must report its model's resident bytes"
    );

    // the metrics/trace commands answer on the replica socket too
    let text = follower_client.metrics().expect("metrics");
    let families = text.lines().filter(|l| l.starts_with("# TYPE qostream_")).count();
    assert!(families >= 15, "expected >= 15 series, got {families}:\n{text}");
    for series in ["qostream_repl_lag_learns", "qostream_repl_deltas_applied_total"] {
        assert!(text.contains(series), "exposition missing {series}:\n{text}");
    }
    let trace = follower_client.trace_splits().expect("trace_splits");
    assert!(
        trace.get("capacity").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
        "{trace:?}"
    );
    assert!(trace.get("events").and_then(Json::as_arr).is_some(), "{trace:?}");

    follower_client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// Fleet-observability satellites on the replica: live freshness spans
/// land in the histogram and the `trace_repl` ring (newest first,
/// `limit` honored), the follower advertises itself into the leader's
/// `stats.followers`, structured `health` reads ok while the leader is
/// reachable — and flips to degraded with a named reason once the
/// leader dies and `poll_errors_consecutive` crosses the run threshold.
#[test]
fn follower_freshness_trace_repl_and_degraded_health() {
    use qostream::persist::codec::pu64;

    let server = Server::start(
        Model::Arf(arf(2, 19)),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let follower = Follower::start(
        &server.addr().to_string(),
        "127.0.0.1:0",
        FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
    )
    .expect("follower");

    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(23, 1.0);
    // 131 learns per round: a count no other test in this binary uses,
    // so this run's trace-ring events are identifiable by their learns
    // stamps (the obs registry and its rings are process-global, and
    // the harness runs tests concurrently)
    for round in 1..=3u64 {
        for _ in 0..131 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
        client.snapshot().expect("publish");
        wait_version(&follower, round);
    }

    // discovery: the follower advertised its serve address on its polls,
    // so the leader's stats lists it (the fleet aggregator's seed)
    let leader_stats = client.stats().expect("leader stats");
    let followers = leader_stats
        .get("followers")
        .and_then(Json::as_arr)
        .expect("leader stats must list followers");
    let follower_addr = follower.addr().to_string();
    assert!(
        followers.iter().any(|f| f.as_str() == Some(follower_addr.as_str())),
        "leader must know {follower_addr}: {leader_stats:?}"
    );

    let mut follower_client = ServeClient::connect(follower.addr()).expect("replica client");
    let text =
        |j: &Json, key: &str| j.get(key).and_then(Json::as_str).unwrap_or("?").to_string();
    let num = |j: &Json, key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
    // protocol u64s travel as ju64 decimal strings; pu64 decodes a value
    let u64_field =
        |j: &Json, key: &str| -> Option<u64> { j.get(key).and_then(|v| pu64(v, key).ok()) };

    // health while the leader is reachable: ok, no reasons
    let health = follower_client.health().expect("health");
    assert_eq!(text(&health, "status"), "ok", "{health:?}");
    assert_eq!(text(&health, "role"), "follower", "{health:?}");
    assert!(num(&health, "uptime_secs") >= 0.0, "{health:?}");
    assert!(num(&health, "poll_errors_consecutive") == 0.0, "{health:?}");
    assert_eq!(u64_field(&health, "snapshot_version"), Some(3), "{health:?}");
    assert!(
        health.get("reasons").and_then(Json::as_arr).expect("reasons").is_empty(),
        "{health:?}"
    );

    // the live freshness families render on the replica's exposition
    let metrics = follower_client.metrics().expect("metrics");
    for series in
        ["qostream_repl_freshness_seconds", "qostream_repl_freshness_seconds_window"]
    {
        assert!(metrics.contains(series), "exposition missing {series}:\n{metrics}");
    }

    // trace_repl: one event per applied delta, newest first, sane
    // spans. Concurrent tests record into the same process-global ring,
    // so pick this run's events out by their 131-multiple learns stamps.
    let trace = follower_client.trace_repl(None).expect("trace_repl");
    let events = trace.get("events").and_then(Json::as_arr).expect("events").to_vec();
    let mine: Vec<&Json> = events
        .iter()
        .filter(|e| {
            u64_field(e, "learns").is_some_and(|l| l > 0 && l <= 3 * 131 && l % 131 == 0)
        })
        .collect();
    assert_eq!(mine.len(), 3, "three applied versions: {trace:?}");
    let versions: Vec<u64> =
        mine.iter().map(|e| u64_field(e, "version").expect("version")).collect();
    assert_eq!(versions, vec![3, 2, 1], "events must be newest first");
    for (event, expected_learns) in mine.iter().zip([393u64, 262, 131]) {
        assert!(num(event, "span_ns") >= 0.0, "{event:?}");
        assert_eq!(u64_field(event, "learns"), Some(expected_learns), "{event:?}");
        assert_eq!(
            event.get("full").and_then(Json::as_bool),
            Some(false),
            "healthy deltas must not be full resyncs: {event:?}"
        );
    }
    // limit honored (equality with the full dump would race concurrent
    // tests appending to the shared ring, so assert shape only)
    let limited = follower_client.trace_repl(Some(1)).expect("trace_repl limit");
    let limited_events =
        limited.get("events").and_then(Json::as_arr).expect("events").to_vec();
    assert_eq!(limited_events.len(), 1, "{limited:?}");
    assert!(num(&limited, "total") >= 3.0, "{limited:?}");

    // kill the leader: consecutive poll failures must degrade health
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let health = follower_client.health().expect("health");
        if text(&health, "status") == "degraded" {
            assert!(num(&health, "poll_errors_consecutive") >= 3.0, "{health:?}");
            let reasons =
                health.get("reasons").and_then(Json::as_arr).expect("reasons").to_vec();
            assert!(
                reasons
                    .iter()
                    .any(|r| r.as_str().is_some_and(|s| s.contains("leader sync failing"))),
                "degradation must name its reason: {health:?}"
            );
            break;
        }
        assert!(Instant::now() < deadline, "health never degraded: {health:?}");
        std::thread::sleep(Duration::from_millis(5));
    }

    follower_client.shutdown().expect("follower shutdown");
    follower.join().expect("follower exit");
}
