//! Integration: every attribute observer against the exhaustive oracle
//! across the paper's Table 1 data settings.
//!
//! The paper's Sec. 6.1 finding is the contract checked here: E-BST is
//! exact (equal merit to the oracle), TE-BST is near-exact, and the QO
//! variants trade a small, radius-controlled amount of merit for their
//! memory/time advantage.

use qostream::criterion::VarianceReduction;
use qostream::observer::{paper_lineup, AttributeObserver, ExhaustiveObserver};
use qostream::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};
use qostream::stream::Stream;

/// Drive a single-feature synthetic sample through an observer.
fn observe_sample(
    ao: &mut dyn AttributeObserver,
    dist: Distribution,
    target: TargetFn,
    n: usize,
    seed: u64,
) {
    let mut stream = SyntheticRegression::new(
        dist,
        target,
        NoiseSpec::for_distribution(&dist, 0.1),
        1,
        seed,
    );
    for _ in 0..n {
        let inst = stream.next_instance().unwrap();
        ao.observe(inst.x[0], inst.y, 1.0);
    }
}

#[test]
fn ebst_merit_equals_oracle_everywhere() {
    for (di, dist) in Distribution::table1().into_iter().enumerate() {
        for target in [TargetFn::Linear, TargetFn::Cubic] {
            let mut ebst = paper_lineup()[0].build();
            let mut oracle = ExhaustiveObserver::new();
            let seed = 1000 + di as u64;
            observe_sample(ebst.as_mut(), dist, target, 2000, seed);
            observe_sample(&mut oracle, dist, target, 2000, seed);
            let sb = ebst.best_split(&VarianceReduction).unwrap();
            let so = oracle.best_split(&VarianceReduction).unwrap();
            assert!(
                (sb.merit - so.merit).abs() <= 1e-9 * so.merit.abs().max(1e-12),
                "{} {}: {} vs {}",
                dist.label(),
                target.label(),
                sb.merit,
                so.merit
            );
        }
    }
}

#[test]
fn merit_ordering_oracle_geq_qo() {
    // merit: oracle >= each QO variant, across the full Table 1 grid
    for (di, dist) in Distribution::table1().into_iter().enumerate() {
        for target in [TargetFn::Linear, TargetFn::Cubic] {
            let seed = 2000 + di as u64;
            let mut oracle = ExhaustiveObserver::new();
            observe_sample(&mut oracle, dist, target, 3000, seed);
            let mo = oracle.best_split(&VarianceReduction).unwrap().merit;
            for fac in paper_lineup().into_iter().skip(2) {
                let mut qo = fac.build();
                observe_sample(qo.as_mut(), dist, target, 3000, seed);
                let mq = qo.best_split(&VarianceReduction).map(|s| s.merit).unwrap_or(0.0);
                assert!(
                    mq <= mo + 1e-9 * mo.abs().max(1e-12),
                    "{} {} {}: qo {} > oracle {}",
                    fac.name(),
                    dist.label(),
                    target.label(),
                    mq,
                    mo
                );
            }
        }
    }
}

#[test]
fn qo_merit_within_band_of_oracle() {
    // Sec 6.1: "the actual obtained VR values were very similar" — check
    // QO_0.01-style small radii recover >= 90% of the oracle merit on the
    // unit-scale settings.
    let dist = Distribution::Normal { mu: 0.0, sigma: 1.0 };
    for target in [TargetFn::Linear, TargetFn::Cubic] {
        let mut oracle = ExhaustiveObserver::new();
        observe_sample(&mut oracle, dist, target, 5000, 42);
        let mo = oracle.best_split(&VarianceReduction).unwrap().merit;
        let mut qo = paper_lineup()[2].build(); // QO_0.01
        observe_sample(qo.as_mut(), dist, target, 5000, 42);
        let mq = qo.best_split(&VarianceReduction).unwrap().merit;
        assert!(mq >= 0.9 * mo, "{}: {} vs {}", target.label(), mq, mo);
    }
}

#[test]
fn element_counts_ordering_matches_paper_fig4() {
    // elements: QO_s2 <= QO_s3 <= QO_0.01 (unit-scale data) and every QO
    // <= TE-BST <= E-BST
    let dist = Distribution::Normal { mu: 0.0, sigma: 1.0 };
    let n = 20_000;
    let mut counts = std::collections::BTreeMap::new();
    for fac in paper_lineup() {
        let mut ao = fac.build();
        observe_sample(ao.as_mut(), dist, TargetFn::Linear, n, 77);
        counts.insert(fac.name(), ao.n_elements());
    }
    let c = |k: &str| counts[k];
    assert!(c("QO_s2") <= c("QO_s3"), "{counts:?}");
    assert!(c("QO_s3") <= c("QO_0.01"), "{counts:?}");
    assert!(c("QO_0.01") <= c("TE-BST"), "{counts:?}");
    assert!(c("TE-BST") <= c("E-BST"), "{counts:?}");
    // and the headline: QO uses orders of magnitude fewer elements
    assert!(c("QO_s2") * 100 < c("E-BST"), "{counts:?}");
}

#[test]
fn split_points_converge_to_oracle_with_radius() {
    // Fig 3: smaller radius -> split point closer to the E-BST/oracle one
    let dist = Distribution::Uniform { lo: -1.0, hi: 1.0 };
    let mut oracle = ExhaustiveObserver::new();
    let seed = 55;
    let n = 10_000;
    observe_sample(&mut oracle, dist, TargetFn::Cubic, n, seed);
    let t_oracle = oracle.best_split(&VarianceReduction).unwrap().threshold;

    let mut diffs = Vec::new();
    for radius in [0.5, 0.1, 0.01] {
        let mut qo = qostream::observer::QuantizationObserver::with_radius(radius);
        observe_sample(&mut qo, dist, TargetFn::Cubic, n, seed);
        let t = qo.best_split(&VarianceReduction).unwrap().threshold;
        diffs.push((t - t_oracle).abs());
    }
    assert!(
        diffs[2] <= diffs[0] + 1e-9,
        "radius 0.01 diff {} should not exceed radius 0.5 diff {}",
        diffs[2],
        diffs[0]
    );
    assert!(diffs[2] < 0.05, "small-radius split should be near oracle: {diffs:?}");
}

#[test]
fn noise_does_not_break_any_observer() {
    let dist = Distribution::Bimodal { mu1: -7.0, sigma1: 7.0, mu2: 7.0, sigma2: 0.1 };
    for fac in paper_lineup() {
        let mut ao = fac.build();
        observe_sample(ao.as_mut(), dist, TargetFn::Cubic, 5000, 91);
        let s = ao.best_split(&VarianceReduction);
        assert!(s.is_some(), "{} returned no split", fac.name());
        let s = s.unwrap();
        assert!(s.merit.is_finite() && s.threshold.is_finite(), "{}", fac.name());
        assert!(s.left.n > 0.0 && s.right.n > 0.0, "{}", fac.name());
    }
}
