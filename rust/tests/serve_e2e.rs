//! End-to-end tests driving a real TCP serving session: learn / predict /
//! snapshot / stats / shutdown over the NDJSON protocol, plus the
//! acceptance contract — a client trains a forest through `serve`, takes
//! a checkpoint, a fresh server restores it, and both servers return
//! **bit-identical** predictions on a held-out batch.

use qostream::forest::{ArfOptions, ArfRegressor};
use qostream::observer::{factory, QuantizationObserver, RadiusPolicy};
use qostream::persist::Model;
use qostream::serve::{ServeClient, ServeOptions, Server};
use qostream::stream::{Friedman1, Stream};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

fn qo_factory() -> Box<dyn qostream::observer::ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

fn tree_model() -> Model {
    Model::Tree(HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory()))
}

fn arf_model(members: usize, seed: u64) -> Model {
    Model::Arf(ArfRegressor::new(
        10,
        ArfOptions { n_members: members, lambda: 3.0, seed, ..Default::default() },
        qo_factory(),
    ))
}

/// CI smoke test (satellite contract): ephemeral port, learn / predict /
/// snapshot / stats / shutdown, clean exit.
#[test]
fn smoke_learn_predict_snapshot_shutdown() {
    let server = Server::start(tree_model(), "127.0.0.1:0", ServeOptions::default())
        .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    let mut stream = Friedman1::new(1, 1.0);
    for _ in 0..100 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn ack");
    }
    let p = client.predict(&[0.5; 10]).expect("predict");
    assert!(p.is_finite());
    let checkpoint = client.snapshot().expect("snapshot");
    assert!(checkpoint.contains("qostream-checkpoint"));
    let stats = client.stats().expect("stats");
    assert_eq!(
        stats.get("kind").and_then(qostream::common::json::Json::as_str),
        Some("tree")
    );
    assert!(
        stats
            .get("learns_enqueued")
            .and_then(qostream::common::json::Json::as_f64)
            .unwrap_or(0.0)
            >= 100.0
    );
    // staleness reporting (ops/follower contract): the explicit snapshot
    // just published, so the version is known and the age is zero
    assert_eq!(
        stats.get("role").and_then(qostream::common::json::Json::as_str),
        Some("leader")
    );
    let version: u64 = stats
        .get("snapshot_version")
        .and_then(qostream::common::json::Json::as_str)
        .expect("stats must report snapshot_version")
        .parse()
        .expect("version is a decimal string");
    assert!(version >= 1, "explicit snapshot must have bumped the version");
    assert_eq!(
        stats
            .get("snapshot_age_learns")
            .and_then(qostream::common::json::Json::as_f64),
        Some(0.0),
        "age must reset right after a snapshot"
    );
    client.shutdown().expect("shutdown ack");
    let final_model = server.join().expect("clean exit");
    assert_eq!(final_model.kind(), "tree");
}

/// Regression: an explicit `snapshot` that lands when the trainer has
/// nothing dirty (`learns_since_sync == 0` — e.g. right after the
/// `snapshot_every` boundary auto-published) must still refresh the
/// publication bookkeeping: the `snapshots` counter bumps and
/// `snapshot_age_learns` reports zero, instead of the request being
/// swallowed by the clean fast path.
#[test]
fn zero_dirty_snapshot_still_refreshes_bookkeeping() {
    let server = Server::start(
        tree_model(),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 100, ..Default::default() },
    )
    .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    let mut stream = Friedman1::new(33, 1.0);
    for _ in 0..100 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn ack");
    }
    let stat = |stats: &qostream::common::json::Json, key: &str| -> f64 {
        stats.get(key).and_then(qostream::common::json::Json::as_f64).unwrap_or(-1.0)
    };
    // the 100th applied learn crosses the snapshot_every boundary, so the
    // trainer auto-publishes and the model goes clean; wait for that state
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let auto_published = loop {
        let stats = client.stats().expect("stats");
        if stat(&stats, "learns_applied") >= 100.0 && stat(&stats, "snapshots") >= 1.0 {
            break stat(&stats, "snapshots");
        }
        assert!(
            std::time::Instant::now() < deadline,
            "auto-publish never happened: {stats:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    };

    // explicit snapshot on the clean model: the checkpoint still comes
    // back, the publication counter still bumps, the age stays zero
    let checkpoint = client.snapshot().expect("zero-dirty snapshot");
    assert!(checkpoint.contains("qostream-checkpoint"));
    let stats = client.stats().expect("stats");
    assert!(
        stat(&stats, "snapshots") > auto_published,
        "zero-dirty snapshot must still count as a publication: {stats:?}"
    );
    assert_eq!(
        stat(&stats, "snapshot_age_learns"),
        0.0,
        "zero-dirty snapshot must pin the age at zero: {stats:?}"
    );
    // nothing changed, so a second snapshot returns the identical document
    assert_eq!(client.snapshot().expect("second snapshot"), checkpoint);
    client.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}

/// The acceptance contract: train a forest over TCP, checkpoint it,
/// restore into a fresh server, and compare held-out predictions
/// bit-for-bit across both servers.
#[test]
fn restored_server_is_bit_identical_on_held_out_batch() {
    let server_a = Server::start(
        arf_model(3, 7),
        "127.0.0.1:0",
        // small swap interval: hot-swapping stays exercised during training
        ServeOptions { snapshot_every: 200, ..Default::default() },
    )
    .expect("server A");
    let mut client_a = ServeClient::connect(server_a.addr()).expect("connect A");

    let mut stream = Friedman1::new(11, 1.0);
    for _ in 0..1500 {
        let inst = stream.next_instance().unwrap();
        client_a.learn(&inst.x, inst.y).expect("learn");
    }
    // snapshot: trainer-FIFO guarantees all 1500 learns are in; also
    // publishes, so A's reads now serve exactly the checkpointed state
    let checkpoint = client_a.snapshot().expect("checkpoint");

    let restored = Model::from_text(&checkpoint).expect("restore checkpoint");
    assert_eq!(restored.kind(), "arf");
    let server_b =
        Server::start(restored, "127.0.0.1:0", ServeOptions::default()).expect("server B");
    let mut client_b = ServeClient::connect(server_b.addr()).expect("connect B");

    // held-out batch, never trained on
    let mut held_out = Friedman1::new(0xDEAD, 0.0);
    let batch: Vec<Vec<f64>> =
        (0..100).map(|_| held_out.next_instance().unwrap().x).collect();
    let preds_a = client_a.predict_batch(&batch).expect("batch A");
    let preds_b = client_b.predict_batch(&batch).expect("batch B");
    assert_eq!(preds_a.len(), 100);
    for (i, (a, b)) in preds_a.iter().zip(&preds_b).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "prediction {i} diverged: {a} (live) vs {b} (restored)"
        );
    }
    // single predicts agree with the batch (same snapshot both ways)
    let single_a = client_a.predict(&batch[0]).expect("single A");
    assert_eq!(single_a.to_bits(), preds_a[0].to_bits());

    client_a.shutdown().expect("shutdown A");
    client_b.shutdown().expect("shutdown B");
    server_a.join().expect("A clean exit");
    server_b.join().expect("B clean exit");
}

/// Reads must keep flowing while a concurrent connection trains, and the
/// published snapshot must trail by at most the swap interval.
#[test]
fn concurrent_reads_during_training() {
    let server = Server::start(
        arf_model(2, 3),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 50, ..Default::default() },
    )
    .expect("server");
    let addr = server.addr();

    let writer = std::thread::spawn(move || {
        let mut client = ServeClient::connect(addr).expect("writer connect");
        let mut stream = Friedman1::new(21, 1.0);
        for _ in 0..800 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
    });

    let mut reader = ServeClient::connect(addr).expect("reader connect");
    let probe = [0.4; 10];
    for _ in 0..200 {
        let p = reader.predict(&probe).expect("predict during training");
        assert!(p.is_finite());
    }
    writer.join().expect("writer thread");

    // an explicit snapshot is a sync point: it drains the trainer FIFO,
    // so the counters below are deterministic
    reader.snapshot().expect("snapshot");
    let stats = reader.stats().expect("stats");
    let swaps = stats
        .get("snapshots")
        .and_then(qostream::common::json::Json::as_f64)
        .unwrap_or(0.0);
    assert!(swaps >= 1.0, "hot-swap never ran: {swaps}");
    reader.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}

/// Protocol robustness: malformed lines and bad requests produce error
/// responses, and the connection stays usable afterwards.
#[test]
fn malformed_requests_get_errors_not_disconnects() {
    let server =
        Server::start(tree_model(), "127.0.0.1:0", ServeOptions::default()).expect("server");
    let mut client = ServeClient::connect(server.addr()).expect("connect");

    for bad in [
        "this is not json",
        "{\"cmd\":\"warp\"}",
        "{\"no\":\"cmd\"}",
        "{\"cmd\":\"learn\",\"x\":[1,2],\"y\":0}",            // wrong arity
        "{\"cmd\":\"learn\",\"x\":[1,2,3,4,5,6,7,8,9,10]}",   // missing y
        "{\"cmd\":\"predict\",\"x\":\"nope\"}",
    ] {
        let response = client.raw_line(bad).expect("server must respond");
        assert!(
            response.contains("\"ok\":false"),
            "expected an error envelope for {bad:?}, got {response}"
        );
    }
    // the connection survived all of it
    let p = client.predict(&[0.0; 10]).expect("still usable");
    assert!(p.is_finite());
    client.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}

/// Byte-level robustness (below the JSON layer): non-UTF-8 bytes and an
/// unterminated oversized frame must not take the server down — the
/// offending connection is dropped (the cap answers with one error
/// envelope first) and a clean client keeps working afterwards.
#[test]
fn malformed_frames_drop_connection_but_not_server() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    let server =
        Server::start(tree_model(), "127.0.0.1:0", ServeOptions::default()).expect("server");
    let addr = server.addr();

    // non-UTF-8 input: the framed read fails server-side and the
    // connection is dropped without a response
    {
        let mut raw = TcpStream::connect(addr).expect("raw connect");
        raw.write_all(b"{\"cmd\":\xff\xfe\"predict\"}\n").expect("write bytes");
        raw.flush().expect("flush");
        let mut buf = Vec::new();
        let n = raw.read_to_end(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "non-UTF-8 frame must drop the connection, got {buf:?}");
    }

    // an unterminated 16 MiB line hits the per-request cap: one error
    // envelope, then the connection closes (network input must never
    // pick the server's allocation size)
    {
        let mut raw = TcpStream::connect(addr).expect("raw connect");
        let chunk = vec![b'a'; 1024 * 1024];
        for _ in 0..16 {
            raw.write_all(&chunk).expect("write chunk");
        }
        raw.flush().expect("flush");
        let mut reader = BufReader::new(raw);
        let mut line = String::new();
        reader.read_line(&mut line).expect("cap response");
        assert!(line.contains("request too large"), "{line:?}");
        line.clear();
        let n = reader.read_line(&mut line).expect("read after cap");
        assert_eq!(n, 0, "capped connection must close");
    }

    // the server survived both: a fresh client trains and reads normally
    let mut client = ServeClient::connect(addr).expect("clean connect");
    let mut stream = Friedman1::new(31, 1.0);
    for _ in 0..50 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn after bad frames");
    }
    let p = client.predict(&[0.5; 10]).expect("predict after bad frames");
    assert!(p.is_finite());
    client.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}

/// Observability over the wire: `metrics` returns a Prometheus text
/// exposition covering the tree/observer/backend/serve/replication
/// series, and `trace_splits` returns the split-attempt ring — both on a
/// live leader. Assertions on *values* stay loose: the obs registry is
/// process-global and other tests in this binary train concurrently.
#[test]
fn metrics_and_trace_splits_round_trip() {
    let server = Server::start(tree_model(), "127.0.0.1:0", ServeOptions::default())
        .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    // enough learns to clear the grace period several times over, so
    // split attempts (and therefore trace events) actually happen
    let mut stream = Friedman1::new(21, 1.0);
    for _ in 0..900 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn ack");
    }
    // snapshot drains the trainer FIFO, so every learn above is applied
    client.snapshot().expect("snapshot");

    let text = client.metrics().expect("metrics");
    let families: Vec<&str> = text
        .lines()
        .filter(|l| l.starts_with("# TYPE qostream_"))
        .collect();
    assert!(
        families.len() >= 15,
        "exposition must cover >= 15 series, got {}:\n{text}",
        families.len()
    );
    // one representative per instrumented layer
    for series in [
        "qostream_tree_learns_total",
        "qostream_tree_route_depth",
        "qostream_qo_inserts_total",
        "qostream_backend_batches_total",
        "qostream_forest_drifts_total",
        "qostream_serve_learn_ns",
        "qostream_model_mem_bytes",
        "qostream_repl_lag_versions",
        "qostream_tree_split_attempts_total",
        "qostream_snapshot_publish_seconds",
    ] {
        assert!(text.contains(series), "exposition missing {series}:\n{text}");
    }
    // the zero-copy publish instrumentation: both checkpoint-size series
    // render with their format label, and the snapshot above materialized
    // a full JSON document, so the json counter is live
    assert!(
        text.contains("qostream_snapshot_bytes{format=\"json\"}"),
        "exposition missing json snapshot bytes:\n{text}"
    );
    assert!(
        text.contains("qostream_snapshot_bytes{format=\"binary\"}"),
        "exposition missing binary snapshot bytes:\n{text}"
    );
    // this server trained 900 instances, so the global learn counter and
    // the memory gauge must both be live (other tests only add to them)
    let counter_value = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(name) && !l.starts_with("# "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0)
    };
    assert!(counter_value("qostream_tree_learns_total") >= 900.0, "{text}");
    assert!(counter_value("qostream_model_mem_bytes") > 0.0, "{text}");
    assert!(
        counter_value("qostream_snapshot_bytes{format=\"json\"}") > 0.0,
        "snapshot materialization must record the JSON document size:\n{text}"
    );

    let trace = client.trace_splits().expect("trace_splits");
    let json = |j: &qostream::common::json::Json, key: &str| -> f64 {
        j.get(key).and_then(qostream::common::json::Json::as_f64).unwrap_or(-1.0)
    };
    assert!(json(&trace, "capacity") > 0.0, "{trace:?}");
    assert!(json(&trace, "total") >= 1.0, "900 learns must attempt a split: {trace:?}");
    let events = trace
        .get("events")
        .and_then(qostream::common::json::Json::as_arr)
        .expect("events array");
    assert!(!events.is_empty(), "ring must hold recent attempts");
    for event in events {
        let outcome = event
            .get("outcome")
            .and_then(qostream::common::json::Json::as_str)
            .expect("event outcome");
        assert!(
            ["accepted", "tie_broken", "hoeffding_rejected", "no_merit", "branch_too_small"]
                .contains(&outcome),
            "unknown outcome {outcome}"
        );
        assert!(json(event, "elapsed_ns") >= 0.0);
        assert!(json(event, "slots_evaluated") >= 0.0);
    }
    client.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}

/// Fleet-observability satellites on the leader role, over a real
/// socket: structured `health` (ok verdict, role, uptime), `# HELP`
/// lines golden against the shared metric catalog, and `trace_splits`
/// honoring `limit` with newest-first ordering (the limited dump is an
/// exact prefix of the full newest-first dump).
#[test]
fn health_help_lines_and_trace_limit_round_trip() {
    use qostream::common::json::Json;

    let server = Server::start(tree_model(), "127.0.0.1:0", ServeOptions::default())
        .expect("server must start");
    let mut client = ServeClient::connect(server.addr()).expect("connect");
    let mut stream = Friedman1::new(33, 1.0);
    for _ in 0..900 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn ack");
    }
    client.snapshot().expect("snapshot");

    // health: a freshly trained leader reports ok, its role, and uptime
    let health = client.health().expect("health");
    let text =
        |j: &Json, key: &str| j.get(key).and_then(Json::as_str).unwrap_or("?").to_string();
    let num = |j: &Json, key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
    assert_eq!(text(&health, "status"), "ok", "{health:?}");
    assert_eq!(text(&health, "role"), "leader", "{health:?}");
    assert!(num(&health, "uptime_secs") >= 0.0, "{health:?}");
    assert!(num(&health, "mem_bytes") > 0.0, "{health:?}");
    assert!(num(&health, "snapshot_failures_consecutive") == 0.0, "{health:?}");
    let version = health
        .get("snapshot_version")
        .and_then(|v| qostream::persist::codec::pu64(v, "snapshot_version").ok())
        .expect("snapshot_version must be a ju64");
    assert!(version >= 1, "{health:?}");
    let reasons = health.get("reasons").and_then(Json::as_arr).expect("reasons array");
    assert!(reasons.is_empty(), "healthy leader must list no reasons: {health:?}");

    // every `# TYPE` family in the exposition carries a `# HELP` line
    // whose text comes verbatim from the shared obs::CATALOG table
    let metrics = client.metrics().expect("metrics");
    let mut families = 0;
    for line in metrics.lines().filter(|l| l.starts_with("# TYPE ")) {
        let name = line.split_whitespace().nth(2).expect("family name on TYPE line");
        let desc = qostream::obs::describe(name)
            .unwrap_or_else(|| panic!("{name} rendered but missing from obs::CATALOG"));
        let golden = format!("# HELP {} {}", desc.name, desc.help);
        assert!(
            metrics.lines().any(|l| l == golden),
            "exposition HELP for {name} must match the catalog: {golden:?}"
        );
        families += 1;
    }
    assert!(families >= 15, "exposition must cover >= 15 families, got {families}");

    // trace_splits limit: the dump shrinks to the requested count while
    // `total` keeps reporting lifetime attempts. (Newest-first ordering
    // is asserted against identifiable version stamps in
    // replicate_e2e's trace_repl test — the split ring is process-global
    // and concurrent tests append to it, so order is not stable here.)
    let full = client.trace_splits().expect("trace_splits");
    let limited = client.trace_splits_limit(Some(3)).expect("trace_splits limit");
    let events = |j: &Json| j.get("events").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
    assert!(events(&full).len() >= 3, "900 learns must log >= 3 attempts: {full:?}");
    assert_eq!(events(&limited).len(), 3, "{limited:?}");
    assert!(num(&limited, "total") >= 3.0, "total ignores the limit: {limited:?}");
    // a zero limit is honored, not treated as "unlimited"
    let none = client.trace_splits_limit(Some(0)).expect("trace_splits 0");
    assert!(events(&none).is_empty(), "{none:?}");

    client.shutdown().expect("shutdown");
    server.join().expect("clean exit");
}
