//! Integration: the Hoeffding tree with each paper observer on realistic
//! streams — accuracy, growth, memory and drift behaviour.

use qostream::eval::{prequential, MeanRegressor, Regressor};
use qostream::observer::paper_lineup;
use qostream::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};
use qostream::stream::{AbruptDrift, Friedman1, Stream};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

#[test]
fn every_observer_learns_friedman() {
    let n = 20_000;
    let mut mean_rmse = {
        let mut mean = MeanRegressor::new();
        prequential(&mut mean, &mut Friedman1::new(7, 1.0), n, 0).metrics.rmse()
    };
    // guard against a silently broken baseline
    assert!(mean_rmse > 3.0);
    for fac in paper_lineup() {
        let name = fac.name();
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
        let report = prequential(&mut tree, &mut Friedman1::new(7, 1.0), n, 0);
        assert!(
            report.metrics.rmse() < 0.85 * mean_rmse,
            "{name}: rmse {} vs mean baseline {mean_rmse}",
            report.metrics.rmse()
        );
        assert!(tree.n_splits() >= 1, "{name}: tree never grew");
        mean_rmse = mean_rmse.max(report.metrics.rmse()); // keep borrowck quiet, no-op
    }
}

#[test]
fn qo_tree_memory_is_a_fraction_of_ebst_tree() {
    let n = 30_000;
    let run = |idx: usize| -> (f64, usize) {
        let fac = paper_lineup().remove(idx);
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
        let report = prequential(&mut tree, &mut Friedman1::new(11, 1.0), n, 0);
        (report.metrics.rmse(), tree.total_elements())
    };
    let (rmse_ebst, elems_ebst) = run(0); // E-BST
    let (rmse_qo, elems_qo) = run(3); // QO_s2
    // Note: inside a tree the dynamic-radius QO also counts its per-leaf
    // warmup buffers (fresh leaves haven't frozen their radius yet), so
    // the in-tree gap is smaller than the AO-level orders-of-magnitude gap
    // checked in observers_vs_oracle.rs.
    assert!(
        elems_qo * 3 < elems_ebst,
        "QO tree should store <1/3 of E-BST tree elements: {elems_qo} vs {elems_ebst}"
    );
    // accuracy must remain comparable (within 25%)
    assert!(
        rmse_qo < 1.25 * rmse_ebst,
        "QO tree rmse {rmse_qo} vs E-BST tree rmse {rmse_ebst}"
    );
}

#[test]
fn tree_handles_multifeature_table1_streams() {
    for dist in [
        Distribution::Normal { mu: 0.0, sigma: 7.0 },
        Distribution::Uniform { lo: -0.1, hi: 0.1 },
        Distribution::Bimodal { mu1: -1.0, sigma1: 1.0, mu2: 1.0, sigma2: 1.0 },
    ] {
        let fac = paper_lineup().remove(3); // QO_s2 (dynamic radius)
        let mut tree = HoeffdingTreeRegressor::new(3, HtrOptions::default(), fac);
        let mut stream = SyntheticRegression::new(
            dist,
            TargetFn::Cubic,
            NoiseSpec::for_distribution(&dist, 0.1),
            3,
            13,
        );
        let report = prequential(&mut tree, &mut stream, 15_000, 0);
        assert!(report.metrics.r2() > 0.3, "{}: r2={}", dist.label(), report.metrics.r2());
    }
}

#[test]
fn tree_keeps_learning_after_abrupt_drift() {
    let before = Box::new(SyntheticRegression::new(
        Distribution::Uniform { lo: -1.0, hi: 1.0 },
        TargetFn::Linear,
        NoiseSpec::NONE,
        2,
        17,
    ));
    let after = Box::new(SyntheticRegression::new(
        Distribution::Uniform { lo: -1.0, hi: 1.0 },
        TargetFn::Linear,
        NoiseSpec::NONE,
        2,
        999, // different coefficients: a genuine concept change
    ));
    let mut stream = AbruptDrift::new(before, after, 15_000);
    let fac = paper_lineup().remove(3);
    let mut tree = HoeffdingTreeRegressor::new(2, HtrOptions::default(), fac);
    let report = prequential(&mut tree, &mut stream, 30_000, 1000);
    // error spikes at the drift, then declines as new leaves fit the new
    // concept. The curve stores *cumulative* MAE; recover windowed MAE
    // from consecutive checkpoints: sum(k) = mae(k) * k.
    let cum = |k: usize| {
        report
            .curve
            .iter()
            .find(|(n, _, _)| *n == k)
            .map(|(_, mae, _)| *mae * k as f64)
            .expect("checkpoint")
    };
    let window = |a: usize, b: usize| (cum(b) - cum(a)) / (b - a) as f64;
    let right_after_drift = window(15_000, 19_000);
    let long_after_drift = window(26_000, 30_000);
    assert!(
        long_after_drift < 0.8 * right_after_drift,
        "windowed MAE should recover after the drift: {right_after_drift} -> {long_after_drift}"
    );
}

#[test]
fn deeper_trees_with_more_data() {
    let fac = paper_lineup().remove(2); // QO_0.01
    let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
    let mut splits_at = Vec::new();
    let mut stream = Friedman1::new(29, 0.5);
    for _ in 0..3 {
        for inst in stream.take_vec(10_000) {
            tree.learn_one(&inst.x, inst.y);
        }
        splits_at.push(tree.n_splits());
    }
    assert!(splits_at[0] <= splits_at[1] && splits_at[1] <= splits_at[2]);
    assert!(splits_at[2] > splits_at[0], "tree should keep growing: {splits_at:?}");
}
