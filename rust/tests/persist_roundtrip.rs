//! Checkpoint round-trip property test (satellite contract): for random
//! streams × model kinds {tree, ARF, bagging} × observer kinds
//! {QO (dynamic + fixed radius), E-BST, TE-BST, exhaustive}, `save → load`
//! must produce **bit-identical predictions** and an **identical
//! subsequent training trajectory** (same split counts, same structure,
//! same predictions after further training). The binary checkpoint fast
//! path is held to the same bar: binary ≡ canonical JSON bit-for-bit
//! across the whole corpus (`docs/FORMATS.md`).

use qostream::common::proptest::check;
use qostream::common::Rng;
use qostream::eval::Regressor;
use qostream::forest::{ArfOptions, ArfRegressor, OnlineBaggingRegressor};
use qostream::observer::{ObserverFactory, ObserverSpec};
use qostream::persist::{delta, Model};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions, SubspaceSize};

/// The observer grid: every checkpointable kind, through the same spec
/// labels the codec stores.
fn observer_grid() -> Vec<Box<dyn ObserverFactory>> {
    ["QO_s2", "QO_0.05", "E-BST", "TE-BST_3", "Exhaustive"]
        .iter()
        .map(|label| ObserverSpec::from_label(label).expect(label).to_factory())
        .collect()
}

/// One synthetic instance: 4 features, a piecewise target with noise.
fn draw_instance(rng: &mut Rng) -> (Vec<f64>, f64) {
    let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let base = if x[0] <= 0.0 { 3.0 * x[1] } else { -2.0 + x[2] };
    let y = base + rng.normal(0.0, 0.2);
    (x, y)
}

/// Assert save → load is invisible: identical predictions now, identical
/// trajectory after `extra` more instances.
fn assert_roundtrip_invisible(mut live: Model, rng: &mut Rng, extra: usize) {
    let text = live.to_text().expect("encode");
    let mut restored = Model::from_text(&text).expect("decode");
    assert_eq!(restored.name(), live.name());
    assert_eq!(restored.kind(), live.kind());
    assert_eq!(restored.n_elements(), live.n_elements());
    for _ in 0..20 {
        let (x, _) = draw_instance(rng);
        assert_eq!(
            live.predict(&x).to_bits(),
            restored.predict(&x).to_bits(),
            "prediction diverged right after restore ({})",
            live.name()
        );
    }
    for _ in 0..extra {
        let (x, y) = draw_instance(rng);
        live.learn_one(&x, y);
        restored.learn_one(&x, y);
    }
    assert_eq!(
        restored.n_elements(),
        live.n_elements(),
        "element counts diverged after continued training ({})",
        live.name()
    );
    for _ in 0..20 {
        let (x, _) = draw_instance(rng);
        assert_eq!(
            live.predict(&x).to_bits(),
            restored.predict(&x).to_bits(),
            "trajectory diverged after continued training ({})",
            live.name()
        );
    }
}

#[test]
fn tree_roundtrip_across_observers_and_streams() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("tree-roundtrip[{label}]"), 0xD0 + i as u64, 3, |rng| {
            let mut tree = HoeffdingTreeRegressor::new(
                4,
                HtrOptions {
                    grace_period: 100,
                    seed: rng.next_u64(),
                    subspace: SubspaceSize::Fixed(3),
                    ..Default::default()
                },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
            );
            let n = 600 + rng.below(900) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                tree.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Tree(tree), rng, 600);
            Ok(())
        });
    }
}

#[test]
fn arf_roundtrip_across_observers() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("arf-roundtrip[{label}]"), 0xE0 + i as u64, 2, |rng| {
            let mut arf = ArfRegressor::new(
                4,
                ArfOptions {
                    n_members: 3,
                    lambda: 2.0,
                    seed: rng.next_u64(),
                    weighted_vote: rng.bool(0.5),
                    tree: HtrOptions { grace_period: 100, ..Default::default() },
                    ..Default::default()
                },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
            );
            let n = 500 + rng.below(700) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                arf.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Arf(arf), rng, 500);
            Ok(())
        });
    }
}

#[test]
fn bagging_roundtrip_across_observers() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("bag-roundtrip[{label}]"), 0xF0 + i as u64, 2, |rng| {
            let mut bag = OnlineBaggingRegressor::new(
                4,
                3,
                1.5,
                HtrOptions { grace_period: 100, ..Default::default() },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
                rng.next_u64(),
            )
            .with_weighted_vote(rng.bool(0.5));
            let n = 500 + rng.below(700) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                bag.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Bagging(bag), rng, 500);
            Ok(())
        });
    }
}

/// Build one model of each checkpointable kind for `label`.
fn model_grid(label: &str, rng: &mut Rng) -> Vec<Model> {
    let fac = || ObserverSpec::from_label(label).expect(label).to_factory();
    let tree_opts = HtrOptions { grace_period: 100, ..Default::default() };
    vec![
        Model::Tree(HoeffdingTreeRegressor::new(4, tree_opts, fac())),
        Model::Arf(ArfRegressor::new(
            4,
            ArfOptions {
                n_members: 2,
                lambda: 2.0,
                seed: rng.next_u64(),
                tree: tree_opts,
                ..Default::default()
            },
            fac(),
        )),
        Model::Bagging(OnlineBaggingRegressor::new(
            4,
            2,
            1.5,
            tree_opts,
            fac(),
            rng.next_u64(),
        )),
    ]
}

/// The delta-checkpoint acceptance property: a full checkpoint at v0 plus
/// k structural deltas, replayed on a fresh copy, must reproduce the full
/// checkpoint at vk **byte-for-byte** — and decode to a model with
/// bit-identical predictions — across {tree, ARF, bagging} × {QO dynamic,
/// QO fixed-radius, E-BST}.
#[test]
fn delta_chain_reconstructs_full_checkpoints_byte_for_byte() {
    for (i, label) in ["QO_s2", "QO_0.05", "E-BST"].iter().enumerate() {
        check(&format!("delta-chain[{label}]"), 0x1CE + i as u64, 2, |rng| {
            for mut model in model_grid(label, rng) {
                let name = model.name();
                let base = 300 + rng.below(400) as usize;
                for _ in 0..base {
                    let (x, y) = draw_instance(rng);
                    model.learn_one(&x, y);
                }
                let v0 = model.to_checkpoint().expect("encode v0");

                // k delta steps of random length
                let mut patches = Vec::new();
                let mut full_docs = Vec::new();
                let mut prev = v0.clone();
                for _ in 0..3 {
                    let chunk = 100 + rng.below(300) as usize;
                    for _ in 0..chunk {
                        let (x, y) = draw_instance(rng);
                        model.learn_one(&x, y);
                    }
                    let doc = model.to_checkpoint().expect("encode step");
                    patches.push(delta::diff(&prev, &doc));
                    full_docs.push(doc.clone());
                    prev = doc;
                }

                // replay the chain on a fresh copy of v0
                let mut replica = v0;
                for (step, (patch, want)) in
                    patches.iter().zip(&full_docs).enumerate()
                {
                    replica = delta::apply(&replica, patch)
                        .map_err(|e| format!("{name}: apply step {step}: {e}"))?;
                    if replica.to_compact() != want.to_compact() {
                        return Err(format!(
                            "{name}: delta step {step} diverged from the full checkpoint"
                        ));
                    }
                    if delta::doc_hash(&replica) != delta::doc_hash(want) {
                        return Err(format!("{name}: hash diverged at step {step}"));
                    }
                }
                // the reconstructed head is a live, bit-identical model
                let restored = Model::from_checkpoint(&replica)
                    .map_err(|e| format!("{name}: decode head: {e}"))?;
                for _ in 0..10 {
                    let (x, _) = draw_instance(rng);
                    if restored.predict(&x).to_bits() != model.predict(&x).to_bits() {
                        return Err(format!("{name}: reconstructed head predicts differently"));
                    }
                }
            }
            Ok(())
        });
    }
}

/// The binary fast path is an alternate serialization of the canonical
/// document, nothing more: across the full corpus ({tree, ARF, bagging}
/// × every checkpointable observer kind), a binary checkpoint must
/// decode back to the canonical JSON **byte-for-byte**, restore to a
/// model with bit-identical predictions, and train on identically from
/// there (`docs/FORMATS.md`).
#[test]
fn binary_checkpoint_equals_json_across_the_corpus() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("binary-vs-json[{label}]"), 0xB1 + i as u64, 1, |rng| {
            for mut model in model_grid(&label, rng) {
                let name = model.name();
                let n = 300 + rng.below(500) as usize;
                for _ in 0..n {
                    let (x, y) = draw_instance(rng);
                    model.learn_one(&x, y);
                }

                // bit-for-bit canonical-document equivalence
                let doc = model.to_checkpoint().expect("encode");
                let bytes = model.to_binary().expect("binary encode");
                let decoded = qostream::persist::binary::decode_doc(&bytes)
                    .map_err(|e| format!("{name}: binary decode: {e}"))?;
                if decoded.to_compact() != doc.to_compact() {
                    return Err(format!("{name}: binary decode changed the canonical text"));
                }
                if delta::doc_hash(&decoded) != delta::doc_hash(&doc) {
                    return Err(format!("{name}: binary decode changed the doc hash"));
                }

                // a binary restore behaves exactly like a JSON restore
                let mut restored = Model::from_binary(&bytes)
                    .map_err(|e| format!("{name}: binary restore: {e}"))?;
                for _ in 0..10 {
                    let (x, _) = draw_instance(rng);
                    if restored.predict(&x).to_bits() != model.predict(&x).to_bits() {
                        return Err(format!("{name}: binary restore predicts differently"));
                    }
                }
                for _ in 0..200 {
                    let (x, y) = draw_instance(rng);
                    model.learn_one(&x, y);
                    restored.learn_one(&x, y);
                }
                if restored.n_elements() != model.n_elements() {
                    return Err(format!("{name}: element counts diverged after training on"));
                }
                for _ in 0..10 {
                    let (x, _) = draw_instance(rng);
                    if restored.predict(&x).to_bits() != model.predict(&x).to_bits() {
                        return Err(format!("{name}: trajectory diverged after binary restore"));
                    }
                }
                // re-encoding the restored model is a fixpoint in both formats
                if restored.to_text().expect("re-encode") != model.to_text().expect("encode") {
                    return Err(format!("{name}: JSON re-encode after binary restore differs"));
                }
                if restored.to_binary().expect("re-encode") != model.to_binary().expect("encode") {
                    return Err(format!("{name}: binary re-encode after restore differs"));
                }
            }
            Ok(())
        });
    }
}

/// Dispatch memory-governance step (a) across model kinds, mirroring
/// [`qostream::govern`]'s internal walker.
fn compact_model(model: &mut Model, target_slots: usize) -> usize {
    match model {
        Model::Tree(t) => t.compact_observers(target_slots),
        Model::Arf(f) => f.compact_observers(target_slots),
        Model::Bagging(b) => b.compact_observers(target_slots),
    }
}

/// Dispatch memory-governance step (b) across model kinds.
fn evict_model(model: &mut Model, per_tree: usize) -> usize {
    match model {
        Model::Tree(t) => t.evict_coldest(per_tree),
        Model::Arf(f) => f.evict_coldest(per_tree),
        Model::Bagging(b) => b.evict_coldest(per_tree),
    }
}

/// Governance is *exact* over the checkpoint corpus (docs/MEMORY.md):
/// the codec preserves QO slot tables bit-for-bit and the adjacent-slot
/// `VarStats` merge is deterministic, so compacting + evicting a live
/// model and doing the same to its save → load restore must land on
/// **byte-identical** checkpoints, bit-identical predictions, and an
/// identical continued-training trajectory. On E-BST members step (a)
/// must be a no-op — compaction only ever touches QO tables.
#[test]
fn governance_commutes_with_checkpoint_roundtrip() {
    for (i, label) in ["QO_s2", "QO_0.05", "E-BST"].iter().enumerate() {
        check(&format!("govern-commute[{label}]"), 0x60 + i as u64, 2, |rng| {
            for mut live in model_grid(label, rng) {
                let name = live.name();
                let n = 800 + rng.below(800) as usize;
                for _ in 0..n {
                    let (x, y) = draw_instance(rng);
                    live.learn_one(&x, y);
                }
                let mut restored = Model::from_text(&live.to_text().expect("encode"))
                    .map_err(|e| format!("{name}: restore: {e}"))?;

                let target = 2 + rng.below(14) as usize;
                let per_tree = 1 + rng.below(3) as usize;
                let ca = compact_model(&mut live, target);
                let cb = compact_model(&mut restored, target);
                if ca != cb {
                    return Err(format!("{name}: compaction count diverged: {ca} vs {cb}"));
                }
                if *label == "E-BST" && ca != 0 {
                    return Err(format!("{name}: compaction must not touch E-BST tables"));
                }
                let ea = evict_model(&mut live, per_tree);
                let eb = evict_model(&mut restored, per_tree);
                if ea != eb {
                    return Err(format!("{name}: eviction count diverged: {ea} vs {eb}"));
                }

                // governed state is byte-identical on both sides...
                let text = live.to_text().expect("encode governed");
                if restored.to_text().expect("encode governed restore") != text {
                    return Err(format!("{name}: governance did not commute with save/load"));
                }
                // ...and stays exact through continued training
                for _ in 0..300 {
                    let (x, y) = draw_instance(rng);
                    live.learn_one(&x, y);
                    restored.learn_one(&x, y);
                }
                for _ in 0..10 {
                    let (x, _) = draw_instance(rng);
                    if live.predict(&x).to_bits() != restored.predict(&x).to_bits() {
                        return Err(format!("{name}: trajectory diverged after governance"));
                    }
                }
                if live.to_text().expect("re-encode") != restored.to_text().expect("re-encode") {
                    return Err(format!("{name}: checkpoints diverged after training on"));
                }
            }
            Ok(())
        });
    }
}

/// A governed (budget-stamped) checkpoint is still a first-class
/// checkpoint: the stamped envelope survives JSON parse → re-encode and
/// the binary document codec **byte-for-byte**, decodes to a model with
/// bit-identical predictions (the decoder ignores the stamp keys), and
/// the stamp itself parses back exactly while the `GOVERN_BUDGET` audit
/// invariant convicts it iff the claim exceeds the budget.
#[test]
fn governed_stamped_checkpoints_round_trip_bit_identically() {
    use qostream::common::json::Json;

    check("governed-stamp-roundtrip", 0x60A, 2, |rng| {
        for mut model in model_grid("QO_0.05", rng) {
            let name = model.name();
            let n = 600 + rng.below(600) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                model.learn_one(&x, y);
            }
            // govern against a real (possibly unmeetable) budget so the
            // corpus covers both honest and self-convicting stamps
            let budget = model.mem_bytes() * 3 / 4;
            qostream::govern::Governor::new(budget).enforce(&mut model);
            let claimed = model.mem_bytes();
            let mut doc = model.to_checkpoint().expect("encode");
            qostream::govern::stamp_governed(&mut doc, budget, claimed);

            // the stamp parses back exactly
            match qostream::govern::governed_claim(&doc) {
                Ok(Some((b, c))) if b == budget && c == claimed => {}
                other => return Err(format!("{name}: stamp did not parse back: {other:?}")),
            }

            // JSON text round-trip is a byte-level fixpoint
            let text = doc.to_compact();
            let parsed = Json::parse(&text).map_err(|e| format!("{name}: parse: {e}"))?;
            if parsed.to_compact() != text {
                return Err(format!("{name}: stamped JSON re-encode differs"));
            }

            // binary document codec carries the stamped envelope verbatim
            let bytes = qostream::persist::binary::encode_doc(&doc);
            let back = qostream::persist::binary::decode_doc(&bytes)
                .map_err(|e| format!("{name}: binary decode: {e}"))?;
            if back.to_compact() != text {
                return Err(format!("{name}: binary round-trip changed the stamped doc"));
            }

            // the decoder ignores stamp keys: restore is bit-identical
            let restored = Model::from_checkpoint(&parsed)
                .map_err(|e| format!("{name}: decode stamped: {e}"))?;
            for _ in 0..10 {
                let (x, _) = draw_instance(rng);
                if restored.predict(&x).to_bits() != model.predict(&x).to_bits() {
                    return Err(format!("{name}: stamped restore predicts differently"));
                }
            }

            // GOVERN_BUDGET holds the file to its own claim
            let convicted = qostream::audit::invariants::verify_checkpoint(&doc)
                .iter()
                .any(|f| f.rule == qostream::audit::invariants::GOVERN_BUDGET);
            let should_convict = budget > 0 && claimed > budget;
            if convicted != should_convict {
                return Err(format!(
                    "{name}: GOVERN_BUDGET verdict wrong (budget={budget}, \
                     claimed={claimed}, convicted={convicted})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn checkpoint_of_a_checkpoint_is_byte_identical() {
    // canonicalization: the codec is a fixpoint on its own output, for
    // every model kind
    let mut rng = Rng::new(0xAB);
    let mut tree = HoeffdingTreeRegressor::new(
        4,
        HtrOptions::default(),
        ObserverSpec::from_label("QO_s2").unwrap().to_factory(),
    );
    for _ in 0..1500 {
        let (x, y) = draw_instance(&mut rng);
        tree.learn_one(&x, y);
    }
    let model = Model::Tree(tree);
    let once = model.to_text().unwrap();
    let twice = Model::from_text(&once).unwrap().to_text().unwrap();
    assert_eq!(once, twice);
}
