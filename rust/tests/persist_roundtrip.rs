//! Checkpoint round-trip property test (satellite contract): for random
//! streams × model kinds {tree, ARF, bagging} × observer kinds
//! {QO (dynamic + fixed radius), E-BST, TE-BST, exhaustive}, `save → load`
//! must produce **bit-identical predictions** and an **identical
//! subsequent training trajectory** (same split counts, same structure,
//! same predictions after further training).

use qostream::common::proptest::check;
use qostream::common::Rng;
use qostream::eval::Regressor;
use qostream::forest::{ArfOptions, ArfRegressor, OnlineBaggingRegressor};
use qostream::observer::{ObserverFactory, ObserverSpec};
use qostream::persist::Model;
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions, SubspaceSize};

/// The observer grid: every checkpointable kind, through the same spec
/// labels the codec stores.
fn observer_grid() -> Vec<Box<dyn ObserverFactory>> {
    ["QO_s2", "QO_0.05", "E-BST", "TE-BST_3", "Exhaustive"]
        .iter()
        .map(|label| ObserverSpec::from_label(label).expect(label).to_factory())
        .collect()
}

/// One synthetic instance: 4 features, a piecewise target with noise.
fn draw_instance(rng: &mut Rng) -> (Vec<f64>, f64) {
    let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let base = if x[0] <= 0.0 { 3.0 * x[1] } else { -2.0 + x[2] };
    let y = base + rng.normal(0.0, 0.2);
    (x, y)
}

/// Assert save → load is invisible: identical predictions now, identical
/// trajectory after `extra` more instances.
fn assert_roundtrip_invisible(mut live: Model, rng: &mut Rng, extra: usize) {
    let text = live.to_text().expect("encode");
    let mut restored = Model::from_text(&text).expect("decode");
    assert_eq!(restored.name(), live.name());
    assert_eq!(restored.kind(), live.kind());
    assert_eq!(restored.n_elements(), live.n_elements());
    for _ in 0..20 {
        let (x, _) = draw_instance(rng);
        assert_eq!(
            live.predict(&x).to_bits(),
            restored.predict(&x).to_bits(),
            "prediction diverged right after restore ({})",
            live.name()
        );
    }
    for _ in 0..extra {
        let (x, y) = draw_instance(rng);
        live.learn_one(&x, y);
        restored.learn_one(&x, y);
    }
    assert_eq!(
        restored.n_elements(),
        live.n_elements(),
        "element counts diverged after continued training ({})",
        live.name()
    );
    for _ in 0..20 {
        let (x, _) = draw_instance(rng);
        assert_eq!(
            live.predict(&x).to_bits(),
            restored.predict(&x).to_bits(),
            "trajectory diverged after continued training ({})",
            live.name()
        );
    }
}

#[test]
fn tree_roundtrip_across_observers_and_streams() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("tree-roundtrip[{label}]"), 0xD0 + i as u64, 3, |rng| {
            let mut tree = HoeffdingTreeRegressor::new(
                4,
                HtrOptions {
                    grace_period: 100,
                    seed: rng.next_u64(),
                    subspace: SubspaceSize::Fixed(3),
                    ..Default::default()
                },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
            );
            let n = 600 + rng.below(900) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                tree.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Tree(tree), rng, 600);
            Ok(())
        });
    }
}

#[test]
fn arf_roundtrip_across_observers() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("arf-roundtrip[{label}]"), 0xE0 + i as u64, 2, |rng| {
            let mut arf = ArfRegressor::new(
                4,
                ArfOptions {
                    n_members: 3,
                    lambda: 2.0,
                    seed: rng.next_u64(),
                    weighted_vote: rng.bool(0.5),
                    tree: HtrOptions { grace_period: 100, ..Default::default() },
                    ..Default::default()
                },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
            );
            let n = 500 + rng.below(700) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                arf.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Arf(arf), rng, 500);
            Ok(())
        });
    }
}

#[test]
fn bagging_roundtrip_across_observers() {
    for (i, factory) in observer_grid().into_iter().enumerate() {
        let label = factory.name();
        check(&format!("bag-roundtrip[{label}]"), 0xF0 + i as u64, 2, |rng| {
            let mut bag = OnlineBaggingRegressor::new(
                4,
                3,
                1.5,
                HtrOptions { grace_period: 100, ..Default::default() },
                ObserverSpec::from_label(&label).expect("grid label").to_factory(),
                rng.next_u64(),
            )
            .with_weighted_vote(rng.bool(0.5));
            let n = 500 + rng.below(700) as usize;
            for _ in 0..n {
                let (x, y) = draw_instance(rng);
                bag.learn_one(&x, y);
            }
            assert_roundtrip_invisible(Model::Bagging(bag), rng, 500);
            Ok(())
        });
    }
}

#[test]
fn checkpoint_of_a_checkpoint_is_byte_identical() {
    // canonicalization: the codec is a fixpoint on its own output, for
    // every model kind
    let mut rng = Rng::new(0xAB);
    let mut tree = HoeffdingTreeRegressor::new(
        4,
        HtrOptions::default(),
        ObserverSpec::from_label("QO_s2").unwrap().to_factory(),
    );
    for _ in 0..1500 {
        let (x, y) = draw_instance(&mut rng);
        tree.learn_one(&x, y);
    }
    let model = Model::Tree(tree);
    let once = model.to_text().unwrap();
    let twice = Model::from_text(&once).unwrap().to_text().unwrap();
    assert_eq!(once, twice);
}
