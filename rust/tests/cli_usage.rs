//! CLI error-handling regression tests (satellite contract): unknown
//! subcommands and malformed flags must print usage to **stderr** and
//! exit nonzero; bare `qostream` prints usage to stdout and exits 0.

use std::process::Command;

fn qostream(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_qostream"))
        .args(args)
        .output()
        .expect("spawn qostream")
}

#[test]
fn no_subcommand_prints_usage_to_stdout_and_exits_zero() {
    let out = qostream(&[]);
    assert!(out.status.success(), "bare invocation must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"), "usage must go to stdout: {stdout}");
    assert!(stdout.contains("serve"), "usage must list the serve subcommand");
    assert!(stdout.contains("checkpoint"), "usage must list checkpoint");
}

#[test]
fn unknown_subcommand_prints_usage_to_stderr_and_exits_nonzero() {
    let out = qostream(&["frobnicate"]);
    assert!(!out.status.success(), "unknown subcommand must exit nonzero");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("frobnicate"), "error must name the subcommand: {stderr}");
    assert!(stderr.contains("USAGE"), "usage must go to stderr: {stderr}");
    assert!(
        String::from_utf8_lossy(&out.stdout).is_empty(),
        "nothing should land on stdout"
    );
}

#[test]
fn malformed_integer_flag_prints_usage_and_exits_nonzero() {
    let out = qostream(&["tree", "--instances", "banana"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--instances"), "error must name the flag: {stderr}");
    assert!(stderr.contains("USAGE"));
}

#[test]
fn malformed_enum_flag_prints_usage_and_exits_nonzero() {
    for args in [
        vec!["forest", "--instances", "10", "--subspace", "martian"],
        vec!["forest", "--instances", "10", "--split-backend", "warp-drive"],
        vec!["protocol", "--profile", "ultra"],
        vec!["serve", "--bench", "--instances", "nope"],
        vec!["checkpoint"], // neither --out nor --load
        vec!["fleet"],      // no --targets
        vec!["fleet", "--targets", " , "], // targets parse to an empty list
    ] {
        let out = qostream(&args);
        assert!(!out.status.success(), "{args:?} must exit nonzero");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains("USAGE"), "{args:?} must print usage to stderr: {stderr}");
    }
}

#[test]
fn checkpoint_save_then_load_roundtrips_via_the_binary() {
    let dir = std::env::temp_dir().join(format!("qostream-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    let path_str = path.to_str().unwrap();

    let out = qostream(&[
        "checkpoint",
        "--out",
        path_str,
        "--model",
        "tree",
        "--instances",
        "1500",
    ]);
    assert!(
        out.status.success(),
        "save failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bit-identical: true"), "{stdout}");

    let out = qostream(&["checkpoint", "--load", path_str]);
    assert!(
        out.status.success(),
        "load failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bit-identical: true"), "{stdout}");

    std::fs::remove_dir_all(&dir).ok();
}
