//! End-to-end fleet-observability tests over real sockets: a leader's
//! `stats.followers` seeds auto-discovery, `scrape_fleet` reads every
//! node's `health` + `metrics_raw`, and the aggregator's merged
//! registry is **bit-exact equal** to merging the per-node snapshots
//! locally (the acceptance contract — quantiles come from summed
//! buckets, never from averaged quantiles). Also drives the
//! `serve_scrapes` HTTP endpoint end to end.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use qostream::forest::{ArfOptions, ArfRegressor};
use qostream::obs::RegistrySnapshot;
use qostream::observer::{factory, QuantizationObserver, RadiusPolicy};
use qostream::persist::Model;
use qostream::serve::{fleet, Follower, FollowerOptions, ServeClient, ServeOptions, Server};
use qostream::stream::{Friedman1, Stream};

fn qo_factory() -> Box<dyn qostream::observer::ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

fn arf_model(members: usize, seed: u64) -> Model {
    Model::Arf(ArfRegressor::new(
        10,
        ArfOptions { n_members: members, lambda: 3.0, seed, ..Default::default() },
        qo_factory(),
    ))
}

/// Block until the follower reaches `version` (bounded).
fn wait_version(follower: &Follower, version: u64) {
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while follower.version() < version {
        assert!(
            std::time::Instant::now() < deadline,
            "follower stuck at v{} waiting for v{version}",
            follower.version()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The tentpole end to end: seed `discover` with only the leader, find
/// the whole fleet through its `stats.followers`, scrape every node,
/// and prove the aggregator's merged registry equals a local merge of
/// the very snapshots it scraped — bit-exact, by `PartialEq` on the
/// decoded bucket arrays.
#[test]
fn discovery_scrape_and_exact_merge() {
    let server = Server::start(
        arf_model(2, 31),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let leader_addr = server.addr().to_string();
    let start_follower = || {
        Follower::start(
            &leader_addr,
            "127.0.0.1:0",
            FollowerOptions { poll_interval: Duration::from_millis(3), ..Default::default() },
        )
        .expect("follower")
    };
    let follower_a = start_follower();
    let follower_b = start_follower();

    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(29, 1.0);
    for round in 1..=3u64 {
        for _ in 0..150 {
            let inst = stream.next_instance().unwrap();
            client.learn(&inst.x, inst.y).expect("learn");
        }
        client.snapshot().expect("publish");
        wait_version(&follower_a, round);
        wait_version(&follower_b, round);
    }

    // discovery: the leader seed expands to the full fleet, seed first
    let targets = fleet::discover(&[leader_addr.clone()]);
    assert_eq!(targets.len(), 3, "leader + 2 advertised followers: {targets:?}");
    assert_eq!(targets[0], leader_addr, "seeds stay first: {targets:?}");
    for addr in [follower_a.addr().to_string(), follower_b.addr().to_string()] {
        assert!(targets.contains(&addr), "{addr} not discovered: {targets:?}");
    }

    let scrape = fleet::scrape_fleet(&targets);
    assert_eq!(scrape.nodes.len(), 3);
    assert_eq!(scrape.merge_skipped, 0, "same-version fleet must merge fully");
    for node in &scrape.nodes {
        assert!(node.up, "{} must be reachable", node.addr);
        assert_eq!(node.status, "ok", "{}: {:?}", node.addr, node.status);
        assert_eq!(node.snapshot_version, 3, "{} at the head", node.addr);
    }
    assert_eq!(scrape.nodes.iter().filter(|n| n.role == "leader").count(), 1);
    assert_eq!(scrape.nodes.iter().filter(|n| n.role == "follower").count(), 2);

    // the acceptance contract: merging the scraped per-node snapshots
    // locally reproduces the aggregator's merged registry bit-exactly
    let mut local: Option<RegistrySnapshot> = None;
    for node in &scrape.nodes {
        let snap = node.snapshot.as_ref().expect("up node carries a snapshot");
        local = Some(match local.take() {
            None => snap.clone(),
            Some(acc) => acc.merge(snap).expect("uniform fleet must merge"),
        });
    }
    let local = local.expect("three snapshots");
    assert_eq!(scrape.merged.as_ref(), Some(&local), "merge must be deterministic");

    // ... and the merged freshness histogram is the exact bucketwise sum
    // of its inputs (never an average of quantiles)
    let fam = "qostream_repl_freshness_seconds";
    let merged_hist = local.summary_hist(fam).expect("freshness family");
    for bucket in 0..merged_hist.counts.len() {
        let summed: u64 = scrape
            .nodes
            .iter()
            .filter_map(|n| n.snapshot.as_ref()?.summary_hist(fam))
            .map(|h| h.counts[bucket])
            .sum();
        assert_eq!(merged_hist.counts[bucket], summed, "bucket {bucket} drifted");
    }
    assert!(merged_hist.count >= 6, "2 followers x 3 versions applied: {merged_hist:?}");

    // per-node derived views: every follower has live freshness
    for node in scrape.nodes.iter().filter(|n| n.role == "follower") {
        let p99 = node.freshness_p99_secs().expect("follower freshness");
        assert!(p99 > 0.0, "{}: p99 {p99}", node.addr);
    }

    // rendered fleet exposition: totals, one labeled row per node, and
    // the merged families beside them
    let text = scrape.exposition();
    assert!(text.contains("qostream_fleet_nodes 3\n"), "{text}");
    assert!(text.contains("qostream_fleet_nodes_up 3\n"), "{text}");
    for node in &scrape.nodes {
        let row = format!(
            "qostream_node_up{{node=\"{}\",role=\"{}\"}} 1\n",
            node.addr, node.role
        );
        assert!(text.contains(&row), "missing {row:?} in:\n{text}");
    }
    assert!(text.contains("qostream_tree_learns_total"), "{text}");
    assert!(text.contains("qostream_node_freshness_p99_seconds"), "{text}");

    // dashboard: one row per node plus the fleet footer
    let dash = scrape.dashboard();
    for node in &scrape.nodes {
        assert!(dash.contains(&node.addr), "dashboard missing {}:\n{dash}", node.addr);
    }
    assert!(dash.contains("nodes: 3  up: 3"), "{dash}");

    let mut client_a = ServeClient::connect(follower_a.addr()).expect("follower a");
    client_a.shutdown().expect("follower a shutdown");
    follower_a.join().expect("follower a exit");
    let mut client_b = ServeClient::connect(follower_b.addr()).expect("follower b");
    client_b.shutdown().expect("follower b shutdown");
    follower_b.join().expect("follower b exit");
    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}

/// `qostream fleet --listen` end to end: the HTTP endpoint re-discovers
/// and re-scrapes per request and answers a plain Prometheus text page
/// a scraper can parse with nothing but content-length.
#[test]
fn http_endpoint_serves_the_fleet_exposition() {
    let server = Server::start(
        arf_model(2, 37),
        "127.0.0.1:0",
        ServeOptions { snapshot_every: 0, ..Default::default() },
    )
    .expect("leader");
    let leader_addr = server.addr().to_string();
    let mut client = ServeClient::connect(server.addr()).expect("leader client");
    let mut stream = Friedman1::new(41, 1.0);
    for _ in 0..100 {
        let inst = stream.next_instance().unwrap();
        client.learn(&inst.x, inst.y).expect("learn");
    }
    client.snapshot().expect("publish");

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind scrape endpoint");
    let endpoint = listener.local_addr().expect("endpoint addr");
    let seeds = vec![leader_addr.clone()];
    // serve_scrapes loops forever; the thread dies with the test process
    std::thread::spawn(move || fleet::serve_scrapes(listener, seeds, true));

    for _ in 0..2 {
        // two rounds: the endpoint must answer repeated scrapes
        let mut conn = TcpStream::connect(endpoint).expect("connect scraper");
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: fleet\r\n\r\n")
            .expect("send request");
        let body = fleet::read_http_body(conn).expect("parse http response");
        assert!(body.contains("qostream_fleet_nodes 1\n"), "{body}");
        assert!(body.contains("qostream_fleet_nodes_up 1\n"), "{body}");
        let row = format!("qostream_node_up{{node=\"{leader_addr}\",role=\"leader\"}} 1\n");
        assert!(body.contains(&row), "missing {row:?} in:\n{body}");
        assert!(body.contains("# HELP qostream_fleet_nodes "), "{body}");
        assert!(body.contains("qostream_tree_learns_total"), "{body}");
    }

    client.shutdown().expect("leader shutdown");
    server.join().expect("leader exit");
}
