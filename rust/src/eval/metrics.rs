//! Incremental regression metrics: MAE, RMSE and R² computed online (one
//! pass, O(1) state) using the robust [`VarStats`] accumulator
//! for the target-variance term of R².

use crate::stats::VarStats;

/// One-pass MAE / RMSE / R² accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegressionMetrics {
    n: f64,
    abs_err_sum: f64,
    sq_err_sum: f64,
    target_stats: VarStats,
}

impl RegressionMetrics {
    pub fn new() -> RegressionMetrics {
        RegressionMetrics::default()
    }

    pub fn update(&mut self, y_true: f64, y_pred: f64) {
        let err = y_true - y_pred;
        self.n += 1.0;
        self.abs_err_sum += err.abs();
        self.sq_err_sum += err * err;
        self.target_stats.update(y_true, 1.0);
    }

    pub fn count(&self) -> f64 {
        self.n
    }

    pub fn mae(&self) -> f64 {
        if self.n > 0.0 {
            self.abs_err_sum / self.n
        } else {
            0.0
        }
    }

    pub fn mse(&self) -> f64 {
        if self.n > 0.0 {
            self.sq_err_sum / self.n
        } else {
            0.0
        }
    }

    pub fn rmse(&self) -> f64 {
        self.mse().sqrt()
    }

    /// R² = 1 − SSE / SST (0 when the target variance is degenerate).
    pub fn r2(&self) -> f64 {
        let sst = self.target_stats.m2;
        if sst > 0.0 {
            1.0 - self.sq_err_sum / sst
        } else {
            0.0
        }
    }

    /// Merge two accumulators (metrics are additive).
    pub fn merged(&self, o: &RegressionMetrics) -> RegressionMetrics {
        RegressionMetrics {
            n: self.n + o.n,
            abs_err_sum: self.abs_err_sum + o.abs_err_sum,
            sq_err_sum: self.sq_err_sum + o.sq_err_sum,
            target_stats: self.target_stats + o.target_stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let mut m = RegressionMetrics::new();
        for y in [1.0, 2.0, 3.0] {
            m.update(y, y);
        }
        assert_eq!(m.mae(), 0.0);
        assert_eq!(m.rmse(), 0.0);
        assert_eq!(m.r2(), 1.0);
    }

    #[test]
    fn known_errors() {
        let mut m = RegressionMetrics::new();
        m.update(1.0, 2.0); // err 1
        m.update(5.0, 2.0); // err 3
        assert!((m.mae() - 2.0).abs() < 1e-12);
        assert!((m.mse() - 5.0).abs() < 1e-12);
        assert!((m.rmse() - 5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn r2_of_mean_predictor_is_zero() {
        // predicting the (final) mean gives R² ~ 0
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mean = 3.0;
        let mut m = RegressionMetrics::new();
        for &y in &ys {
            m.update(y, mean);
        }
        assert!(m.r2().abs() < 1e-12);
    }

    #[test]
    fn merged_equals_sequential() {
        let mut a = RegressionMetrics::new();
        let mut b = RegressionMetrics::new();
        let mut whole = RegressionMetrics::new();
        for i in 0..10 {
            let (y, p) = (i as f64, i as f64 * 0.9);
            if i < 5 {
                a.update(y, p);
            } else {
                b.update(y, p);
            }
            whole.update(y, p);
        }
        let m = a.merged(&b);
        assert!((m.mae() - whole.mae()).abs() < 1e-12);
        assert!((m.r2() - whole.r2()).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_finite() {
        let m = RegressionMetrics::new();
        assert_eq!(m.mae(), 0.0);
        assert_eq!(m.rmse(), 0.0);
        assert_eq!(m.r2(), 0.0);
    }
}
