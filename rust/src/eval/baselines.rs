//! Baseline regressors: the running-mean predictor and a normalized
//! linear SGD model (the FIMT leaf perceptron uses the same core).

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::persist::codec::{
    field, jf64, parr, pf64, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

use super::Regressor;

/// Predicts the running target mean — the weakest sensible baseline and
/// also the leaf model of a regression tree stump.
#[derive(Clone, Debug, Default)]
pub struct MeanRegressor {
    stats: VarStats,
}

impl MeanRegressor {
    pub fn new() -> MeanRegressor {
        MeanRegressor::default()
    }
}

impl Regressor for MeanRegressor {
    fn predict(&self, _x: &[f64]) -> f64 {
        self.stats.mean
    }

    fn learn_one(&mut self, _x: &[f64], y: f64) {
        self.stats.update(y, 1.0);
    }

    fn name(&self) -> String {
        "mean".to_string()
    }

    fn n_elements(&self) -> usize {
        1
    }
}

/// Linear model trained by SGD on z-normalized features and target
/// (FIMT's leaf perceptron; Ikonomovska et al. 2011 Sec. 4.2).
///
/// Normalization uses running per-feature statistics, so the model is
/// scale-free and the fixed learning rate is stable across the Table 1
/// settings (feature scales span 0.1 to 7).
#[derive(Clone, Debug)]
pub struct LinearSgd {
    weights: Vec<f64>,
    bias: f64,
    lr: f64,
    feature_stats: Vec<VarStats>,
    target_stats: VarStats,
}

impl LinearSgd {
    pub fn new(n_features: usize, lr: f64) -> LinearSgd {
        LinearSgd {
            weights: vec![0.0; n_features],
            bias: 0.0,
            lr,
            feature_stats: vec![VarStats::new(); n_features],
            target_stats: VarStats::new(),
        }
    }

    /// Resident heap footprint in bytes (weights + normalization stats).
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<LinearSgd>()
            + self.weights.capacity() * std::mem::size_of::<f64>()
            + self.feature_stats.capacity() * std::mem::size_of::<VarStats>()
    }

    #[inline]
    fn norm_x(&self, i: usize, xi: f64) -> f64 {
        let s = &self.feature_stats[i];
        let sd = s.std();
        if sd > 0.0 {
            (xi - s.mean) / (3.0 * sd)
        } else {
            0.0
        }
    }

    /// Prediction in normalized target space.
    fn predict_norm(&self, x: &[f64]) -> f64 {
        let mut out = self.bias;
        for (i, &xi) in x.iter().enumerate() {
            out += self.weights[i] * self.norm_x(i, xi);
        }
        out
    }

    /// Checkpoint encoding ([`crate::persist`]): weights, bias, learning
    /// rate and the running normalization statistics.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("weights", Json::Arr(self.weights.iter().map(|&w| jf64(w)).collect()))
            .set("bias", jf64(self.bias))
            .set("lr", jf64(self.lr))
            .set(
                "feature_stats",
                Json::Arr(self.feature_stats.iter().map(varstats_to_json).collect()),
            )
            .set("target_stats", varstats_to_json(&self.target_stats));
        o
    }

    /// Decode a model written by [`LinearSgd::to_json`].
    pub fn from_json(j: &Json) -> Result<LinearSgd> {
        let weights: Vec<f64> = parr(field(j, "weights")?, "weights")?
            .iter()
            .map(|w| pf64(w, "weights"))
            .collect::<Result<_>>()?;
        let feature_stats: Vec<VarStats> = parr(field(j, "feature_stats")?, "feature_stats")?
            .iter()
            .map(|s| varstats_from(s, "feature_stats"))
            .collect::<Result<_>>()?;
        if feature_stats.len() != weights.len() {
            return Err(anyhow!(
                "linear model: {} weights but {} feature stats",
                weights.len(),
                feature_stats.len()
            ));
        }
        Ok(LinearSgd {
            weights,
            bias: pf64(field(j, "bias")?, "bias")?,
            lr: pf64(field(j, "lr")?, "lr")?,
            feature_stats,
            target_stats: varstats_from(field(j, "target_stats")?, "target_stats")?,
        })
    }
}

impl LinearSgd {
    /// Fused learn + predict: returns the pre-update prediction computed
    /// with the SAME normalized pass used by the gradient step, so
    /// adaptive leaves don't pay for a second `predict_norm` loop per
    /// instance (see EXPERIMENTS.md §Perf).
    pub fn learn_returning_prediction(&mut self, x: &[f64], y: f64) -> f64 {
        debug_assert_eq!(x.len(), self.weights.len());
        for (i, &xi) in x.iter().enumerate() {
            self.feature_stats[i].update(xi, 1.0);
        }
        self.target_stats.update(y, 1.0);
        let sd = self.target_stats.std();
        if sd == 0.0 {
            return self.target_stats.mean;
        }
        let pred_norm = self.predict_norm(x);
        let prediction = pred_norm * 3.0 * sd + self.target_stats.mean;
        let y_norm = (y - self.target_stats.mean) / (3.0 * sd);
        let err = pred_norm - y_norm;
        for (i, &xi) in x.iter().enumerate() {
            let xn = self.norm_x(i, xi);
            self.weights[i] -= self.lr * err * xn;
        }
        self.bias -= self.lr * err;
        prediction
    }
}

impl Regressor for LinearSgd {
    fn predict(&self, x: &[f64]) -> f64 {
        let sd = self.target_stats.std();
        if sd > 0.0 {
            self.predict_norm(x) * 3.0 * sd + self.target_stats.mean
        } else {
            self.target_stats.mean
        }
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        debug_assert_eq!(x.len(), self.weights.len());
        for (i, &xi) in x.iter().enumerate() {
            self.feature_stats[i].update(xi, 1.0);
        }
        self.target_stats.update(y, 1.0);
        let sd = self.target_stats.std();
        if sd == 0.0 {
            return;
        }
        let y_norm = (y - self.target_stats.mean) / (3.0 * sd);
        let err = self.predict_norm(x) - y_norm;
        for (i, &xi) in x.iter().enumerate() {
            let xn = self.norm_x(i, xi);
            self.weights[i] -= self.lr * err * xn;
        }
        self.bias -= self.lr * err;
    }

    fn name(&self) -> String {
        "linear-sgd".to_string()
    }

    fn n_elements(&self) -> usize {
        self.weights.len() + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn mean_regressor_tracks_mean() {
        let mut m = MeanRegressor::new();
        for y in [2.0, 4.0, 6.0] {
            m.learn_one(&[0.0], y);
        }
        assert!((m.predict(&[123.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn linear_sgd_fits_linear_function() {
        let mut model = LinearSgd::new(2, 0.05);
        let mut rng = Rng::new(31);
        for _ in 0..20_000 {
            let x = [rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)];
            let y = 3.0 * x[0] - 2.0 * x[1] + 0.5;
            model.learn_one(&x, y);
        }
        let mut max_err: f64 = 0.0;
        for _ in 0..100 {
            let x = [rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)];
            let y = 3.0 * x[0] - 2.0 * x[1] + 0.5;
            max_err = max_err.max((model.predict(&x) - y).abs());
        }
        assert!(max_err < 0.6, "max_err={max_err}");
    }

    #[test]
    fn linear_sgd_beats_mean_on_linear_data() {
        let mut lin = LinearSgd::new(1, 0.05);
        let mut mean = MeanRegressor::new();
        let mut rng = Rng::new(33);
        let mut err_lin = 0.0;
        let mut err_mean = 0.0;
        for t in 0..5000 {
            let x = [rng.uniform(-2.0, 2.0)];
            let y = 5.0 * x[0];
            if t > 1000 {
                err_lin += (lin.predict(&x) - y).abs();
                err_mean += (mean.predict(&x) - y).abs();
            }
            lin.learn_one(&x, y);
            mean.learn_one(&x, y);
        }
        assert!(err_lin < 0.5 * err_mean, "lin={err_lin} mean={err_mean}");
    }

    #[test]
    fn linear_sgd_json_roundtrip_is_bit_identical() {
        let mut model = LinearSgd::new(3, 0.05);
        let mut rng = Rng::new(71);
        for _ in 0..500 {
            let x = [rng.f64(), rng.normal(0.0, 2.0), rng.uniform(-1.0, 1.0)];
            model.learn_one(&x, 2.0 * x[0] - x[2]);
        }
        let text = model.to_json().to_compact();
        let mut back =
            LinearSgd::from_json(&crate::common::json::Json::parse(&text).unwrap()).unwrap();
        let probe = [0.3, -0.7, 0.9];
        assert_eq!(model.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        // continued training stays identical
        for _ in 0..100 {
            let x = [rng.f64(), rng.normal(0.0, 2.0), rng.uniform(-1.0, 1.0)];
            let y = 2.0 * x[0] - x[2];
            model.learn_one(&x, y);
            back.learn_one(&x, y);
        }
        assert_eq!(model.predict(&probe).to_bits(), back.predict(&probe).to_bits());
    }

    #[test]
    fn scale_invariance() {
        // same data scaled by 1000: relative accuracy must be similar
        let run = |scale: f64| -> f64 {
            let mut model = LinearSgd::new(1, 0.05);
            let mut rng = Rng::new(35);
            let mut err = 0.0;
            for t in 0..10_000 {
                let x = [rng.uniform(-1.0, 1.0) * scale];
                let y = 2.0 * x[0];
                if t > 8000 {
                    err += (model.predict(&x) - y).abs() / scale;
                }
                model.learn_one(&x, y);
            }
            err
        };
        let (e1, e1000) = (run(1.0), run(1000.0));
        assert!((e1 - e1000).abs() / e1.max(1e-9) < 0.5, "e1={e1} e1000={e1000}");
    }
}
