//! Prequential (interleaved test-then-train) evaluation: every instance is
//! first used for prediction, then for learning — the standard protocol of
//! the data-stream literature (Gama 2010).

use std::time::Instant;

use crate::stream::Stream;

use super::{metrics::RegressionMetrics, Regressor};

/// Outcome of a prequential run.
#[derive(Clone, Debug)]
pub struct PrequentialReport {
    pub model: String,
    pub stream: String,
    pub instances: usize,
    pub metrics: RegressionMetrics,
    /// Wall-clock seconds spent in predict+learn.
    pub seconds: f64,
    /// Element count reported by the model at the end.
    pub n_elements: usize,
    /// Periodic checkpoints: (instances seen, MAE so far, RMSE so far).
    pub curve: Vec<(usize, f64, f64)>,
}

impl PrequentialReport {
    pub fn throughput(&self) -> f64 {
        if self.seconds > 0.0 {
            self.instances as f64 / self.seconds
        } else {
            f64::INFINITY
        }
    }
}

/// Run `model` prequentially over up to `max_instances` of `stream`,
/// checkpointing the error curve every `checkpoint_every` instances
/// (0 = no curve).
pub fn prequential(
    model: &mut dyn Regressor,
    stream: &mut dyn Stream,
    max_instances: usize,
    checkpoint_every: usize,
) -> PrequentialReport {
    let mut metrics = RegressionMetrics::new();
    let mut curve = Vec::new();
    let mut seen = 0usize;
    let start = Instant::now();
    while seen < max_instances {
        let Some(inst) = stream.next_instance() else { break };
        let pred = model.predict(&inst.x);
        metrics.update(inst.y, pred);
        model.learn_one(&inst.x, inst.y);
        seen += 1;
        if checkpoint_every > 0 && seen % checkpoint_every == 0 {
            curve.push((seen, metrics.mae(), metrics.rmse()));
        }
    }
    PrequentialReport {
        model: model.name(),
        stream: stream.name(),
        instances: seen,
        metrics,
        seconds: start.elapsed().as_secs_f64(),
        n_elements: model.n_elements(),
        curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::baselines::MeanRegressor;
    use crate::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};

    fn stream() -> SyntheticRegression {
        SyntheticRegression::new(
            Distribution::Uniform { lo: -1.0, hi: 1.0 },
            TargetFn::Linear,
            NoiseSpec::NONE,
            2,
            77,
        )
    }

    #[test]
    fn runs_exact_instance_count() {
        let mut model = MeanRegressor::new();
        let mut s = stream();
        let report = prequential(&mut model, &mut s, 500, 100);
        assert_eq!(report.instances, 500);
        assert_eq!(report.curve.len(), 5);
        assert_eq!(report.curve.last().unwrap().0, 500);
    }

    #[test]
    fn mean_regressor_r2_near_zero() {
        let mut model = MeanRegressor::new();
        let mut s = stream();
        let report = prequential(&mut model, &mut s, 5000, 0);
        assert!(report.metrics.r2() < 0.2, "r2={}", report.metrics.r2());
        assert!(report.curve.is_empty());
    }

    #[test]
    fn bounded_stream_stops_early() {
        struct Two(usize);
        impl Stream for Two {
            fn next_instance(&mut self) -> Option<crate::stream::Instance> {
                if self.0 == 0 {
                    return None;
                }
                self.0 -= 1;
                Some(crate::stream::Instance { x: vec![0.0], y: 1.0 })
            }
            fn n_features(&self) -> usize {
                1
            }
            fn name(&self) -> String {
                "two".into()
            }
        }
        let mut model = MeanRegressor::new();
        let report = prequential(&mut model, &mut Two(2), 100, 0);
        assert_eq!(report.instances, 2);
    }
}
