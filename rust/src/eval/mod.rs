//! Evaluation substrate: incremental regression metrics, the prequential
//! (test-then-train) protocol and baseline regressors.

pub mod baselines;
pub mod metrics;
pub mod prequential;

pub use baselines::{LinearSgd, MeanRegressor};
pub use metrics::RegressionMetrics;
pub use prequential::{prequential, PrequentialReport};

/// An online regression model (test-then-train interface).
pub trait Regressor: Send {
    /// Predict the target for `x` (must work from the first instance).
    fn predict(&self, x: &[f64]) -> f64;

    /// Learn from one labelled instance.
    fn learn_one(&mut self, x: &[f64], y: f64);

    fn name(&self) -> String;

    /// Rough model-size indicator (element counts, see paper Sec. 5.3).
    fn n_elements(&self) -> usize {
        0
    }
}
