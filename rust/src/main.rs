//! `qostream` CLI — the L3 entrypoint.
//!
//! Subcommands map one-to-one onto the paper's evaluation (DESIGN.md §3):
//!
//! ```text
//! qostream protocol --describe                # Table 1 grid
//! qostream fig1 [--profile quick|standard|full] [--sizes 100,1000] [--reps N]
//! qostream fig3 [--profile ...]
//! qostream cd [--metric merit|elements|observe|query|all] [--profile ...]
//! qostream tree [--instances N] [--seed S]    # Sec. 7 integration
//! qostream forest [--members N] [--lambda L] [--subspace sqrt|all|K]
//!                 [--split-backend per-observer|native-batch|xla] [--parallel W]
//!                 [--shards N]                 # leader/shard distributed fit
//! qostream coordinator [--shards N] [--instances N]
//! qostream xla [--instances N] [--radius R]
//! qostream all                                # everything, standard profile
//! ```

use anyhow::Result;

use qostream::bench_suite::{cd, fig1, fig3, forest_bench, protocol::Profile, tree_bench, Protocol};
use qostream::common::cli::Args;
use qostream::common::timing::human_time;
use qostream::coordinator::{CoordinatorConfig, ShardedObserverCoordinator};
use qostream::criterion::VarianceReduction;
use qostream::eval::Regressor;
use qostream::forest::{fit_parallel, ArfOptions, ArfRegressor, ParallelFitConfig, SubspaceSize};
use qostream::observer::AttributeObserver;
use qostream::runtime::{find_artifacts_dir, Manifest, SplitBackendKind, XlaSplitEngine};
use qostream::stream::{Friedman1, Stream};

fn protocol_from(args: &Args) -> Protocol {
    let profile = Profile::parse(args.get_or("profile", "standard"))
        .unwrap_or_else(|| panic!("--profile must be quick|standard|full"));
    let mut protocol = Protocol::new(profile);
    if let Some(sizes) = args.opt("sizes") {
        let sizes: Vec<usize> = sizes
            .split(',')
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("bad size {s:?}")))
            .collect();
        protocol = protocol.with_sizes(sizes);
    }
    if let Some(reps) = args.opt("reps") {
        protocol = protocol.with_repetitions(reps.parse().expect("--reps integer"));
    }
    protocol
}

fn cmd_protocol(args: &Args) -> Result<()> {
    let protocol = protocol_from(args);
    println!("{}", protocol.describe());
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let protocol = protocol_from(args);
    eprintln!("fig1: {}", protocol.describe());
    let rendered = fig1::generate(&protocol, !args.flag("quiet"))?;
    println!("{rendered}");
    println!("written to results/fig1/");
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let protocol = protocol_from(args);
    eprintln!("fig3: {}", protocol.describe());
    let rendered = fig3::generate(&protocol, !args.flag("quiet"))?;
    println!("{rendered}");
    println!("written to results/fig3/");
    Ok(())
}

fn cmd_cd(args: &Args) -> Result<()> {
    let protocol = protocol_from(args);
    let metric = args.get_or("metric", "all").to_string();
    eprintln!("cd[{metric}]: {}", protocol.describe());
    if metric == "all" {
        println!("{}", cd::generate(&protocol, !args.flag("quiet"))?);
        println!("written to results/cd/");
    } else {
        let results = fig1::run_protocol(&protocol, !args.flag("quiet"));
        println!("{}", cd::analyze(&results, &metric)?);
    }
    Ok(())
}

fn cmd_tree(args: &Args) -> Result<()> {
    let instances = args.usize_or("instances", 100_000);
    let seed = args.u64_or("seed", 1);
    println!("{}", tree_bench::generate(instances, seed)?);
    println!("written to results/tree/");
    Ok(())
}

fn observer_factory(kind: &str) -> Box<dyn qostream::observer::ObserverFactory> {
    match kind {
        "qo" => forest_bench::qo_factory(),
        "ebst" => forest_bench::ebst_factory(),
        other => panic!("--observer must be qo|ebst, got {other:?}"),
    }
}

fn cmd_forest(args: &Args) -> Result<()> {
    let instances = args.usize_or("instances", 20_000);
    let cfg = forest_bench::ForestBenchConfig {
        instances,
        members: args.usize_or("members", 10),
        lambda: args.f64_or("lambda", 6.0),
        subspace: SubspaceSize::parse(args.get_or("subspace", "sqrt"))
            .unwrap_or_else(|| panic!("--subspace must be all|sqrt|<count>|<fraction>")),
        seed: args.u64_or("seed", 1),
        drift_at: args.usize_or("drift-at", instances / 2),
        split_backend: SplitBackendKind::parse(args.get_or("split-backend", "native-batch"))
            .unwrap_or_else(|| {
                panic!("--split-backend must be per-observer|native-batch|xla")
            }),
    };
    println!("{}", forest_bench::generate(&cfg)?);
    println!("written to results/forest/");

    let workers = args.usize_or("parallel", 0);
    if workers > 0 {
        // multi-core fit demo: same members, same seed, sharded over
        // worker threads — predictions must match the sequential path
        let observer = args.get_or("observer", "qo").to_string();
        let opts = ArfOptions {
            n_members: cfg.members,
            lambda: cfg.lambda,
            subspace: cfg.subspace,
            seed: cfg.seed,
            tree: qostream::tree::HtrOptions {
                split_backend: cfg.split_backend,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut sequential = ArfRegressor::new(10, opts, observer_factory(&observer));
        let mut stream = cfg.stream();
        let (seq_secs, _) = qostream::common::timing::time_once(|| {
            for _ in 0..cfg.instances {
                let Some(inst) = stream.next_instance() else { break };
                sequential.learn_one(&inst.x, inst.y);
            }
        });
        let mut parallel = ArfRegressor::new(10, opts, observer_factory(&observer));
        let report = fit_parallel(
            &mut parallel,
            &mut *cfg.stream(),
            cfg.instances,
            ParallelFitConfig { n_workers: workers, ..Default::default() },
        );
        let mut probe = Friedman1::new(cfg.seed ^ 0xBEEF, 0.0);
        let identical = (0..100).all(|_| {
            let inst = probe.next_instance().unwrap();
            sequential.predict(&inst.x) == parallel.predict(&inst.x)
        });
        println!(
            "parallel fit: {} workers, {} in {} ({:.1}k inst/s vs {:.1}k sequential); \
             predictions identical to sequential: {identical}",
            report.n_workers,
            report.instances,
            human_time(report.seconds),
            report.throughput() / 1e3,
            cfg.instances as f64 / seq_secs / 1e3,
        );
    }

    let shards = args.usize_or("shards", 0);
    if shards > 0 {
        // leader/shard distributed forest: members sharded across workers,
        // one split-backend round-trip per shard per tick, and the
        // leader-merged vote asserted bit-identical to sequential
        println!("{}", forest_bench::sharded_comparison(&cfg, shards).render());
    }
    Ok(())
}

fn cmd_coordinator(args: &Args) -> Result<()> {
    let shards = args.usize_or("shards", 4);
    let instances = args.usize_or("instances", 500_000);
    let radius = args.f64_or("radius", 0.05);
    let mut stream = Friedman1::new(args.u64_or("seed", 1), 1.0);
    let coordinator = ShardedObserverCoordinator::new(
        stream.n_features(),
        CoordinatorConfig { n_shards: shards, radius, ..Default::default() },
    );
    println!("coordinating {instances} instances over {shards} shard(s), r={radius}");
    let report = coordinator.run(&mut stream, instances);
    println!(
        "done in {} ({:.1}k inst/s); per-shard: {:?}",
        human_time(report.seconds),
        report.instances as f64 / report.seconds / 1e3,
        report.per_shard
    );
    for (f, split) in report.best_splits(&VarianceReduction).iter().enumerate() {
        match split {
            Some(s) => println!(
                "  feature {f}: slots={:<5} best split x <= {:.4} (VR {:.4})",
                report.merged[f].n_elements(),
                s.threshold,
                s.merit
            ),
            None => println!("  feature {f}: no split"),
        }
    }
    Ok(())
}

fn cmd_xla(args: &Args) -> Result<()> {
    let dir = find_artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let client = xla::PjRtClient::cpu()?;
    let engine = XlaSplitEngine::load(&client, &manifest)?;
    println!(
        "loaded split_eval artifact (F={}, S={}) on {}",
        engine.f,
        engine.s,
        client.platform_name()
    );
    let n = args.usize_or("instances", 20_000);
    let radius = args.f64_or("radius", 0.05);
    let mut rng = qostream::common::Rng::new(args.u64_or("seed", 7));
    let observers: Vec<qostream::observer::QuantizationObserver> = (0..engine.f)
        .map(|f| {
            let mut qo = qostream::observer::QuantizationObserver::with_radius(radius);
            for _ in 0..n {
                let x = rng.normal(0.0, 1.0);
                let y = (f as f64 + 1.0) * x.powi(2) + rng.normal(0.0, 0.1);
                qo.observe(x, y, 1.0);
            }
            qo
        })
        .collect();
    let refs: Vec<&qostream::observer::QuantizationObserver> = observers.iter().collect();
    let (secs, results) = qostream::common::timing::time_once(|| {
        engine.best_splits_for_observers(&refs).expect("xla eval")
    });
    println!("evaluated {} features in {}", engine.f, human_time(secs));
    for (f, (qo, res)) in observers.iter().zip(&results).enumerate() {
        let native = qo.best_split(&VarianceReduction).unwrap();
        let xres = res.expect("split");
        println!(
            "  feature {f}: xla (c={:.4}, vr={:.4})  native (c={:.4}, vr={:.4})  agree={}",
            xres.threshold,
            xres.merit,
            native.threshold,
            native.merit,
            (xres.threshold - native.threshold).abs() < 1e-9
        );
    }
    Ok(())
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_fig1(args)?;
    cmd_fig3(args)?;
    cmd_cd(args)?;
    cmd_tree(args)?;
    cmd_forest(args)?;
    Ok(())
}

const USAGE: &str = "\
qostream — Quantization Observer for online tree regressors (paper reproduction)

USAGE: qostream <subcommand> [options]

SUBCOMMANDS
  protocol     describe the Table 1 grid          [--profile quick|standard|full]
  fig1         merit/elements/time vs sample size [--profile --sizes --reps]
  fig3         split-point distance to E-BST      [--profile --sizes --reps]
  cd           Friedman/Nemenyi CD diagrams       [--metric merit|elements|observe|query|all]
  tree         Hoeffding-tree integration bench   [--instances N --seed S]
  forest       online ensembles vs single tree    [--instances N --members M --lambda L
               (bagging + ARF on drifting data,    --subspace all|sqrt|K --drift-at N --seed S
                batched split queries,             --split-backend per-observer|native-batch|xla
                sharded leader/worker fitting)     --parallel W --shards N
                                                   --observer qo|ebst (demo only)]
  coordinator  sharded distributed observation    [--shards N --instances N --radius R]
  xla          AOT split-eval via PJRT artifacts  [--instances N --radius R]
  all          fig1 + fig3 + cd + tree + forest (standard profile)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("protocol") => cmd_protocol(&args),
        Some("fig1") => cmd_fig1(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("cd") => cmd_cd(&args),
        Some("tree") => cmd_tree(&args),
        Some("forest") => cmd_forest(&args),
        Some("coordinator") => cmd_coordinator(&args),
        Some("xla") => cmd_xla(&args),
        Some("all") => cmd_all(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
