//! `qostream` CLI — the L3 entrypoint.
//!
//! Subcommands map one-to-one onto the paper's evaluation (DESIGN.md §3)
//! plus the production-facing layers grown on top of it:
//!
//! ```text
//! qostream protocol --describe                # Table 1 grid
//! qostream fig1 [--profile quick|standard|full] [--sizes 100,1000] [--reps N]
//! qostream fig3 [--profile ...]
//! qostream cd [--metric merit|elements|observe|query|all] [--profile ...]
//! qostream tree [--instances N] [--seed S]    # Sec. 7 integration
//! qostream forest [--members N] [--lambda L] [--subspace sqrt|all|K]
//!                 [--split-backend per-observer|native-batch|xla] [--parallel W]
//!                 [--shards N] [--weighted-vote] [--mem-budget BYTES]
//! qostream coordinator [--shards N] [--instances N]
//! qostream serve [--port P] [--model tree|arf|bag] [--observer qo|ebst|<label>]
//!                [--members N] [--snapshot-every K] [--restore ckpt.json]
//!                [--checkpoint-out ckpt.json] [--shards N] [--shard-batch B]
//!                [--delta-history K] [--mem-budget BYTES]
//!                [--follower-of HOST:PORT] [--poll-ms MS]
//!                [--bench [--replication] [--smoke --out F --baseline F]]
//! qostream fleet --targets HOST:PORT[,...] [--listen HOST:PORT] [--top [--interval-ms MS]]
//!                [--once] [--no-discover]
//! qostream checkpoint --out ckpt.json [--model ...] [--instances N] [--format json|binary]
//! qostream checkpoint --load ckpt.json [--convert out.qosb] [--format json|binary]
//! qostream audit --checkpoint ckpt.json|ckpt.qosb [--deltas FILE|DIR] [--json]
//! qostream audit --self-check
//! qostream xla [--instances N] [--radius R]
//! qostream all                                # everything, standard profile
//! ```
//!
//! Error contract: an unknown subcommand or a malformed flag prints the
//! error and the usage to **stderr** and exits nonzero (regression-tested
//! in `rust/tests/cli_usage.rs`); plain `qostream` prints usage to stdout
//! and exits 0.

#![forbid(unsafe_code)]

use anyhow::{anyhow, bail, Context, Result};

use qostream::audit::invariants;
use qostream::bench_suite::{
    cd, fig1, fig3, forest_bench, protocol::Profile, serve_bench, tree_bench, Protocol,
};
use qostream::common::cli::Args;
use qostream::common::json::Json;
use qostream::common::timing::human_time;
use qostream::coordinator::{CoordinatorConfig, ShardedObserverCoordinator};
use qostream::criterion::VarianceReduction;
use qostream::eval::Regressor;
use qostream::forest::{
    fit_parallel, ArfOptions, ArfRegressor, OnlineBaggingRegressor, ParallelFitConfig,
    SubspaceSize,
};
use qostream::observer::{AttributeObserver, ObserverSpec};
use qostream::persist::{codec, delta, Model};
use qostream::runtime::{find_artifacts_dir, Manifest, SplitBackendKind, XlaSplitEngine};
use qostream::serve::{fleet, Follower, FollowerOptions, ServeOptions, Server};
use qostream::stream::{Friedman1, Stream};
use qostream::tree::{HoeffdingTreeRegressor, HtrOptions};

fn protocol_from(args: &Args) -> Result<Protocol> {
    let profile = Profile::parse(args.get_or("profile", "standard"))
        .ok_or_else(|| anyhow!("--profile must be quick|standard|full"))?;
    let mut protocol = Protocol::new(profile);
    if let Some(sizes) = args.opt("sizes") {
        let sizes: Vec<usize> = sizes
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow!("--sizes expects integers, got {s:?}"))
            })
            .collect::<Result<_>>()?;
        protocol = protocol.with_sizes(sizes);
    }
    if let Some(reps) = args.opt("reps") {
        protocol = protocol.with_repetitions(
            reps.parse().map_err(|_| anyhow!("--reps expects an integer, got {reps:?}"))?,
        );
    }
    Ok(protocol)
}

fn cmd_protocol(args: &Args) -> Result<()> {
    let protocol = protocol_from(args)?;
    println!("{}", protocol.describe());
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let protocol = protocol_from(args)?;
    eprintln!("fig1: {}", protocol.describe());
    let rendered = fig1::generate(&protocol, !args.flag("quiet"))?;
    println!("{rendered}");
    println!("written to results/fig1/");
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let protocol = protocol_from(args)?;
    eprintln!("fig3: {}", protocol.describe());
    let rendered = fig3::generate(&protocol, !args.flag("quiet"))?;
    println!("{rendered}");
    println!("written to results/fig3/");
    Ok(())
}

fn cmd_cd(args: &Args) -> Result<()> {
    let protocol = protocol_from(args)?;
    let metric = args.get_or("metric", "all").to_string();
    eprintln!("cd[{metric}]: {}", protocol.describe());
    if metric == "all" {
        println!("{}", cd::generate(&protocol, !args.flag("quiet"))?);
        println!("written to results/cd/");
    } else {
        let results = fig1::run_protocol(&protocol, !args.flag("quiet"));
        println!("{}", cd::analyze(&results, &metric)?);
    }
    Ok(())
}

fn cmd_tree(args: &Args) -> Result<()> {
    let instances = args.try_usize("instances", 100_000)?;
    let seed = args.try_u64("seed", 1)?;
    println!("{}", tree_bench::generate(instances, seed)?);
    println!("written to results/tree/");
    Ok(())
}

/// Observer selection shared by `forest`, `serve` and `checkpoint`:
/// the `qo`/`ebst` shorthands, or any [`ObserverSpec`] label
/// (`QO_0.01`, `QO_s3`, `TE-BST_3`, `Exhaustive`, …).
fn observer_factory(kind: &str) -> Result<Box<dyn qostream::observer::ObserverFactory>> {
    match kind {
        "qo" => Ok(forest_bench::qo_factory()),
        "ebst" => Ok(forest_bench::ebst_factory()),
        other => ObserverSpec::from_label(other)
            .map(|spec| spec.to_factory())
            .ok_or_else(|| {
                anyhow!("--observer must be qo|ebst or an observer label, got {other:?}")
            }),
    }
}

fn cmd_forest(args: &Args) -> Result<()> {
    let instances = args.try_usize("instances", 20_000)?;
    let cfg = forest_bench::ForestBenchConfig {
        instances,
        members: args.try_usize("members", 10)?,
        lambda: args.try_f64("lambda", 6.0)?,
        subspace: SubspaceSize::parse(args.get_or("subspace", "sqrt"))
            .ok_or_else(|| anyhow!("--subspace must be all|sqrt|<count>|<fraction>"))?,
        seed: args.try_u64("seed", 1)?,
        drift_at: args.try_usize("drift-at", instances / 2)?,
        split_backend: SplitBackendKind::parse(args.get_or("split-backend", "native-batch"))
            .ok_or_else(|| anyhow!("--split-backend must be per-observer|native-batch|xla"))?,
    };
    println!("{}", forest_bench::generate(&cfg)?);
    println!("written to results/forest/");

    if args.flag("weighted-vote") {
        // accuracy-weighted vote demo: same members, same stream, only
        // the fold differs — compare prequential accuracy around a drift
        let opts = ArfOptions {
            n_members: cfg.members,
            lambda: cfg.lambda,
            subspace: cfg.subspace,
            seed: cfg.seed,
            weighted_vote: true,
            tree: HtrOptions { split_backend: cfg.split_backend, ..Default::default() },
            ..Default::default()
        };
        let observer = args.get_or("observer", "qo").to_string();
        let mut weighted = ArfRegressor::new(10, opts, observer_factory(&observer)?);
        let mut flat = ArfRegressor::new(
            10,
            ArfOptions { weighted_vote: false, ..opts },
            observer_factory(&observer)?,
        );
        let (mut err_w, mut err_f) = (0.0f64, 0.0f64);
        let mut stream = cfg.stream();
        for i in 0..cfg.instances {
            let Some(inst) = stream.next_instance() else { break };
            if i >= cfg.drift_at {
                let ew = inst.y - weighted.predict(&inst.x);
                let ef = inst.y - flat.predict(&inst.x);
                err_w += ew * ew;
                err_f += ef * ef;
            }
            weighted.learn_one(&inst.x, inst.y);
            flat.learn_one(&inst.x, inst.y);
        }
        let n = cfg.instances.saturating_sub(cfg.drift_at).max(1) as f64;
        println!(
            "weighted vote (post-drift RMSE): weighted {:.4} vs flat {:.4}",
            (err_w / n).sqrt(),
            (err_f / n).sqrt()
        );
    }

    let workers = args.try_usize("parallel", 0)?;
    if workers > 0 {
        // multi-core fit demo: same members, same seed, sharded over
        // worker threads — predictions must match the sequential path
        let observer = args.get_or("observer", "qo").to_string();
        let opts = ArfOptions {
            n_members: cfg.members,
            lambda: cfg.lambda,
            subspace: cfg.subspace,
            seed: cfg.seed,
            tree: HtrOptions { split_backend: cfg.split_backend, ..Default::default() },
            ..Default::default()
        };
        let mut sequential = ArfRegressor::new(10, opts, observer_factory(&observer)?);
        let mut stream = cfg.stream();
        let (seq_secs, _) = qostream::common::timing::time_once(|| {
            for _ in 0..cfg.instances {
                let Some(inst) = stream.next_instance() else { break };
                sequential.learn_one(&inst.x, inst.y);
            }
        });
        let mut parallel = ArfRegressor::new(10, opts, observer_factory(&observer)?);
        let report = fit_parallel(
            &mut parallel,
            &mut *cfg.stream(),
            cfg.instances,
            ParallelFitConfig { n_workers: workers, ..Default::default() },
        );
        let mut probe = Friedman1::new(cfg.seed ^ 0xBEEF, 0.0);
        let identical = (0..100).all(|_| {
            let inst = probe.next_instance().unwrap();
            sequential.predict(&inst.x) == parallel.predict(&inst.x)
        });
        println!(
            "parallel fit: {} workers, {} in {} ({:.1}k inst/s vs {:.1}k sequential); \
             predictions identical to sequential: {identical}",
            report.n_workers,
            report.instances,
            human_time(report.seconds),
            report.throughput() / 1e3,
            cfg.instances as f64 / seq_secs / 1e3,
        );
    }

    let shards = args.try_usize("shards", 0)?;
    if shards > 0 {
        // leader/shard distributed forest: members sharded across workers,
        // one split-backend round-trip per shard per tick, and the
        // leader-merged vote asserted bit-identical to sequential
        println!("{}", forest_bench::sharded_comparison(&cfg, shards).render());
    }

    let mem_budget = args.try_usize("mem-budget", 0)?;
    if mem_budget > 0 {
        // memory-governance demo: grow the same forest on the same
        // stream, then run the escalation ladder (compact -> evict ->
        // prune, docs/MEMORY.md) and report what it took to fit
        let observer = args.get_or("observer", "qo").to_string();
        let mut model = Model::Arf(ArfRegressor::new(
            10,
            ArfOptions {
                n_members: cfg.members,
                lambda: cfg.lambda,
                subspace: cfg.subspace,
                seed: cfg.seed,
                tree: HtrOptions { split_backend: cfg.split_backend, ..Default::default() },
                ..Default::default()
            },
            observer_factory(&observer)?,
        ));
        let mut stream = cfg.stream();
        for _ in 0..cfg.instances {
            let Some(inst) = stream.next_instance() else { break };
            model.learn_one(&inst.x, inst.y);
        }
        let report = qostream::govern::Governor::new(mem_budget).enforce(&mut model);
        println!(
            "memory governance: {} B -> {} B under a {mem_budget} B budget \
             ({} compactions, {} evictions, {} prunes; within budget: {})",
            report.start_bytes,
            report.end_bytes,
            report.compactions,
            report.evictions,
            report.prunes,
            report.within_budget
        );
    }
    Ok(())
}

fn cmd_coordinator(args: &Args) -> Result<()> {
    let shards = args.try_usize("shards", 4)?;
    let instances = args.try_usize("instances", 500_000)?;
    let radius = args.try_f64("radius", 0.05)?;
    let mut stream = Friedman1::new(args.try_u64("seed", 1)?, 1.0);
    let coordinator = ShardedObserverCoordinator::new(
        stream.n_features(),
        CoordinatorConfig { n_shards: shards, radius, ..Default::default() },
    );
    println!("coordinating {instances} instances over {shards} shard(s), r={radius}");
    let report = coordinator.run(&mut stream, instances);
    println!(
        "done in {} ({:.1}k inst/s); per-shard: {:?}",
        human_time(report.seconds),
        report.instances as f64 / report.seconds / 1e3,
        report.per_shard
    );
    for (f, split) in report.best_splits(&VarianceReduction).iter().enumerate() {
        match split {
            Some(s) => println!(
                "  feature {f}: slots={:<5} best split x <= {:.4} (VR {:.4})",
                report.merged[f].n_elements(),
                s.threshold,
                s.merit
            ),
            None => println!("  feature {f}: no split"),
        }
    }
    Ok(())
}

/// Build the model `serve`/`checkpoint` operate on: `--restore` loads a
/// checkpoint, otherwise `--model`/`--observer`/`--members` configure a
/// fresh one (10 features, matching the Friedman #1 demo streams).
fn build_model(args: &Args) -> Result<Model> {
    if let Some(path) = args.opt("restore") {
        let model = Model::load(path)?;
        eprintln!("restored {} ({}) from {path}", model.name(), model.kind());
        return Ok(model);
    }
    let observer = args.get_or("observer", "qo").to_string();
    let n_features = args.try_usize("features", 10)?;
    let members = args.try_usize("members", 5)?;
    let seed = args.try_u64("seed", 1)?;
    let weighted = args.flag("weighted-vote");
    match args.get_or("model", "arf") {
        "tree" => Ok(Model::Tree(HoeffdingTreeRegressor::new(
            n_features,
            HtrOptions::default(),
            observer_factory(&observer)?,
        ))),
        "arf" => Ok(Model::Arf(ArfRegressor::new(
            n_features,
            ArfOptions {
                n_members: members,
                seed,
                weighted_vote: weighted,
                ..Default::default()
            },
            observer_factory(&observer)?,
        ))),
        "bag" | "bagging" => Ok(Model::Bagging(
            OnlineBaggingRegressor::new(
                n_features,
                members,
                6.0,
                HtrOptions::default(),
                observer_factory(&observer)?,
                seed,
            )
            .with_weighted_vote(weighted),
        )),
        other => bail!("--model must be tree|arf|bag, got {other:?}"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("bench") {
        if args.flag("smoke") {
            // pinned-seed micro-bench + CI regression gate: writes the
            // BENCH_ci.json artifact and exits nonzero on a gate violation
            let out = args.get_or("out", "BENCH_ci.json");
            print!("{}", serve_bench::run_smoke_cli(out, args.opt("baseline"))?);
            return Ok(());
        }
        if args.flag("replication") {
            let cfg = serve_bench::ReplicationBenchConfig {
                instances: args.try_usize("instances", 4000)?,
                members: args.try_usize("members", 3)?,
                snapshot_every: args.try_usize("snapshot-every", 100)?,
                followers: args.try_usize("followers", 2)?,
                poll_ms: args.try_u64("poll-ms", 5)?,
                seed: args.try_u64("seed", 1)?,
            };
            let r = serve_bench::run_replication(&cfg)?;
            println!(
                "replication: {} versions, {} deltas applied, {} full resyncs\n\
                 lag p50 {:.2}ms p99 {:.2}ms ({} samples); live freshness p50 {:.2}ms \
                 p99 {:.2}ms ({} spans); delta {:.0}B vs full {}B \
                 ({:.1}x); reads/s leader {:.0} followers {:.0}; bit-identical: {}",
                r.versions,
                r.deltas_applied,
                r.full_resyncs,
                r.lag_p50_s * 1e3,
                r.lag_p99_s * 1e3,
                r.lag_samples,
                r.freshness_p50_s * 1e3,
                r.freshness_p99_s * 1e3,
                r.freshness_samples,
                r.mean_delta_bytes,
                r.full_bytes,
                r.delta_ratio,
                r.leader_reads_per_sec,
                r.follower_reads_per_sec,
                r.bit_identical
            );
            return Ok(());
        }
        let cfg = serve_bench::ServeBenchConfig {
            instances: args.try_usize("instances", 5000)?,
            members: args.try_usize("members", 5)?,
            snapshot_every: args.try_usize("snapshot-every", 500)?,
            min_predict_samples: args.try_usize("predict-samples", 500)?,
            seed: args.try_u64("seed", 1)?,
        };
        println!("{}", serve_bench::generate(&cfg)?);
        println!("written to results/serve/");
        return Ok(());
    }
    let bind = format!(
        "{}:{}",
        args.get_or("host", "127.0.0.1"),
        args.try_u64("port", 7878)?
    );
    if let Some(leader) = args.opt("follower-of") {
        // read replica: no trainer, mirrors the leader's published delta
        // checkpoints and serves predict/predict_batch/stats
        let options = FollowerOptions {
            poll_interval: args.try_ms("poll-ms", 25)?,
            ..Default::default()
        };
        let follower = Follower::start(leader, &bind, options)?;
        println!(
            "following {leader} on {} (poll every {:?})\n\
             protocol: NDJSON predict | predict_batch | snapshot | stats | health \
             | metrics | metrics_raw | trace_splits | trace_repl | shutdown",
            follower.addr(),
            options.poll_interval
        );
        follower.join()?;
        println!("follower stopped");
        return Ok(());
    }
    let model = build_model(args)?;
    let options = ServeOptions {
        snapshot_every: args.try_usize("snapshot-every", 512)?,
        queue_capacity: args.try_usize("queue", 1024)?,
        delta_history: args.try_usize("delta-history", 64)?,
        shards: args.try_usize("shards", 0)?,
        shard_batch: args.try_usize("shard-batch", 256)?,
        mem_budget: args.try_usize("mem-budget", 0)?,
    };
    let name = model.name();
    let server = Server::start(model, &bind, options)?;
    let sharding = if options.shards > 1 {
        format!(", {} trainer shards", options.shards)
    } else {
        String::new()
    };
    let budget = if options.mem_budget > 0 {
        format!(", {} B memory budget", options.mem_budget)
    } else {
        String::new()
    };
    println!(
        "serving {name} on {} (snapshot hot-swap every {} learns, \
         {}-deep delta ring{sharding}{budget})\n\
         protocol: NDJSON learn | predict | predict_batch | snapshot | stats | health \
         | repl_sync | metrics | metrics_raw | trace_splits | trace_repl | shutdown",
        server.addr(),
        options.snapshot_every,
        options.delta_history
    );
    let final_model = server.join()?;
    println!("server stopped");
    if let Some(path) = args.opt("checkpoint-out") {
        if options.mem_budget > 0 {
            // governed run: stamp the budget and the measured footprint
            // into the envelope so `qostream audit` can hold the file to
            // its own claim (GOVERN_BUDGET, docs/MEMORY.md)
            let mut doc = final_model.to_checkpoint()?;
            qostream::govern::stamp_governed(
                &mut doc,
                options.mem_budget,
                final_model.mem_bytes(),
            );
            let mut text = doc.to_compact();
            text.push('\n');
            std::fs::write(path, text)
                .with_context(|| format!("writing governed checkpoint {path}"))?;
        } else {
            final_model.save(path)?;
        }
        println!("final model checkpointed to {path}");
    }
    Ok(())
}

/// Parse `--format json|binary`; `None` when the flag is absent.
fn checkpoint_format(args: &Args) -> Result<Option<bool>> {
    match args.opt("format") {
        None => Ok(None),
        Some("json") => Ok(Some(false)),
        Some("binary") => Ok(Some(true)),
        Some(other) => bail!("--format must be json or binary, got {other:?}"),
    }
}

fn cmd_checkpoint(args: &Args) -> Result<()> {
    let format = checkpoint_format(args)?;
    if let Some(path) = args.opt("load") {
        let source_bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        let source_binary = std::fs::read(path)
            .map(|raw| qostream::persist::binary::is_binary(&raw))
            .unwrap_or(false);
        let model = Model::load(path)?;
        println!(
            "loaded {} ({}): {} features, {} stored elements ({} checkpoint, {source_bytes} bytes)",
            model.name(),
            model.kind(),
            model.n_features(),
            model.n_elements(),
            if source_binary { "binary" } else { "json" },
        );
        // restore-fidelity spot check: another codec round-trip must
        // predict bit-identically
        let clone = model.clone_via_codec()?;
        let mut rng = qostream::common::Rng::new(args.try_u64("seed", 1)? ^ 0xF00D);
        let identical = (0..100).all(|_| {
            let x: Vec<f64> = (0..model.n_features()).map(|_| rng.f64()).collect();
            model.predict(&x).to_bits() == clone.predict(&x).to_bits()
        });
        println!("round-trip predictions bit-identical: {identical}");
        if !identical {
            bail!("checkpoint round-trip diverged");
        }
        if let Some(out) = args.opt("convert") {
            // cross-format conversion: --format picks the target, default
            // is the format the source is not in
            let to_binary = format.unwrap_or(!source_binary);
            if to_binary {
                model.save_binary(out)?;
            } else {
                model.save(out)?;
            }
            let out_bytes = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
            let restored = Model::load(out)?;
            let same_doc =
                restored.to_checkpoint()?.to_compact() == model.to_checkpoint()?.to_compact();
            println!(
                "converted to {} {out}: {source_bytes} -> {out_bytes} bytes \
                 ({:+.1}%), canonical document bit-identical: {same_doc}",
                if to_binary { "binary" } else { "json" },
                100.0 * (out_bytes as f64 - source_bytes as f64) / (source_bytes as f64).max(1.0),
            );
            if !same_doc {
                bail!("format conversion changed the canonical document");
            }
        }
        return Ok(());
    }
    let out = args
        .opt("out")
        .ok_or_else(|| anyhow!("checkpoint needs --out <path> (or --load <path>)"))?
        .to_string();
    let mut model = build_model(args)?;
    let instances = args.try_usize("instances", 20_000)?;
    if args.opt("restore").is_none() {
        if model.n_features() != 10 {
            bail!("the training demo streams Friedman #1 (10 features); use --features 10");
        }
        let mut stream = Friedman1::new(args.try_u64("seed", 1)?, 1.0);
        for _ in 0..instances {
            let Some(inst) = stream.next_instance() else { break };
            model.learn_one(&inst.x, inst.y);
        }
    }
    let binary_out = format.unwrap_or(false);
    if binary_out {
        model.save_binary(&out)?;
    } else {
        model.save(&out)?;
    }
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!(
        "{} ({}) checkpointed to {out} ({} format, {bytes} bytes, {} elements)",
        model.name(),
        model.kind(),
        if binary_out { "binary" } else { "json" },
        model.n_elements()
    );
    // prove the file restores to the identical model
    let restored = Model::load(&out)?;
    let mut rng = qostream::common::Rng::new(0xC0FFEE);
    let identical = (0..100).all(|_| {
        let x: Vec<f64> = (0..model.n_features()).map(|_| rng.f64()).collect();
        model.predict(&x).to_bits() == restored.predict(&x).to_bits()
    });
    println!("save → load predictions bit-identical: {identical}");
    if !identical {
        bail!("checkpoint round-trip diverged");
    }
    Ok(())
}

/// Read wire-delta records for `audit --deltas`: either one NDJSON file
/// (one `{"from","to","hash","ops"}` record per line) or a directory of
/// `*.json` record files replayed in lexicographic order.
fn audit_deltas_from(path: &str) -> Result<Vec<Json>> {
    let meta = std::fs::metadata(path).with_context(|| format!("reading deltas {path}"))?;
    let mut sources: Vec<(String, String)> = Vec::new();
    if meta.is_dir() {
        let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(path)
            .with_context(|| format!("listing deltas {path}"))?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| p.extension().map_or(false, |ext| ext == "json"))
            .collect();
        files.sort();
        for file in files {
            let text = std::fs::read_to_string(&file)
                .with_context(|| format!("reading delta {}", file.display()))?;
            sources.push((file.display().to_string(), text));
        }
    } else {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading deltas {path}"))?;
        sources.push((path.to_string(), text));
    }
    let mut records = Vec::new();
    for (name, text) in sources {
        for line in text.lines().map(str::trim).filter(|l| !l.is_empty()) {
            records.push(Json::parse(line).map_err(|e| anyhow!("parsing {name}: {e}"))?);
        }
    }
    Ok(records)
}

/// `audit --self-check`: train a model in-memory, build a checkpoint and
/// a delta chain, require both to verify clean, then inject canary
/// corruptions and require each to be detected under its rule id — the
/// CI `static-analysis` job's end-to-end check of the verifier itself.
fn audit_self_check() -> Result<()> {
    let mut model = Model::Tree(HoeffdingTreeRegressor::new(
        10,
        HtrOptions::default(),
        observer_factory("qo")?,
    ));
    let mut stream = Friedman1::new(42, 1.0);
    for _ in 0..4000 {
        let Some(inst) = stream.next_instance() else { break };
        model.learn_one(&inst.x, inst.y);
    }
    let base = model.to_checkpoint()?;
    let mut deltas = Vec::new();
    let mut prev = base.clone();
    for v in 0..3u64 {
        for _ in 0..400 {
            let Some(inst) = stream.next_instance() else { break };
            model.learn_one(&inst.x, inst.y);
        }
        let next = model.to_checkpoint()?;
        let mut wire = Json::obj();
        wire.set("from", codec::ju64(v))
            .set("to", codec::ju64(v + 1))
            .set("hash", codec::ju64(delta::doc_hash(&next)))
            .set("ops", delta::diff(&prev, &next));
        deltas.push(wire);
        prev = next;
    }

    let clean = invariants::verify_model(&model);
    if !clean.is_empty() {
        for f in &clean {
            println!("{f}");
        }
        bail!("audit self-check: a freshly trained model failed its own audit");
    }
    let chain = invariants::verify_delta_chain(&base, &deltas);
    if !chain.is_empty() {
        for f in &chain {
            println!("{f}");
        }
        bail!("audit self-check: a clean delta chain failed its own audit");
    }

    let mut missed: Vec<String> = Vec::new();
    let mut canary = |name: &str, rule: &str, findings: Vec<qostream::audit::Finding>| {
        if !findings.iter().any(|f| f.rule == rule) {
            missed.push(format!("{name} (expected {rule})"));
        }
    };
    let mut doc = base.clone();
    doc.set("kind", "mystery");
    canary("corrupted kind tag", invariants::CKPT_ENVELOPE, invariants::verify_checkpoint(&doc));
    let mut broken = deltas.clone();
    broken[1].set("hash", codec::ju64(1));
    canary(
        "corrupted delta hash",
        invariants::DELTA_HASH_CHAIN,
        invariants::verify_delta_chain(&base, &broken),
    );
    let gapped = vec![deltas[0].clone(), deltas[2].clone()];
    canary(
        "missing middle delta",
        invariants::DELTA_VERSION_ORDER,
        invariants::verify_delta_chain(&base, &gapped),
    );
    let bin = qostream::persist::binary::encode_doc(&base);
    let bin_clean = invariants::verify_binary(&bin);
    if !bin_clean.is_empty() {
        for f in &bin_clean {
            println!("{f}");
        }
        bail!("audit self-check: a clean binary checkpoint failed its own audit");
    }
    let mut flipped = bin.clone();
    flipped[qostream::persist::binary::HEADER_LEN + 5] ^= 0x01;
    canary(
        "corrupted binary payload",
        invariants::BIN_TRAILER,
        invariants::verify_binary(&flipped),
    );
    let mut flipped = bin.clone();
    flipped[10] ^= 0x01; // doc_hash byte: payload + trailer stay consistent
    canary(
        "corrupted binary doc_hash",
        invariants::BIN_ENVELOPE,
        invariants::verify_binary(&flipped),
    );
    let mut forged = base.clone();
    // a governed stamp claiming a budget the footprint exceeds: the
    // checkpoint convicts itself (docs/MEMORY.md)
    qostream::govern::stamp_governed(&mut forged, 1, model.mem_bytes());
    canary(
        "forged memory-budget claim",
        invariants::GOVERN_BUDGET,
        invariants::verify_checkpoint(&forged),
    );
    if !missed.is_empty() {
        bail!("audit self-check: canaries not detected: {}", missed.join(", "));
    }
    println!(
        "audit self-check: clean model + {}-delta chain + binary envelope verified; \
         6/6 canary corruptions detected",
        deltas.len()
    );
    Ok(())
}

fn cmd_audit(args: &Args) -> Result<()> {
    if args.flag("self-check") {
        return audit_self_check();
    }
    let path = args
        .opt("checkpoint")
        .ok_or_else(|| anyhow!("audit needs --checkpoint <file> (or --self-check)"))?;
    let raw = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    // magic sniff: binary checkpoints get the envelope/trailer rules plus
    // the decoded document's full catalog; JSON goes straight to it
    let (mut findings, doc, mut checked) = if qostream::persist::binary::is_binary(&raw) {
        let findings = invariants::verify_binary(&raw);
        let doc = qostream::persist::binary::decode_doc(&raw).ok();
        (findings, doc, format!("binary checkpoint {path}"))
    } else {
        let text = String::from_utf8(raw).map_err(|e| anyhow!("reading {path}: {e}"))?;
        let doc = Json::parse(text.trim_end()).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        (invariants::verify_checkpoint(&doc), Some(doc), format!("checkpoint {path}"))
    };
    if let Some(deltas_path) = args.opt("deltas") {
        match &doc {
            Some(doc) => {
                let records = audit_deltas_from(deltas_path)?;
                findings
                    .extend(invariants::verify_delta_chain(doc, &records));
                checked
                    .push_str(&format!(" + {} delta record(s) from {deltas_path}", records.len()));
            }
            // the envelope findings already say why there is no document
            None => checked.push_str(" (deltas skipped: checkpoint did not decode)"),
        }
    }
    let json = args.flag("json");
    for f in &findings {
        if json {
            println!("{}", f.to_json().to_compact());
        } else {
            println!("{f}");
        }
    }
    if findings.is_empty() {
        println!("audit: clean ({checked})");
        Ok(())
    } else {
        bail!("audit: {} finding(s) in {checked}", findings.len());
    }
}

fn cmd_xla(args: &Args) -> Result<()> {
    let dir = find_artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let client = xla::PjRtClient::cpu()?;
    let engine = XlaSplitEngine::load(&client, &manifest)?;
    println!(
        "loaded split_eval artifact (F={}, S={}) on {}",
        engine.f,
        engine.s,
        client.platform_name()
    );
    let n = args.try_usize("instances", 20_000)?;
    let radius = args.try_f64("radius", 0.05)?;
    let mut rng = qostream::common::Rng::new(args.try_u64("seed", 7)?);
    let observers: Vec<qostream::observer::QuantizationObserver> = (0..engine.f)
        .map(|f| {
            let mut qo = qostream::observer::QuantizationObserver::with_radius(radius);
            for _ in 0..n {
                let x = rng.normal(0.0, 1.0);
                let y = (f as f64 + 1.0) * x.powi(2) + rng.normal(0.0, 0.1);
                qo.observe(x, y, 1.0);
            }
            qo
        })
        .collect();
    let refs: Vec<&qostream::observer::QuantizationObserver> = observers.iter().collect();
    let (secs, results) = qostream::common::timing::time_once(|| {
        engine.best_splits_for_observers(&refs).expect("xla eval")
    });
    println!("evaluated {} features in {}", engine.f, human_time(secs));
    for (f, (qo, res)) in observers.iter().zip(&results).enumerate() {
        let native = qo.best_split(&VarianceReduction).unwrap();
        let xres = res.expect("split");
        println!(
            "  feature {f}: xla (c={:.4}, vr={:.4})  native (c={:.4}, vr={:.4})  agree={}",
            xres.threshold,
            xres.merit,
            native.threshold,
            native.merit,
            (xres.threshold - native.threshold).abs() < 1e-9
        );
    }
    Ok(())
}

/// `qostream fleet` — fleet-wide observability aggregation (see
/// [`qostream::serve::fleet`] and `docs/OBSERVABILITY.md`): discover a
/// leader's followers, scrape `health` + `metrics_raw` from every node,
/// merge the registries exactly, and either print the fleet exposition
/// once, serve it over HTTP for Prometheus (`--listen`), or render a
/// live per-node dashboard (`--top`).
fn cmd_fleet(args: &Args) -> Result<()> {
    let targets: Vec<String> = args
        .opt("targets")
        .ok_or_else(|| anyhow!("fleet needs --targets HOST:PORT[,HOST:PORT…]"))?
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect();
    if targets.is_empty() {
        bail!("--targets parsed to an empty list");
    }
    let auto_discover = !args.flag("no-discover");
    let resolve = |seeds: &[String]| -> Vec<String> {
        if auto_discover {
            fleet::discover(seeds)
        } else {
            seeds.to_vec()
        }
    };
    if let Some(listen) = args.opt("listen") {
        let listener = std::net::TcpListener::bind(listen)
            .with_context(|| format!("binding scrape endpoint {listen}"))?;
        println!(
            "fleet scrape endpoint on {} ({} seed target(s), discovery {})",
            listener.local_addr()?,
            targets.len(),
            if auto_discover { "on" } else { "off" }
        );
        fleet::serve_scrapes(listener, targets, auto_discover);
        return Ok(());
    }
    if args.flag("top") {
        let interval = args.try_ms("interval-ms", 1000)?;
        loop {
            let scrape = fleet::scrape_fleet(&resolve(&targets));
            if args.flag("once") {
                print!("{}", scrape.dashboard());
                return Ok(());
            }
            // clear + home, then redraw — a minimal terminal dashboard
            print!("\x1b[2J\x1b[H{}", scrape.dashboard());
            use std::io::Write;
            std::io::stdout().flush().ok();
            std::thread::sleep(interval);
        }
    }
    let scrape = fleet::scrape_fleet(&resolve(&targets));
    print!("{}", scrape.exposition());
    Ok(())
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_fig1(args)?;
    cmd_fig3(args)?;
    cmd_cd(args)?;
    cmd_tree(args)?;
    cmd_forest(args)?;
    Ok(())
}

const USAGE: &str = "\
qostream — Quantization Observer for online tree regressors (paper reproduction)

USAGE: qostream <subcommand> [options]

SUBCOMMANDS
  protocol     describe the Table 1 grid          [--profile quick|standard|full]
  fig1         merit/elements/time vs sample size [--profile --sizes --reps]
  fig3         split-point distance to E-BST      [--profile --sizes --reps]
  cd           Friedman/Nemenyi CD diagrams       [--metric merit|elements|observe|query|all]
  tree         Hoeffding-tree integration bench   [--instances N --seed S]
  forest       online ensembles vs single tree    [--instances N --members M --lambda L
               (bagging + ARF on drifting data,    --subspace all|sqrt|K --drift-at N --seed S
                batched split queries,             --split-backend per-observer|native-batch|xla
                sharded leader/worker fitting,     --parallel W --shards N --weighted-vote
                accuracy-weighted voting,          --mem-budget BYTES (governed demo)
                memory governance demo)            --observer qo|ebst (demo only)]
  coordinator  sharded distributed observation    [--shards N --instances N --radius R]
  serve        online learn/predict TCP server    [--port P --model tree|arf|bag --members N
               (NDJSON protocol, hot-swapped       --observer qo|ebst --snapshot-every K
                read snapshots, checkpoints,       --restore ckpt.json --checkpoint-out ckpt.json
                delta-checkpoint replication,      --shards N --shard-batch B --delta-history K
                sharded training, memory           --mem-budget BYTES (docs/MEMORY.md)
                governance;                        --follower-of HOST:PORT --poll-ms MS
                --bench runs the latency scenario, --bench [--replication] [--smoke
                --smoke writes/gates BENCH_ci.json) --out BENCH_ci.json --baseline FILE]]
  fleet        fleet-wide scrape aggregator       [--targets HOST:PORT[,...] --listen HOST:PORT
               (discovers followers via the        --top --interval-ms MS --once --no-discover]
                leader, merges node registries
                exactly; docs/OBSERVABILITY.md)
  checkpoint   save/restore model checkpoints     [--out ckpt.json | --load ckpt.json
               (JSON canonical; binary fast path   --format json|binary --convert OUT
                via docs/FORMATS.md)               --model --observer --members --instances N]
  audit        verify checkpoint invariants       [--checkpoint ckpt.json|ckpt.qosb
               (rule catalog: docs/INVARIANTS.md;  [--deltas FILE|DIR] --json | --self-check]
                JSON or binary, magic-sniffed)
  xla          AOT split-eval via PJRT artifacts  [--instances N --radius R]
  all          fig1 + fig3 + cd + tree + forest (standard profile)
";

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("protocol") => cmd_protocol(args),
        Some("fig1") => cmd_fig1(args),
        Some("fig3") => cmd_fig3(args),
        Some("cd") => cmd_cd(args),
        Some("tree") => cmd_tree(args),
        Some("forest") => cmd_forest(args),
        Some("coordinator") => cmd_coordinator(args),
        Some("serve") => cmd_serve(args),
        Some("fleet") => cmd_fleet(args),
        Some("checkpoint") => cmd_checkpoint(args),
        Some("audit") => cmd_audit(args),
        Some("xla") => cmd_xla(args),
        Some("all") => cmd_all(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        eprintln!();
        eprint!("{USAGE}");
        std::process::exit(2);
    }
}
