//! Sharded distributed forest: *member*-sharding over the leader/shard
//! bounded-channel machinery.
//!
//! [`crate::coordinator::leader`] shards the **data**: instances scatter
//! across workers and the per-feature observers merge losslessly (Chan
//! formulas). This module shards the **model**: ensemble members spread
//! across worker shards, every shard sees the *whole* stream (the leader
//! broadcasts instance batches over bounded `sync_channel`s — a full
//! channel blocks the leader, so a slow shard throttles ingestion instead
//! of ballooning memory), and each shard trains only its own members.
//!
//! The shard's natural unit of work is its members' batched split flush
//! ([`crate::forest::batch::flush_split_attempts`]): members train in
//! deferred-attempt mode and every due leaf across the shard resolves
//! through **one** [`SplitBackend`] round-trip per tick — the
//! one-call-per-tick protocol the ROADMAP's distributed-forest item asks
//! for, and the schedule a real PJRT backend amortizes its dispatch over.
//!
//! At vote time the leader broadcasts the probe batch; every shard ships
//! its members' votes back and the leader folds them **in global member
//! order** through [`fold_votes`]. Shipping pre-reduced per-shard Σs would
//! reassociate an IEEE sum, so the per-member votes travel instead and the
//! leader replays the exact sequential fold — which is why the merged
//! distributed vote, like the trained members themselves, is **bit-for-bit
//! identical** to the sequential ensemble (property-tested below across
//! shard counts, batch sizes and partitioners, and end-to-end in
//! `rust/tests/forest_e2e.rs`).
//!
//! Anything implementing [`ParallelEnsemble`] shards for free: ARF and
//! online bagging both do.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::forest::parallel::{broadcast_batches, ParallelEnsemble};
use crate::forest::vote::{fold_votes, fold_votes_weighted};
use crate::runtime::backend::SplitBackend;
use crate::stream::{Instance, Stream};

use super::shard::Partitioner;

/// Tuning knobs of the sharded forest fit.
#[derive(Clone, Copy, Debug)]
pub struct ForestCoordinatorConfig {
    /// Worker shards (clamped to the member count).
    pub n_shards: usize,
    /// Instances per broadcast message.
    pub batch_size: usize,
    /// Bounded channel depth in batches (backpressure window).
    pub channel_capacity: usize,
    /// Member → shard assignment policy. Any policy is bit-exact — member
    /// state never depends on which shard trains it — so the choice only
    /// affects load balance.
    pub partitioner: Partitioner,
}

impl Default for ForestCoordinatorConfig {
    fn default() -> ForestCoordinatorConfig {
        ForestCoordinatorConfig {
            n_shards: 4,
            batch_size: 256,
            channel_capacity: 8,
            partitioner: Partitioner::RoundRobin,
        }
    }
}

/// What the leader sends to a worker shard.
enum Request {
    /// Train every member of the shard on the batch — one split-backend
    /// round-trip per tick across the shard's members.
    Train(Arc<Vec<Instance>>),
    /// Vote on probe points; the shard replies with per-member votes.
    Vote(Arc<Vec<Vec<f64>>>),
}

/// A shard's per-member votes on one probe batch. Votes carry global
/// member indices so the leader can fold them in member order (see the
/// module docs for why pre-reduced Σs would break bit-equality).
struct VoteReply {
    /// Global member indices, in the shard's local order.
    members: Vec<usize>,
    /// Per-member trained flags, parallel to `members`.
    trained: Vec<bool>,
    /// Per-member recent errors, parallel to `members` (only consulted by
    /// ensembles folding with the accuracy-weighted vote).
    recent_errs: Vec<f64>,
    /// `preds[local_member][probe]`, parallel to `members`.
    preds: Vec<Vec<f64>>,
}

/// Outcome of a sharded forest fit.
#[derive(Clone, Debug)]
pub struct ShardedFitReport {
    pub instances: usize,
    pub seconds: f64,
    /// Shards actually spawned (hash partitioners may leave some empty).
    pub n_shards: usize,
    /// Members owned by each spawned shard.
    pub members_per_shard: Vec<usize>,
    /// Instances replayed by each shard (each sees the full stream).
    pub instances_per_shard: Vec<usize>,
    /// `SplitBackend::best_splits` round-trips per shard — at most one per
    /// tick, exactly one for every tick where a member had a due leaf.
    pub backend_calls_per_shard: Vec<usize>,
    /// Members with ≥ 1 trained instance per shard at the end of the run.
    pub trained_per_shard: Vec<usize>,
}

impl ShardedFitReport {
    pub fn throughput(&self) -> f64 {
        crate::common::timing::throughput(self.instances, self.seconds)
    }
}

/// Train `ensemble` on up to `max_instances` of `stream` with members
/// sharded across worker threads. Bit-for-bit identical to the sequential
/// learn loop (see module docs).
pub fn fit_sharded<E: ParallelEnsemble>(
    ensemble: &mut E,
    stream: &mut dyn Stream,
    max_instances: usize,
    config: ForestCoordinatorConfig,
) -> ShardedFitReport {
    fit_sharded_voting(ensemble, stream, max_instances, &[], config).0
}

/// Replay stream over a borrowed batch slice — the serve layer's
/// micro-batches ([`crate::serve`] drains its trainer queue into one of
/// these and pushes it through the sharded machinery).
struct BatchStream<'a> {
    items: &'a [Instance],
    pos: usize,
}

impl Stream for BatchStream<'_> {
    fn next_instance(&mut self) -> Option<Instance> {
        let inst = self.items.get(self.pos)?.clone();
        self.pos += 1;
        Some(inst)
    }

    fn n_features(&self) -> usize {
        self.items.first().map(|i| i.x.len()).unwrap_or(0)
    }

    fn name(&self) -> String {
        "batch".to_string()
    }
}

/// Train `ensemble` on one bounded batch with members sharded across
/// worker threads — the incremental entry point the serve layer uses to
/// front a sharded fleet from a long-lived trainer loop. Exactly
/// [`fit_sharded`] over a replay of `batch` (so it inherits the
/// bit-for-bit-sequential contract); each call spawns and joins its
/// scoped shard threads, so amortize by batching (the serve layer's
/// `shard_batch` knob).
pub fn train_batch_sharded<E: ParallelEnsemble>(
    ensemble: &mut E,
    batch: &[Instance],
    config: ForestCoordinatorConfig,
) -> Option<ShardedFitReport> {
    if batch.is_empty() {
        return None;
    }
    let mut stream = BatchStream { items: batch, pos: 0 };
    Some(fit_sharded(ensemble, &mut stream, batch.len(), config))
}

/// [`fit_sharded`], then answer `probes` through the distributed vote
/// protocol: shards compute their members' predictions in parallel and the
/// leader merges them into one prediction per probe — bit-for-bit what the
/// sequential ensemble's `predict` returns on the same model state.
pub fn fit_sharded_voting<E: ParallelEnsemble>(
    ensemble: &mut E,
    stream: &mut dyn Stream,
    max_instances: usize,
    probes: &[Vec<f64>],
    config: ForestCoordinatorConfig,
) -> (ShardedFitReport, Vec<f64>) {
    let backend = ensemble.split_backend();
    let weighted_vote = ensemble.weighted_vote();
    let members = ensemble.members_mut();
    let n_members = members.len();
    assert!(n_members >= 1, "cannot fit an empty ensemble");
    assert!(config.n_shards >= 1, "need at least one shard");
    let n_shards = config.n_shards.min(n_members);
    let batch_size = config.batch_size.max(1);
    let start = Instant::now();

    // member -> shard assignment; spawn only populated shards
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
    for (i, shard) in
        config.partitioner.assignment(n_members, n_shards).into_iter().enumerate()
    {
        assigned[shard].push(i);
    }
    let groups: Vec<Vec<usize>> =
        assigned.into_iter().filter(|group| !group.is_empty()).collect();
    let members_per_shard: Vec<usize> = groups.iter().map(Vec::len).collect();

    // disjoint &mut member handles, extracted by global index
    let mut slots: Vec<Option<&mut E::Member>> = members.iter_mut().map(Some).collect();

    let (sent, per_shard, backend_calls, trained, merged) =
        std::thread::scope(|scope| {
            let (reply_tx, reply_rx) = mpsc::channel::<VoteReply>();
            let mut senders: Vec<mpsc::SyncSender<Request>> = Vec::new();
            let mut handles = Vec::new();
            for idxs in groups {
                let (tx, rx) =
                    mpsc::sync_channel::<Request>(config.channel_capacity.max(1));
                senders.push(tx);
                let reply_tx = reply_tx.clone();
                let backend: Arc<dyn SplitBackend> = backend.clone();
                let mut mems: Vec<&mut E::Member> =
                    idxs.iter().map(|&i| slots[i].take().expect("member assigned twice")).collect();
                handles.push(scope.spawn(move || {
                    let mut count = 0usize;
                    let mut calls = 0usize;
                    while let Ok(request) = rx.recv() {
                        match request {
                            Request::Train(batch) => {
                                for inst in batch.iter() {
                                    for m in mems.iter_mut() {
                                        E::train_member(m, &inst.x, inst.y);
                                    }
                                    // the shard's unit of work: ONE backend
                                    // round-trip resolves every member's due
                                    // leaves this tick
                                    if E::flush_members(&mut mems, backend.as_ref()) {
                                        calls += 1;
                                    }
                                }
                                count += batch.len();
                            }
                            Request::Vote(probes) => {
                                let trained: Vec<bool> =
                                    mems.iter().map(|m| E::member_trained(m)).collect();
                                let recent_errs: Vec<f64> =
                                    mems.iter().map(|m| E::member_recent_err(m)).collect();
                                let preds: Vec<Vec<f64>> = mems
                                    .iter()
                                    .map(|m| {
                                        probes
                                            .iter()
                                            .map(|p| E::member_predict(m, p))
                                            .collect()
                                    })
                                    .collect();
                                reply_tx
                                    .send(VoteReply {
                                        members: idxs.clone(),
                                        trained,
                                        recent_errs,
                                        preds,
                                    })
                                    .expect("leader hung up mid-vote");
                            }
                        }
                    }
                    let trained =
                        mems.iter().map(|m| E::member_trained(m)).filter(|&t| t).count();
                    (count, calls, trained)
                }));
            }
            drop(reply_tx); // the leader only receives

            // leader loop: batch and broadcast (blocking on full channels),
            // shared with `fit_parallel`
            let sent = broadcast_batches(
                stream,
                max_instances,
                batch_size,
                &senders,
                Request::Train,
            );

            // distributed vote: collect every shard's member votes, then
            // fold them in global member order (bit-for-bit `predict`)
            let mut merged = Vec::with_capacity(probes.len());
            if !probes.is_empty() {
                let shared = Arc::new(probes.to_vec());
                for tx in &senders {
                    tx.send(Request::Vote(shared.clone())).expect("shard died");
                }
                let mut grid_preds: Vec<Vec<f64>> = vec![Vec::new(); n_members];
                let mut grid_trained: Vec<bool> = vec![false; n_members];
                let mut grid_errs: Vec<f64> = vec![0.0; n_members];
                for _ in 0..senders.len() {
                    let reply = reply_rx.recv().expect("shard died before voting");
                    for (((global, member_trained), member_err), member_preds) in reply
                        .members
                        .into_iter()
                        .zip(reply.trained)
                        .zip(reply.recent_errs)
                        .zip(reply.preds)
                    {
                        grid_trained[global] = member_trained;
                        grid_errs[global] = member_err;
                        grid_preds[global] = member_preds;
                    }
                }
                // replay the exact fold the sequential `predict` uses —
                // flat or accuracy-weighted — in global member order
                merged.extend((0..probes.len()).map(|p| {
                    if weighted_vote {
                        fold_votes_weighted((0..n_members).map(|m| {
                            (grid_preds[m][p], grid_trained[m], grid_errs[m])
                        }))
                    } else {
                        fold_votes(
                            (0..n_members).map(|m| (grid_preds[m][p], grid_trained[m])),
                        )
                    }
                }));
            }

            drop(senders); // close channels: shards drain and return
            let mut per_shard = Vec::with_capacity(handles.len());
            let mut backend_calls = Vec::with_capacity(handles.len());
            let mut trained = Vec::with_capacity(handles.len());
            for handle in handles {
                let (count, calls, shard_trained) =
                    handle.join().expect("shard panicked");
                per_shard.push(count);
                backend_calls.push(calls);
                trained.push(shard_trained);
            }
            (sent, per_shard, backend_calls, trained, merged)
        });

    (
        ShardedFitReport {
            instances: sent,
            seconds: start.elapsed().as_secs_f64(),
            n_shards: members_per_shard.len(),
            members_per_shard,
            instances_per_shard: per_shard,
            backend_calls_per_shard: backend_calls,
            trained_per_shard: trained,
        },
        merged,
    )
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;
    use crate::common::proptest::check;
    use crate::eval::Regressor;
    use crate::forest::{ArfOptions, ArfRegressor, OnlineBaggingRegressor};
    use crate::observer::{
        factory, ObserverFactory, QuantizationObserver, RadiusPolicy, SplitSuggestion,
    };
    use crate::runtime::backend::{NativeBatchBackend, SplitQuery};
    use crate::stream::Friedman1;
    use crate::tree::HtrOptions;

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn arf(members: usize, seed: u64) -> ArfRegressor {
        ArfRegressor::new(
            10,
            ArfOptions { n_members: members, lambda: 3.0, seed, ..Default::default() },
            qo_factory(),
        )
    }

    fn probe_points(n: usize) -> Vec<Vec<f64>> {
        let mut probe = Friedman1::new(0xBEEF, 0.0);
        (0..n).map(|_| probe.next_instance().unwrap().x).collect()
    }

    /// Backend wrapper counting `best_splits` round-trips.
    struct CountingBackend {
        inner: NativeBatchBackend,
        calls: AtomicUsize,
    }

    impl CountingBackend {
        fn new() -> CountingBackend {
            CountingBackend { inner: NativeBatchBackend, calls: AtomicUsize::new(0) }
        }
    }

    impl SplitBackend for CountingBackend {
        fn name(&self) -> &'static str {
            "counting"
        }

        fn best_splits(&self, queries: &[SplitQuery<'_>]) -> Vec<Option<SplitSuggestion>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.best_splits(queries)
        }
    }

    fn train_sequential(model: &mut dyn Regressor, seed: u64, n: usize) {
        let mut stream = Friedman1::new(seed, 1.0);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            model.learn_one(&inst.x, inst.y);
        }
    }

    #[test]
    fn sharded_arf_bit_identical_to_sequential() {
        let n = 4000;
        let mut sequential = arf(5, 7);
        train_sequential(&mut sequential, 11, n);

        let mut sharded = arf(5, 7);
        let probes = probe_points(50);
        let (report, merged) = fit_sharded_voting(
            &mut sharded,
            &mut Friedman1::new(11, 1.0),
            n,
            &probes,
            ForestCoordinatorConfig { n_shards: 3, batch_size: 64, ..Default::default() },
        );
        assert_eq!(report.instances, n);
        assert_eq!(report.n_shards, 3);
        assert_eq!(report.members_per_shard.iter().sum::<usize>(), 5);
        assert!(report.instances_per_shard.iter().all(|&c| c == n));
        assert_eq!(sequential.n_splits(), sharded.n_splits());
        assert_eq!(sequential.n_warnings(), sharded.n_warnings());
        assert_eq!(sequential.n_drifts(), sharded.n_drifts());

        // the leader-merged distributed vote IS the sequential predict
        for (x, &v) in probes.iter().zip(&merged) {
            assert_eq!(
                v.to_bits(),
                sequential.predict(x).to_bits(),
                "merged vote diverged at {x:?}"
            );
        }
        // and the reassembled sharded ensemble agrees member-for-member
        for x in &probes {
            assert_eq!(sharded.predict(x).to_bits(), sequential.predict(x).to_bits());
        }
    }

    #[test]
    fn sharded_weighted_vote_bit_identical_to_sequential() {
        // the leader must replay the *weighted* fold when the ensemble
        // votes by inverse recent error
        let n = 4000;
        let weighted_arf = |seed| {
            ArfRegressor::new(
                10,
                ArfOptions {
                    n_members: 4,
                    lambda: 3.0,
                    seed,
                    weighted_vote: true,
                    ..Default::default()
                },
                qo_factory(),
            )
        };
        let mut sequential = weighted_arf(13);
        train_sequential(&mut sequential, 17, n);

        let mut sharded = weighted_arf(13);
        let probes = probe_points(40);
        let (_, merged) = fit_sharded_voting(
            &mut sharded,
            &mut Friedman1::new(17, 1.0),
            n,
            &probes,
            ForestCoordinatorConfig { n_shards: 2, batch_size: 64, ..Default::default() },
        );
        for (x, &v) in probes.iter().zip(&merged) {
            assert_eq!(
                v.to_bits(),
                sequential.predict(x).to_bits(),
                "weighted merged vote diverged at {x:?}"
            );
        }
    }

    #[test]
    fn one_backend_round_trip_per_shard_per_tick() {
        let n = 3000;
        let counter = Arc::new(CountingBackend::new());
        let shared: Arc<dyn SplitBackend> = counter.clone();
        let mut sharded = arf(4, 3).with_split_backend(shared);
        let report = fit_sharded(
            &mut sharded,
            &mut Friedman1::new(5, 1.0),
            n,
            ForestCoordinatorConfig { n_shards: 2, batch_size: 32, ..Default::default() },
        );
        assert_eq!(report.n_shards, 2);
        // every round-trip the shards made went through the shared backend
        let total: usize = report.backend_calls_per_shard.iter().sum();
        assert_eq!(total, counter.calls.load(Ordering::Relaxed));
        // at most one round-trip per tick, and training actually queried
        for &calls in &report.backend_calls_per_shard {
            assert!(calls >= 1, "a shard never flushed: {report:?}");
            assert!(calls <= n, "more than one backend call per tick: {report:?}");
        }
        assert!(sharded.n_splits() >= 1, "forest never grew");
    }

    #[test]
    fn sharded_bagging_bit_identical_to_sequential() {
        let n = 3000;
        let mut sequential =
            OnlineBaggingRegressor::new(10, 6, 2.0, HtrOptions::default(), qo_factory(), 23);
        train_sequential(&mut sequential, 29, n);

        let mut sharded =
            OnlineBaggingRegressor::new(10, 6, 2.0, HtrOptions::default(), qo_factory(), 23);
        let probes = probe_points(40);
        let (report, merged) = fit_sharded_voting(
            &mut sharded,
            &mut Friedman1::new(29, 1.0),
            n,
            &probes,
            ForestCoordinatorConfig {
                n_shards: 4,
                batch_size: 17,
                channel_capacity: 2,
                partitioner: Partitioner::IndexHash,
            },
        );
        assert!((1..=4).contains(&report.n_shards));
        assert_eq!(report.members_per_shard.iter().sum::<usize>(), 6);
        for (x, &v) in probes.iter().zip(&merged) {
            assert_eq!(v.to_bits(), sequential.predict(x).to_bits());
        }
    }

    #[test]
    fn single_shard_and_oversubscription_work() {
        // 1 shard degenerates to the sequential schedule; 16 shards clamp
        // to the member count
        for shards in [1usize, 16] {
            let mut sequential = arf(3, 13);
            train_sequential(&mut sequential, 17, 1500);
            let mut sharded = arf(3, 13);
            let probes = probe_points(20);
            let (report, merged) = fit_sharded_voting(
                &mut sharded,
                &mut Friedman1::new(17, 1.0),
                1500,
                &probes,
                ForestCoordinatorConfig { n_shards: shards, ..Default::default() },
            );
            assert!(report.n_shards <= 3);
            for (x, &v) in probes.iter().zip(&merged) {
                assert_eq!(v.to_bits(), sequential.predict(x).to_bits());
            }
        }
    }

    #[test]
    fn tiny_channel_capacity_exercises_backpressure() {
        let mut sharded = arf(4, 19);
        let report = fit_sharded(
            &mut sharded,
            &mut Friedman1::new(2, 1.0),
            2000,
            ForestCoordinatorConfig {
                n_shards: 2,
                batch_size: 8,
                channel_capacity: 1,
                ..Default::default()
            },
        );
        assert_eq!(report.instances, 2000);
    }

    #[test]
    fn prop_sharded_forest_identical_across_configs() {
        // the acceptance property: across shard counts, batch sizes and
        // partitioners, the sharded forest (trained members AND the
        // leader-merged distributed vote) is bit-for-bit the sequential
        // ensemble
        check("sharded-forest-vs-sequential", 0x5A4D, 6, |rng| {
            let n = 800 + rng.below(1200) as usize;
            let members = 2 + rng.below(5) as usize;
            let seed = rng.next_u64();
            let stream_seed = rng.next_u64();
            let config = ForestCoordinatorConfig {
                n_shards: 1 + rng.below(6) as usize,
                batch_size: 1 + rng.below(96) as usize,
                channel_capacity: 1 + rng.below(8) as usize,
                partitioner: if rng.bool(0.5) {
                    Partitioner::RoundRobin
                } else {
                    Partitioner::IndexHash
                },
            };

            let mut sequential = arf(members, seed);
            train_sequential(&mut sequential, stream_seed, n);

            let mut sharded = arf(members, seed);
            let probes = probe_points(10);
            let (report, merged) = fit_sharded_voting(
                &mut sharded,
                &mut Friedman1::new(stream_seed, 1.0),
                n,
                &probes,
                config,
            );
            if report.instances != n {
                return Err(format!("trained {} of {n}", report.instances));
            }
            if sequential.n_splits() != sharded.n_splits() {
                return Err(format!(
                    "splits {} vs {}",
                    sharded.n_splits(),
                    sequential.n_splits()
                ));
            }
            for (x, &v) in probes.iter().zip(&merged) {
                let want = sequential.predict(x);
                if v.to_bits() != want.to_bits() {
                    return Err(format!("vote {v} != sequential {want} ({config:?})"));
                }
            }
            Ok(())
        });
    }
}
