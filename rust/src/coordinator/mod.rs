//! Sharded streaming coordinators.
//!
//! The paper's Sec. 3 exists to make target statistics *mergeable and
//! subtractable* (Chan et al. parallel formulas); the QO hash inherits
//! that property slot-by-slot. Both runtimes in this module exploit it,
//! but they shard along different axes:
//!
//! * **Observer sharding** ([`leader`], [`shard`]) is *data-parallel*: the
//!   leader scatters instances across worker shards, each shard maintains
//!   its own per-feature Quantization Observers over its slice of the
//!   stream, and at query time the leader merges the partial hashes
//!   losslessly — the merged observer is numerically equivalent (~1e-12)
//!   to one observer having seen the whole stream. Correct for any
//!   partition of the *instances* because the statistics merge exactly.
//!
//! * **Member sharding** ([`forest`]) is *model-parallel*: the leader
//!   **broadcasts** every instance batch to all shards, each shard owns a
//!   disjoint subset of ensemble *members* and trains only those, and the
//!   leader folds the shards' per-member votes into the ensemble
//!   prediction. Correct for any partition of the *members* because member
//!   updates are independent — which also makes the result **bit-for-bit**
//!   identical to the sequential ensemble, not merely numerically close.
//!   Each shard resolves all of its members' due split attempts through
//!   one [`crate::runtime::backend::SplitBackend`] round-trip per tick.
//!
//! Both run on the same bounded-`sync_channel` backpressure machinery: a
//! full channel blocks the leader, so a slow shard throttles ingestion
//! instead of ballooning memory. This is the L3 distributed runtime — the
//! same two patterns scale QO-backed trees and forests across cores or
//! machines.

pub mod forest;
pub mod leader;
pub mod shard;

pub use forest::{
    fit_sharded, fit_sharded_voting, train_batch_sharded, ForestCoordinatorConfig,
    ShardedFitReport,
};
pub use leader::{CoordinatorConfig, CoordinatorReport, ShardedObserverCoordinator};
pub use shard::Partitioner;
