//! Sharded streaming coordinator.
//!
//! The paper's Sec. 3 exists to make target statistics *mergeable and
//! subtractable* (Chan et al. parallel formulas); the QO hash inherits
//! that property slot-by-slot. This module exploits it: a leader thread
//! fans the stream out to worker shards over bounded channels
//! (backpressure), each shard maintains its own per-feature Quantization
//! Observers, and at query time the leader merges the partial hashes
//! losslessly — the merged observer is *bit-for-bit equivalent in
//! expectation* (and numerically equivalent to ~1e-12) to one observer
//! having seen the whole stream.
//!
//! This is the L3 "distributed attribute observation" runtime: the same
//! pattern scales QO-backed trees across cores or machines.

pub mod leader;
pub mod shard;

pub use leader::{CoordinatorConfig, CoordinatorReport, ShardedObserverCoordinator};
pub use shard::Partitioner;
