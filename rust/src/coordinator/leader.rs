//! Leader/worker runtime for distributed attribute observation — the
//! *observer-sharding* (data-parallel) half of [`crate::coordinator`]; the
//! member-sharding (model-parallel) forest runtime lives in
//! [`super::forest`].
//!
//! The leader owns the stream, batches instances, and pushes batches to
//! worker shards over **bounded** channels (`std::sync::mpsc::sync_channel`)
//! — a full channel blocks the leader, which is the backpressure policy: a
//! slow shard throttles ingestion instead of ballooning memory. Workers
//! maintain one fixed-radius [`QuantizationObserver`] per feature; when
//! the stream ends the leader joins the workers and merges all partial
//! hashes (Chan formulas) into one observer per feature.

use std::sync::mpsc;
use std::time::Instant;

use crate::criterion::SplitCriterion;
use crate::observer::qo::QuantizationObserver;
use crate::observer::{AttributeObserver, SplitSuggestion};
use crate::stream::{Instance, Stream};

use super::shard::Partitioner;

/// Coordinator tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub n_shards: usize,
    /// Instances per message (amortizes channel overhead).
    pub batch_size: usize,
    /// Bounded channel depth in *batches* (backpressure window).
    pub channel_capacity: usize,
    /// Fixed quantization radius shared by every shard (a shared grid is
    /// what makes the partial hashes mergeable).
    pub radius: f64,
    pub partitioner: Partitioner,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            n_shards: 4,
            batch_size: 256,
            channel_capacity: 8,
            radius: 0.1,
            partitioner: Partitioner::RoundRobin,
        }
    }
}

/// Result of a coordinated observation run.
pub struct CoordinatorReport {
    /// One merged observer per feature (equivalent to single-threaded
    /// observation of the whole stream).
    pub merged: Vec<QuantizationObserver>,
    /// Instances processed per shard.
    pub per_shard: Vec<usize>,
    pub instances: usize,
    pub seconds: f64,
}

impl CoordinatorReport {
    /// Best split per feature over the merged observers.
    pub fn best_splits(&self, criterion: &dyn SplitCriterion) -> Vec<Option<SplitSuggestion>> {
        self.merged.iter().map(|qo| qo.best_split(criterion)).collect()
    }
}

/// The sharded observer coordinator (see module docs).
pub struct ShardedObserverCoordinator {
    n_features: usize,
    config: CoordinatorConfig,
}

impl ShardedObserverCoordinator {
    pub fn new(n_features: usize, config: CoordinatorConfig) -> ShardedObserverCoordinator {
        assert!(config.n_shards >= 1);
        assert!(config.batch_size >= 1);
        assert!(config.channel_capacity >= 1);
        assert!(config.radius > 0.0);
        ShardedObserverCoordinator { n_features, config }
    }

    /// Observe up to `max_instances` from `stream` across the shards and
    /// merge the partial observers.
    pub fn run(&self, stream: &mut dyn Stream, max_instances: usize) -> CoordinatorReport {
        let cfg = self.config;
        let n_features = self.n_features;
        let start = Instant::now();

        let result = std::thread::scope(|scope| {
            let mut senders: Vec<mpsc::SyncSender<Vec<Instance>>> = Vec::new();
            let mut handles = Vec::new();
            for _ in 0..cfg.n_shards {
                let (tx, rx) = mpsc::sync_channel::<Vec<Instance>>(cfg.channel_capacity);
                senders.push(tx);
                handles.push(scope.spawn(move || {
                    let mut observers: Vec<QuantizationObserver> = (0..n_features)
                        .map(|_| QuantizationObserver::with_radius(cfg.radius))
                        .collect();
                    let mut count = 0usize;
                    while let Ok(batch) = rx.recv() {
                        for inst in &batch {
                            for (f, qo) in observers.iter_mut().enumerate() {
                                qo.observe(inst.x[f], inst.y, 1.0);
                            }
                            count += 1;
                        }
                    }
                    (observers, count)
                }));
            }

            // leader loop: batch, route, push (blocking on full channels)
            let mut batches: Vec<Vec<Instance>> =
                (0..cfg.n_shards).map(|_| Vec::with_capacity(cfg.batch_size)).collect();
            let mut sent = 0usize;
            while sent < max_instances {
                let Some(inst) = stream.next_instance() else { break };
                let shard = cfg.partitioner.shard_of(sent as u64, cfg.n_shards);
                batches[shard].push(inst);
                sent += 1;
                if batches[shard].len() >= cfg.batch_size {
                    let full = std::mem::replace(
                        &mut batches[shard],
                        Vec::with_capacity(cfg.batch_size),
                    );
                    senders[shard].send(full).expect("worker died");
                }
            }
            for (shard, batch) in batches.into_iter().enumerate() {
                if !batch.is_empty() {
                    senders[shard].send(batch).expect("worker died");
                }
            }
            drop(senders); // close channels: workers drain and return

            let mut merged: Vec<QuantizationObserver> = (0..n_features)
                .map(|_| QuantizationObserver::with_radius(cfg.radius))
                .collect();
            let mut per_shard = Vec::with_capacity(cfg.n_shards);
            for handle in handles {
                let (observers, count) = handle.join().expect("worker panicked");
                per_shard.push(count);
                for (f, qo) in observers.iter().enumerate() {
                    merged[f].merge_from(qo);
                }
            }
            (merged, per_shard, sent)
        });

        let (merged, per_shard, instances) = result;
        CoordinatorReport { merged, per_shard, instances, seconds: start.elapsed().as_secs_f64() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::{check, expect_close};
    use crate::criterion::VarianceReduction;
    use crate::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};

    fn test_stream(seed: u64) -> SyntheticRegression {
        SyntheticRegression::new(
            Distribution::Normal { mu: 0.0, sigma: 1.0 },
            TargetFn::Cubic,
            NoiseSpec::NONE,
            3,
            seed,
        )
    }

    fn single_threaded_reference(seed: u64, n: usize, radius: f64) -> Vec<QuantizationObserver> {
        let mut stream = test_stream(seed);
        let mut observers: Vec<QuantizationObserver> =
            (0..3).map(|_| QuantizationObserver::with_radius(radius)).collect();
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            for (f, qo) in observers.iter_mut().enumerate() {
                qo.observe(inst.x[f], inst.y, 1.0);
            }
        }
        observers
    }

    #[test]
    fn merged_equals_single_threaded() {
        let n = 10_000;
        let radius = 0.25;
        let coordinator = ShardedObserverCoordinator::new(
            3,
            CoordinatorConfig { n_shards: 4, radius, ..Default::default() },
        );
        let report = coordinator.run(&mut test_stream(123), n);
        assert_eq!(report.instances, n);
        assert_eq!(report.per_shard.iter().sum::<usize>(), n);

        let reference = single_threaded_reference(123, n, radius);
        for (f, (merged, single)) in report.merged.iter().zip(reference.iter()).enumerate() {
            assert_eq!(merged.n_elements(), single.n_elements(), "feature {f} slot count");
            assert!((merged.total().n - single.total().n).abs() < 1e-9);
            assert!(
                (merged.total().m2 - single.total().m2).abs() / single.total().m2 < 1e-9,
                "feature {f} m2"
            );
            let sm = merged.best_split(&VarianceReduction).unwrap();
            let ss = single.best_split(&VarianceReduction).unwrap();
            assert!((sm.threshold - ss.threshold).abs() < 1e-9, "feature {f} threshold");
            assert!((sm.merit - ss.merit).abs() < 1e-7 * ss.merit.abs().max(1.0), "feature {f}");
        }
    }

    #[test]
    fn round_robin_balances_shards() {
        let coordinator = ShardedObserverCoordinator::new(
            3,
            CoordinatorConfig { n_shards: 4, batch_size: 16, ..Default::default() },
        );
        let report = coordinator.run(&mut test_stream(9), 4096);
        for &c in &report.per_shard {
            assert_eq!(c, 1024);
        }
    }

    #[test]
    fn single_shard_works() {
        let coordinator = ShardedObserverCoordinator::new(
            3,
            CoordinatorConfig { n_shards: 1, ..Default::default() },
        );
        let report = coordinator.run(&mut test_stream(5), 1000);
        assert_eq!(report.per_shard, vec![1000]);
        assert!(report.best_splits(&VarianceReduction)[0].is_some());
    }

    #[test]
    fn tiny_channel_capacity_exercises_backpressure() {
        // capacity-1 channels force the leader to block on the workers
        let coordinator = ShardedObserverCoordinator::new(
            3,
            CoordinatorConfig {
                n_shards: 2,
                batch_size: 8,
                channel_capacity: 1,
                ..Default::default()
            },
        );
        let report = coordinator.run(&mut test_stream(31), 5000);
        assert_eq!(report.instances, 5000);
    }

    #[test]
    fn prop_sharding_preserves_totals() {
        check("coordinator-totals", 0xD0, 10, |rng| {
            let n = 500 + rng.below(2000) as usize;
            let shards = 1 + rng.below(6) as usize;
            let seed = rng.next_u64();
            let coordinator = ShardedObserverCoordinator::new(
                3,
                CoordinatorConfig {
                    n_shards: shards,
                    batch_size: 1 + rng.below(64) as usize,
                    radius: 0.3,
                    partitioner: if rng.bool(0.5) {
                        Partitioner::RoundRobin
                    } else {
                        Partitioner::IndexHash
                    },
                    ..Default::default()
                },
            );
            let report = coordinator.run(&mut test_stream(seed), n);
            let reference = single_threaded_reference(seed, n, 0.3);
            for (merged, single) in report.merged.iter().zip(reference.iter()) {
                if merged.n_elements() != single.n_elements() {
                    return Err(format!(
                        "slot count {} vs {}",
                        merged.n_elements(),
                        single.n_elements()
                    ));
                }
                expect_close("total n", merged.total().n, single.total().n, 0.0, 1e-9)?;
                expect_close("total mean", merged.total().mean, single.total().mean, 1e-9, 1e-9)?;
                expect_close("total m2", merged.total().m2, single.total().m2, 1e-8, 1e-8)?;
            }
            Ok(())
        });
    }
}
