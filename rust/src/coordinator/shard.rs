//! Shard assignment policies.
//!
//! Because the per-slot statistics merge exactly (Chan et al.), *any*
//! partition of the stream yields the same merged observer — the policy
//! only affects load balance and channel contention. The same policies
//! assign ensemble *members* to shards in [`super::forest`], where any
//! partition is bit-exact because member updates are independent.

/// How instances (or forest members, in [`super::forest`]) are assigned to
/// worker shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// t-th instance goes to shard t mod n (perfect balance).
    RoundRobin,
    /// Hash of the instance index (decorrelates shard and stream phase —
    /// relevant under concept drift).
    IndexHash,
}

impl Partitioner {
    #[inline]
    pub fn shard_of(&self, index: u64, n_shards: usize) -> usize {
        match self {
            Partitioner::RoundRobin => (index % n_shards as u64) as usize,
            Partitioner::IndexHash => {
                let mut s = index;
                (crate::common::rng::splitmix64(&mut s) % n_shards as u64) as usize
            }
        }
    }

    /// Assign `n_items` items (instances, forest members) to `n_shards`
    /// shards up front: `result[i]` is item i's shard. Convenience over
    /// [`Self::shard_of`] for callers that partition a known-size set once,
    /// like the member assignment in [`super::forest`].
    pub fn assignment(&self, n_items: usize, n_shards: usize) -> Vec<usize> {
        (0..n_items).map(|i| self.shard_of(i as u64, n_shards)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_balanced() {
        let mut counts = [0usize; 4];
        for i in 0..1000 {
            counts[Partitioner::RoundRobin.shard_of(i, 4)] += 1;
        }
        assert_eq!(counts, [250, 250, 250, 250]);
    }

    #[test]
    fn assignment_matches_shard_of() {
        for partitioner in [Partitioner::RoundRobin, Partitioner::IndexHash] {
            let assigned = partitioner.assignment(64, 5);
            assert_eq!(assigned.len(), 64);
            for (i, &s) in assigned.iter().enumerate() {
                assert_eq!(s, partitioner.shard_of(i as u64, 5));
                assert!(s < 5);
            }
        }
    }

    #[test]
    fn hash_is_roughly_balanced_and_deterministic() {
        let mut counts = [0usize; 4];
        for i in 0..10_000 {
            let s = Partitioner::IndexHash.shard_of(i, 4);
            assert_eq!(s, Partitioner::IndexHash.shard_of(i, 4));
            counts[s] += 1;
        }
        for c in counts {
            assert!((c as i64 - 2500).abs() < 300, "{counts:?}");
        }
    }
}
