//! Shard assignment policies.
//!
//! Because the per-slot statistics merge exactly (Chan et al.), *any*
//! partition of the stream yields the same merged observer — the policy
//! only affects load balance and channel contention.

/// How instances are assigned to worker shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// t-th instance goes to shard t mod n (perfect balance).
    RoundRobin,
    /// Hash of the instance index (decorrelates shard and stream phase —
    /// relevant under concept drift).
    IndexHash,
}

impl Partitioner {
    #[inline]
    pub fn shard_of(&self, index: u64, n_shards: usize) -> usize {
        match self {
            Partitioner::RoundRobin => (index % n_shards as u64) as usize,
            Partitioner::IndexHash => {
                let mut s = index;
                (crate::common::rng::splitmix64(&mut s) % n_shards as u64) as usize
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_balanced() {
        let mut counts = [0usize; 4];
        for i in 0..1000 {
            counts[Partitioner::RoundRobin.shard_of(i, 4)] += 1;
        }
        assert_eq!(counts, [250, 250, 250, 250]);
    }

    #[test]
    fn hash_is_roughly_balanced_and_deterministic() {
        let mut counts = [0usize; 4];
        for i in 0..10_000 {
            let s = Partitioner::IndexHash.shard_of(i, 4);
            assert_eq!(s, Partitioner::IndexHash.shard_of(i, 4));
            counts[s] += 1;
        }
        for c in counts {
            assert!((c as i64 - 2500).abs() < 300, "{counts:?}");
        }
    }
}
