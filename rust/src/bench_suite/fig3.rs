//! Figure 3: average absolute difference between each observer's chosen
//! split point and E-BST's, per task and sample size — how close the
//! approximate observers land to the exact search.

use std::collections::BTreeMap;

use crate::common::plot::{render_chart, Series};
use crate::common::table::{fnum, Table};
use crate::observer::paper_lineup;

use super::protocol::Protocol;
use super::report::Report;
use super::runner::{cell_sample, run_cell_on_sample};

/// (task, observer, size) -> (Σ|c − c_ebst|, count)
type DiffMap = BTreeMap<(String, String, usize), (f64, usize)>;

/// Compute the split-point differences across a protocol.
pub fn run_diffs(protocol: &Protocol, progress: bool) -> DiffMap {
    let lineup = paper_lineup();
    let mut map: DiffMap = BTreeMap::new();
    let cells = protocol.cells();
    for (i, cell) in cells.iter().enumerate() {
        let sample = cell_sample(cell);
        let reference = run_cell_on_sample(lineup[0].as_ref(), cell, &sample); // E-BST
        if !reference.split_point.is_finite() {
            continue;
        }
        for fac in lineup.iter().skip(1) {
            let r = run_cell_on_sample(fac.as_ref(), cell, &sample);
            if !r.split_point.is_finite() {
                continue;
            }
            let key = (r.task.to_string(), r.observer.clone(), r.size);
            let e = map.entry(key).or_insert((0.0, 0));
            e.0 += (r.split_point - reference.split_point).abs();
            e.1 += 1;
        }
        if progress && (i + 1) % 200 == 0 {
            eprintln!("  fig3: {}/{} cells", i + 1, cells.len());
        }
    }
    map
}

/// Render Figure 3 and write `results/fig3/`.
pub fn generate(protocol: &Protocol, progress: bool) -> anyhow::Result<String> {
    let map = run_diffs(protocol, progress);
    let report = Report::create("fig3")?;
    let observers: Vec<String> =
        paper_lineup().iter().skip(1).map(|f| f.name()).collect();
    let mut rendered = String::new();
    for task in ["lin", "cub"] {
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> =
                map.keys().filter(|(t, _, _)| t == task).map(|(_, _, z)| *z).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        let mut table = Table::new({
            let mut h = vec!["size".to_string()];
            h.extend(observers.iter().cloned());
            h
        });
        let mut series_list = Vec::new();
        for ao in &observers {
            let mut series = Series::new(ao.clone());
            for &size in &sizes {
                if let Some((sum, n)) = map.get(&(task.to_string(), ao.clone(), size)) {
                    series.push(size as f64, sum / *n as f64);
                }
            }
            series_list.push(series);
        }
        for &size in &sizes {
            let mut row = vec![size.to_string()];
            for ao in &observers {
                let v = map
                    .get(&(task.to_string(), ao.clone(), size))
                    .map(|(s, n)| s / *n as f64)
                    .unwrap_or(f64::NAN);
                row.push(fnum(v));
            }
            table.row(row);
        }
        let title = format!("Figure 3 [{task}] |split - E-BST split| vs sample size");
        rendered.push_str(&render_chart(&title, &series_list, 64, 12, true, true));
        rendered.push('\n');
        report.write_table(&format!("{task}_splitdiff"), &table)?;
    }
    report.write_text("charts.txt", &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::protocol::Profile;

    #[test]
    fn diffs_shrink_with_radius() {
        // On the standard-scale settings QO_0.01 must land closer to
        // E-BST than QO_s2 on average (paper Sec. 6.1 / Fig 3).
        let protocol =
            Protocol::new(Profile::Quick).with_sizes(vec![2500]).with_repetitions(2);
        let map = run_diffs(&protocol, false);
        let avg = |ao: &str| -> f64 {
            let mut sum = 0.0;
            let mut n = 0usize;
            for ((_, a, _), (s, c)) in &map {
                if a == ao {
                    sum += s;
                    n += c;
                }
            }
            sum / n as f64
        };
        let d_fixed = avg("QO_0.01");
        let d_s2 = avg("QO_s2");
        assert!(
            d_fixed < d_s2,
            "QO_0.01 diff {d_fixed} should be < QO_s2 diff {d_s2}"
        );
        // and TE-BST is nearly exact
        assert!(avg("TE-BST") < d_fixed.max(1e-4), "tebst={}", avg("TE-BST"));
    }

    #[test]
    fn generate_writes_report() {
        let protocol =
            Protocol::new(Profile::Quick).with_sizes(vec![200]).with_repetitions(1);
        let rendered = generate(&protocol, false).unwrap();
        assert!(rendered.contains("Figure 3 [lin]"));
        assert!(std::path::Path::new("results/fig3/lin_splitdiff.csv").exists());
    }
}
