//! Figure 1: per-task (lin/cub) averages of the four metrics vs sample
//! size, one series per attribute observer — the paper's headline chart.

use std::collections::BTreeMap;

use crate::common::json::Json;
use crate::common::plot::{render_chart, Series};
use crate::common::table::{fnum, Table};
use crate::observer::paper_lineup;

use super::protocol::Protocol;
use super::report::Report;
use super::runner::{cell_sample, run_cell_on_sample, CellResult};

/// All raw cell results for the protocol × the paper's observer lineup.
pub fn run_protocol(protocol: &Protocol, progress: bool) -> Vec<CellResult> {
    let lineup = paper_lineup();
    let cells = protocol.cells();
    let mut results = Vec::with_capacity(cells.len() * lineup.len());
    for (i, cell) in cells.iter().enumerate() {
        // generate once, share across observers (paper: same sample per AO)
        let sample = cell_sample(cell);
        for fac in &lineup {
            results.push(run_cell_on_sample(fac.as_ref(), cell, &sample));
        }
        if progress && (i + 1) % 200 == 0 {
            eprintln!("  fig1: {}/{} cells", i + 1, cells.len());
        }
    }
    results
}

/// (task, observer, size) -> mean metric value.
type SeriesMap = BTreeMap<(String, String, usize), (f64, usize)>;

fn accumulate(results: &[CellResult], metric: impl Fn(&CellResult) -> f64) -> SeriesMap {
    let mut map: SeriesMap = BTreeMap::new();
    for r in results {
        let key = (r.task.to_string(), r.observer.clone(), r.size);
        let entry = map.entry(key).or_insert((0.0, 0));
        entry.0 += metric(r);
        entry.1 += 1;
    }
    map
}

/// The four Figure 1 metric rows.
pub const METRICS: &[(&str, bool)] = &[
    // (name, log-scale-y like the paper's lower three rows)
    ("vr", false),
    ("elements", true),
    ("observe_s", true),
    ("query_s", true),
];

fn metric_value(name: &str, r: &CellResult) -> f64 {
    match name {
        "vr" => r.merit,
        "elements" => r.elements as f64,
        "observe_s" => r.observe_seconds,
        "query_s" => r.query_seconds,
        _ => unreachable!(),
    }
}

/// Render Figure 1 and write `results/fig1/`.
pub fn generate(protocol: &Protocol, progress: bool) -> anyhow::Result<String> {
    let results = run_protocol(protocol, progress);
    let report = Report::create("fig1")?;
    let mut rendered = String::new();

    // raw dump for external plotting
    let mut raw = Table::new(vec![
        "observer", "dataset", "size", "task", "rep", "vr", "split", "elements", "observe_s",
        "query_s",
    ]);
    for r in &results {
        raw.row(vec![
            r.observer.clone(),
            r.dataset_key.clone(),
            r.size.to_string(),
            r.task.to_string(),
            r.repetition.to_string(),
            format!("{:.6e}", r.merit),
            format!("{:.6e}", r.split_point),
            r.elements.to_string(),
            format!("{:.6e}", r.observe_seconds),
            format!("{:.6e}", r.query_seconds),
        ]);
    }
    report.write_text("raw.csv", &raw.to_csv())?;

    let observers: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
    for task in ["lin", "cub"] {
        for &(metric, log_y) in METRICS {
            let acc = accumulate(&results, |r| metric_value(metric, r));
            let mut series_list = Vec::new();
            let mut table = Table::new({
                let mut h = vec!["size".to_string()];
                h.extend(observers.iter().cloned());
                h
            });
            let sizes: Vec<usize> = {
                let mut s: Vec<usize> = acc
                    .keys()
                    .filter(|(t, _, _)| t == task)
                    .map(|(_, _, size)| *size)
                    .collect();
                s.sort_unstable();
                s.dedup();
                s
            };
            for ao in &observers {
                let mut series = Series::new(ao.clone());
                for &size in &sizes {
                    if let Some((sum, count)) =
                        acc.get(&(task.to_string(), ao.clone(), size))
                    {
                        series.push(size as f64, sum / *count as f64);
                    }
                }
                series_list.push(series);
            }
            for &size in &sizes {
                let mut row = vec![size.to_string()];
                for ao in &observers {
                    let v = acc
                        .get(&(task.to_string(), ao.clone(), size))
                        .map(|(s, c)| s / *c as f64)
                        .unwrap_or(f64::NAN);
                    row.push(fnum(v));
                }
                table.row(row);
            }
            let title = format!("Figure 1 [{task}] {metric} vs sample size");
            let chart = render_chart(&title, &series_list, 64, 14, true, log_y);
            rendered.push_str(&chart);
            rendered.push('\n');
            report.write_table(&format!("{task}_{metric}"), &table)?;
        }
    }
    report.write_text("charts.txt", &rendered)?;

    // summary JSON
    let mut j = Json::obj();
    j.set("cells", results.len() / observers.len());
    j.set("observers", observers.clone());
    report.write_json("meta.json", &j)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::protocol::{Cell, Profile};
    use crate::stream::synth::{Distribution, TargetFn};

    #[test]
    fn accumulate_means_by_key() {
        let mk = |observer: &str, size: usize, merit: f64| CellResult {
            observer: observer.into(),
            dataset_key: "d".into(),
            size,
            task: "lin",
            repetition: 0,
            merit,
            split_point: 0.0,
            elements: 1,
            observe_seconds: 0.0,
            query_seconds: 0.0,
        };
        let rs = vec![mk("a", 100, 1.0), mk("a", 100, 3.0), mk("a", 200, 5.0)];
        let acc = accumulate(&rs, |r| r.merit);
        let (sum, count) = acc[&("lin".to_string(), "a".to_string(), 100)];
        assert_eq!((sum, count), (4.0, 2));
        let (sum, count) = acc[&("lin".to_string(), "a".to_string(), 200)];
        assert_eq!((sum, count), (5.0, 1));
    }

    #[test]
    fn tiny_protocol_generates_report() {
        let protocol = Protocol::new(Profile::Quick)
            .with_sizes(vec![100])
            .with_repetitions(1);
        let rendered = generate(&protocol, false).unwrap();
        assert!(rendered.contains("Figure 1 [lin] vr"));
        assert!(rendered.contains("Figure 1 [cub] query_s"));
        assert!(std::path::Path::new("results/fig1/raw.csv").exists());
    }

    #[test]
    fn cell_results_cover_all_observers() {
        let protocol = Protocol::new(Profile::Quick)
            .with_sizes(vec![50])
            .with_repetitions(1);
        let results = run_protocol(&protocol, false);
        let cells = protocol.cells().len();
        assert_eq!(results.len(), cells * 5);
        let _ = Cell {
            size: 50,
            dist: Distribution::Normal { mu: 0.0, sigma: 1.0 },
            target: TargetFn::Linear,
            noise_fraction: 0.0,
            repetition: 0,
        };
    }
}
