//! The paper's evaluation, regenerated.
//!
//! DESIGN.md §3 maps every table and figure to a module here:
//!
//! | Paper artifact | Module | CLI |
//! |---|---|---|
//! | Table 1 protocol | [`protocol`] | `qostream protocol --describe` |
//! | Figure 1 (VR / elements / observe / query vs size) | [`fig1`] | `qostream fig1` |
//! | Figure 2 (CD on merit) | [`cd`] | `qostream cd --metric merit` |
//! | Figure 3 (split-point diff vs E-BST) | [`fig3`] | `qostream fig3` |
//! | Figure 4 (CD on elements) | [`cd`] | `qostream cd --metric elements` |
//! | Figure 5 (CD on observe time) | [`cd`] | `qostream cd --metric observe` |
//! | Figure 6 (CD on query time) | [`cd`] | `qostream cd --metric query` |
//! | Sec. 7 tree integration | [`tree_bench`] | `qostream tree` |
//! | Forest extension (ensembles + drift) | [`forest_bench`] | `qostream forest` |
//! | Serving scenario (predict latency, learns/sec, checkpoint sizes) | [`serve_bench`] | `qostream serve --bench` |
//!
//! Results (CSV + JSON + ASCII charts) are written under `results/`.

pub mod cd;
pub mod fig1;
pub mod fig3;
pub mod forest_bench;
pub mod protocol;
pub mod report;
pub mod runner;
pub mod serve_bench;
pub mod tree_bench;

pub use protocol::{Cell, Profile, Protocol};
pub use runner::{run_cell, CellResult};
