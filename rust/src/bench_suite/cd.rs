//! Figures 2, 4, 5, 6: Friedman + Nemenyi critical-difference analysis of
//! the four metrics over the protocol grid (α = 0.05, as in the paper).
//!
//! Each (size, distribution, task, noise) combination is one "dataset";
//! repetitions are averaged before ranking (paper Sec. 5.1/6).

use std::collections::BTreeMap;

use crate::common::table::Table;
use crate::observer::paper_lineup;
use crate::stats::friedman::friedman_test;
use crate::stats::nemenyi::{nemenyi, render_cd_diagram};

use super::protocol::Protocol;
use super::report::Report;
use super::runner::CellResult;

/// The four CD metrics and their ranking direction.
/// merit: higher is better; the other three: lower is better.
pub const CD_METRICS: &[(&str, bool)] = &[
    ("merit", false),
    ("elements", true),
    ("observe", true),
    ("query", true),
];

fn metric_of(name: &str, r: &CellResult) -> f64 {
    match name {
        "merit" => r.merit,
        "elements" => r.elements as f64,
        "observe" => r.observe_seconds,
        "query" => r.query_seconds,
        _ => panic!("unknown metric {name}"),
    }
}

/// Build the (dataset × algorithm) measurement matrix for a metric,
/// averaging repetitions.
pub fn measurement_matrix(
    results: &[CellResult],
    metric: &str,
    observers: &[String],
) -> Vec<Vec<f64>> {
    // dataset -> observer -> (sum, n)
    let mut acc: BTreeMap<String, BTreeMap<String, (f64, usize)>> = BTreeMap::new();
    for r in results {
        let e = acc
            .entry(r.dataset_key.clone())
            .or_default()
            .entry(r.observer.clone())
            .or_insert((0.0, 0));
        e.0 += metric_of(metric, r);
        e.1 += 1;
    }
    acc.values()
        .filter(|per_obs| observers.iter().all(|o| per_obs.contains_key(o)))
        .map(|per_obs| {
            observers
                .iter()
                .map(|o| {
                    let (s, n) = per_obs[o];
                    s / n as f64
                })
                .collect()
        })
        .collect()
}

/// The paper figure number for each metric's CD diagram.
fn figure_of(metric: &str) -> &'static str {
    match metric {
        "merit" => "Figure 2",
        "elements" => "Figure 4",
        "observe" => "Figure 5",
        "query" => "Figure 6",
        _ => "?",
    }
}

/// Run the CD analysis for one metric over precomputed results.
pub fn analyze(results: &[CellResult], metric: &str) -> anyhow::Result<String> {
    let observers: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
    let (_, lower_better) = CD_METRICS
        .iter()
        .find(|(m, _)| *m == metric)
        .ok_or_else(|| anyhow::anyhow!("unknown metric {metric}"))?;
    let matrix = measurement_matrix(results, metric, &observers);
    anyhow::ensure!(matrix.len() >= 2, "need >= 2 datasets, got {}", matrix.len());
    let fr = friedman_test(&matrix, *lower_better);
    let ne = nemenyi(&fr, 0.05);

    let mut out = String::new();
    out.push_str(&format!(
        "{} — Friedman/Nemenyi on {metric} ({} datasets, {} algorithms)\n",
        figure_of(metric),
        fr.n_datasets,
        fr.n_algorithms
    ));
    out.push_str(&format!(
        "chi2_F = {:.3} (p = {:.3e}); F_F = {:.3} (p = {:.3e}); {}\n",
        fr.chi2,
        fr.p_chi2,
        fr.f_stat,
        fr.p_f,
        if fr.significant(0.05) { "SIGNIFICANT at a=0.05" } else { "not significant" }
    ));
    out.push_str(&render_cd_diagram(&observers, &ne));

    let mut table = Table::new(vec!["observer", "avg_rank"]);
    let mut order: Vec<usize> = (0..observers.len()).collect();
    order.sort_by(|&a, &b| fr.avg_ranks[a].partial_cmp(&fr.avg_ranks[b]).unwrap());
    for i in order {
        table.row(vec![observers[i].clone(), format!("{:.4}", fr.avg_ranks[i])]);
    }
    out.push_str(&table.render());
    Ok(out)
}

/// Generate all four CD diagrams and write `results/cd/`.
pub fn generate(protocol: &Protocol, progress: bool) -> anyhow::Result<String> {
    let results = super::fig1::run_protocol(protocol, progress);
    let report = Report::create("cd")?;
    let mut all = String::new();
    for (metric, _) in CD_METRICS {
        let text = analyze(&results, metric)?;
        report.write_text(&format!("{metric}.txt"), &text)?;
        all.push_str(&text);
        all.push('\n');
    }
    report.write_text("all.txt", &all)?;
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::protocol::Profile;
    use crate::bench_suite::runner::run_cell;

    fn small_results() -> Vec<CellResult> {
        let protocol =
            Protocol::new(Profile::Quick).with_sizes(vec![500, 1000]).with_repetitions(2);
        let lineup = paper_lineup();
        let mut out = Vec::new();
        for cell in protocol.cells() {
            for fac in &lineup {
                out.push(run_cell(fac.as_ref(), &cell));
            }
        }
        out
    }

    #[test]
    fn matrix_shape_and_rep_averaging() {
        let results = small_results();
        let observers: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
        let m = measurement_matrix(&results, "elements", &observers);
        // 2 sizes x 9 dists x 2 targets x 2 noise = 72 datasets
        assert_eq!(m.len(), 72);
        assert!(m.iter().all(|row| row.len() == 5));
    }

    #[test]
    fn element_ranks_match_paper_fig4_order() {
        // Fig 4: QO_s2 best rank, then QO_s3, QO_0.01, TE-BST, E-BST worst
        let results = small_results();
        let observers: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
        let m = measurement_matrix(&results, "elements", &observers);
        let fr = friedman_test(&m, true);
        let rank = |name: &str| {
            fr.avg_ranks[observers.iter().position(|o| o == name).unwrap()]
        };
        assert!(rank("QO_s2") < rank("QO_0.01"), "{:?}", fr.avg_ranks);
        assert!(rank("QO_0.01") < rank("TE-BST"), "{:?}", fr.avg_ranks);
        assert!(rank("TE-BST") < rank("E-BST"), "{:?}", fr.avg_ranks);
        assert!(fr.significant(0.05));
    }

    #[test]
    fn merit_ranks_favor_exhaustive_methods() {
        // Fig 2: E-BST & TE-BST rank above the QO variants on merit
        let results = small_results();
        let observers: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
        let m = measurement_matrix(&results, "merit", &observers);
        let fr = friedman_test(&m, false);
        let rank = |name: &str| {
            fr.avg_ranks[observers.iter().position(|o| o == name).unwrap()]
        };
        assert!(rank("E-BST") < rank("QO_s2"), "{:?}", fr.avg_ranks);
        assert!(rank("TE-BST") < rank("QO_s2"), "{:?}", fr.avg_ranks);
    }

    #[test]
    fn analyze_renders_diagram() {
        let results = small_results();
        let text = analyze(&results, "query").unwrap();
        assert!(text.contains("Figure 6"));
        assert!(text.contains("CD ="));
        assert!(text.contains("avg_rank"));
    }
}
