//! Run one protocol cell through one attribute observer, measuring the
//! paper's four metrics (Sec. 5.3): split merit (VR), stored elements,
//! observation time and query time.

use std::time::Instant;

use crate::criterion::VarianceReduction;
use crate::observer::ObserverFactory;
use crate::stream::synth::SyntheticRegression;
use crate::stream::Stream;

use super::protocol::Cell;

/// Metrics of one (cell, observer) run.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub observer: String,
    pub dataset_key: String,
    pub size: usize,
    pub task: &'static str,
    pub repetition: usize,
    /// Best split merit (VR) reported by the observer.
    pub merit: f64,
    /// Chosen split point (NaN if no split was possible).
    pub split_point: f64,
    /// Stored elements after the whole sample (nodes or slots).
    pub elements: usize,
    /// Seconds to monitor the whole sample.
    pub observe_seconds: f64,
    /// Seconds to produce the best split candidate.
    pub query_seconds: f64,
}

/// Generate the cell's sample once (single monitored feature, as in the
/// paper's AO-level protocol).
pub fn cell_sample(cell: &Cell) -> Vec<(f64, f64)> {
    let mut stream =
        SyntheticRegression::new(cell.dist, cell.target, cell.noise(), 1, cell.seed());
    (0..cell.size)
        .map(|_| {
            let inst = stream.next_instance().unwrap();
            (inst.x[0], inst.y)
        })
        .collect()
}

/// Run one observer over a pre-generated sample.
pub fn run_cell_on_sample(
    factory: &dyn ObserverFactory,
    cell: &Cell,
    sample: &[(f64, f64)],
) -> CellResult {
    let mut ao = factory.build();
    let start = Instant::now();
    for &(x, y) in sample {
        ao.observe(x, y, 1.0);
    }
    let observe_seconds = start.elapsed().as_secs_f64();

    let start = Instant::now();
    let suggestion = ao.best_split(&VarianceReduction);
    let query_seconds = start.elapsed().as_secs_f64();

    CellResult {
        observer: factory.name(),
        dataset_key: cell.dataset_key(),
        size: cell.size,
        task: cell.target.label(),
        repetition: cell.repetition,
        merit: suggestion.as_ref().map(|s| s.merit).unwrap_or(0.0),
        split_point: suggestion.as_ref().map(|s| s.threshold).unwrap_or(f64::NAN),
        elements: ao.n_elements(),
        observe_seconds,
        query_seconds,
    }
}

/// Convenience: generate the sample and run.
pub fn run_cell(factory: &dyn ObserverFactory, cell: &Cell) -> CellResult {
    let sample = cell_sample(cell);
    run_cell_on_sample(factory, cell, &sample)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observer::paper_lineup;
    use crate::stream::synth::{Distribution, TargetFn};

    fn cell() -> Cell {
        Cell {
            size: 2000,
            dist: Distribution::Normal { mu: 0.0, sigma: 1.0 },
            target: TargetFn::Linear,
            noise_fraction: 0.0,
            repetition: 0,
        }
    }

    #[test]
    fn sample_is_deterministic() {
        let c = cell();
        assert_eq!(cell_sample(&c), cell_sample(&c));
    }

    #[test]
    fn all_observers_produce_results() {
        let c = cell();
        let sample = cell_sample(&c);
        for fac in paper_lineup() {
            let r = run_cell_on_sample(fac.as_ref(), &c, &sample);
            assert!(r.merit > 0.0, "{}: merit {}", r.observer, r.merit);
            assert!(r.split_point.is_finite(), "{}", r.observer);
            assert!(r.elements > 0);
            assert!(r.observe_seconds >= 0.0 && r.query_seconds >= 0.0);
        }
    }

    #[test]
    fn ebst_stores_more_elements_than_qo() {
        let c = cell();
        let sample = cell_sample(&c);
        let lineup = paper_lineup();
        let ebst = run_cell_on_sample(lineup[0].as_ref(), &c, &sample);
        let qo = run_cell_on_sample(lineup[3].as_ref(), &c, &sample);
        assert!(qo.elements < ebst.elements / 10, "{} vs {}", qo.elements, ebst.elements);
    }
}
