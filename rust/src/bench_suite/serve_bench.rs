//! Serving scenario: predict latency and training throughput of the
//! [`crate::serve`] server under live load, plus the QO vs E-BST
//! checkpoint-size comparison (the paper's memory story, Sec. 5.3,
//! restated in bytes-on-the-wire).
//!
//! A background client streams Friedman #1 `learn`s over TCP while the
//! foreground client hammers `predict` and records per-request latency;
//! snapshot hot-swapping stays enabled throughout, so the p50/p99 numbers
//! include the swaps. Run via `qostream serve --bench`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::common::table::Table;
use crate::common::timing::human_time;
use crate::eval::Regressor;
use crate::forest::{ArfOptions, ArfRegressor};
use crate::persist::Model;
use crate::serve::{ServeClient, ServeOptions, Server};
use crate::stream::{Friedman1, Stream};
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::forest_bench::{ebst_factory, qo_factory};
use super::report::Report;

/// Scenario parameters (CLI-exposed via `qostream serve --bench`).
#[derive(Clone, Copy, Debug)]
pub struct ServeBenchConfig {
    /// Learns the background client streams.
    pub instances: usize,
    /// ARF members of the served model.
    pub members: usize,
    /// Applied learns between snapshot hot-swaps.
    pub snapshot_every: usize,
    /// Minimum predict-latency samples to collect.
    pub min_predict_samples: usize,
    pub seed: u64,
}

impl Default for ServeBenchConfig {
    fn default() -> ServeBenchConfig {
        ServeBenchConfig {
            instances: 5000,
            members: 5,
            snapshot_every: 500,
            min_predict_samples: 500,
            seed: 1,
        }
    }
}

/// Measured outcome of one serving run.
#[derive(Clone, Debug)]
pub struct ServeBenchResult {
    pub learns: usize,
    pub learn_seconds: f64,
    pub predict_samples: usize,
    pub predict_p50: f64,
    pub predict_p99: f64,
    pub snapshots: u64,
    /// (label, bytes, elements) for the checkpoint-size comparison.
    pub checkpoint_sizes: Vec<(String, usize, usize)>,
}

impl ServeBenchResult {
    pub fn learns_per_sec(&self) -> f64 {
        crate::common::timing::throughput(self.learns, self.learn_seconds)
    }
}

/// Percentile over raw samples (nearest-rank; `q` in [0, 1]).
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[rank - 1]
}

/// Drive one full serving scenario against a real TCP server on an
/// ephemeral port.
pub fn run(cfg: &ServeBenchConfig) -> Result<ServeBenchResult> {
    let model = Model::Arf(ArfRegressor::new(
        10,
        ArfOptions {
            n_members: cfg.members,
            lambda: 6.0,
            seed: cfg.seed,
            ..Default::default()
        },
        qo_factory(),
    ));
    let server = Server::start(
        model,
        "127.0.0.1:0",
        ServeOptions { snapshot_every: cfg.snapshot_every, ..Default::default() },
    )?;
    let addr = server.addr();

    // background client: stream learns as fast as the queue admits them
    let done = Arc::new(AtomicBool::new(false));
    let learner = {
        let done = done.clone();
        let (instances, seed) = (cfg.instances, cfg.seed);
        std::thread::spawn(move || -> Result<f64> {
            let out = (|| -> Result<f64> {
                let mut client = ServeClient::connect(addr)?;
                let mut stream = Friedman1::new(seed, 1.0);
                let start = Instant::now();
                for _ in 0..instances {
                    let inst = stream.next_instance().expect("endless stream");
                    client.learn(&inst.x, inst.y)?;
                }
                Ok(start.elapsed().as_secs_f64())
            })();
            // set on EVERY exit path: the foreground latency loop spins
            // until this flips, even when the learner fails
            done.store(true, Ordering::SeqCst);
            out
        })
    };

    // foreground client: predict latency while training runs
    let mut client = ServeClient::connect(addr)?;
    let mut probe = Friedman1::new(cfg.seed ^ 0x5EED, 0.0);
    let mut latencies = Vec::new();
    while !done.load(Ordering::SeqCst) || latencies.len() < cfg.min_predict_samples {
        let inst = probe.next_instance().expect("endless stream");
        let start = Instant::now();
        let p = client.predict(&inst.x)?;
        latencies.push(start.elapsed().as_secs_f64());
        debug_assert!(p.is_finite());
    }
    let learn_seconds = learner
        .join()
        .map_err(|_| anyhow!("learner thread panicked"))?
        .map_err(|e| e.context("background learner failed"))?;

    // force a final hot-swap (checkpoint through the full codec), read
    // the counters, then stop the server
    client.snapshot()?;
    let stats = client.stats()?;
    let snapshots = stats
        .get("snapshots")
        .and_then(crate::common::json::Json::as_f64)
        .unwrap_or(0.0) as u64;
    client.shutdown()?;
    server.join()?;

    let predict_samples = latencies.len();
    let mut sorted = latencies;
    let predict_p50 = percentile(&mut sorted, 0.50);
    let predict_p99 = percentile(&mut sorted, 0.99);

    Ok(ServeBenchResult {
        learns: cfg.instances,
        learn_seconds,
        predict_samples,
        predict_p50,
        predict_p99,
        snapshots,
        checkpoint_sizes: checkpoint_sizes(cfg)?,
    })
}

/// QO vs E-BST checkpoint bytes for the same tree on the same stream:
/// the paper's elements metric, restated as serialized model size.
fn checkpoint_sizes(cfg: &ServeBenchConfig) -> Result<Vec<(String, usize, usize)>> {
    let mut out = Vec::new();
    for factory in [qo_factory(), ebst_factory()] {
        let label = factory.name();
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), factory);
        let mut stream = Friedman1::new(cfg.seed, 1.0);
        for _ in 0..cfg.instances {
            let inst = stream.next_instance().expect("endless stream");
            tree.learn_one(&inst.x, inst.y);
        }
        let elements = tree.total_elements();
        let model = Model::Tree(tree);
        let bytes = model.to_text()?.len();
        out.push((format!("htr[{label}]"), bytes, elements));
    }
    Ok(out)
}

/// Render + persist under `results/serve/`.
pub fn generate(cfg: &ServeBenchConfig) -> Result<String> {
    let result = run(cfg)?;
    let mut out = String::new();
    out.push_str(&format!(
        "serving scenario: {} learns streamed over TCP, {}-member ARF, \
         snapshot hot-swap every {} learns\n",
        result.learns, cfg.members, cfg.snapshot_every
    ));
    out.push_str(&format!(
        "  learns/sec     : {:.1}k ({} in {})\n",
        result.learns_per_sec() / 1e3,
        result.learns,
        human_time(result.learn_seconds)
    ));
    out.push_str(&format!(
        "  predict latency: p50 {}  p99 {}  ({} samples, concurrent with training)\n",
        human_time(result.predict_p50),
        human_time(result.predict_p99),
        result.predict_samples
    ));
    out.push_str(&format!("  snapshots published: {}\n", result.snapshots));
    out.push_str("checkpoint sizes (same tree, same stream):\n");
    let mut table = Table::new(vec!["model", "checkpoint_bytes", "elements"]);
    for (label, bytes, elements) in &result.checkpoint_sizes {
        table.row(vec![label.clone(), bytes.to_string(), elements.to_string()]);
    }
    out.push_str(&table.render());

    let report = Report::create("serve")?;
    report.write_text("serve.txt", &out)?;
    let mut j = crate::common::json::Json::obj();
    j.set("learns", result.learns)
        .set("learn_seconds", result.learn_seconds)
        .set("learns_per_sec", result.learns_per_sec())
        .set("predict_p50_s", result.predict_p50)
        .set("predict_p99_s", result.predict_p99)
        .set("predict_samples", result.predict_samples)
        .set("snapshots", result.snapshots);
    let mut sizes = crate::common::json::Json::Arr(Vec::new());
    for (label, bytes, elements) in &result.checkpoint_sizes {
        let mut row = crate::common::json::Json::obj();
        row.set("model", label.as_str()).set("bytes", *bytes).set("elements", *elements);
        sizes.push(row);
    }
    j.set("checkpoint_sizes", sizes);
    report.write_json("serve.json", &j)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&mut xs, 0.5), 2.0);
        assert_eq!(percentile(&mut xs, 0.99), 4.0);
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }

    #[test]
    fn tiny_scenario_reports_sane_numbers() {
        // a real end-to-end run, sized for CI: the acceptance contract
        // (p50/p99 + learns/sec with hot-swap enabled) must hold
        let cfg = ServeBenchConfig {
            instances: 400,
            members: 2,
            snapshot_every: 100,
            min_predict_samples: 20,
            seed: 3,
        };
        let result = run(&cfg).expect("scenario must complete");
        assert_eq!(result.learns, 400);
        assert!(result.learn_seconds > 0.0);
        assert!(result.predict_samples >= 20);
        assert!(result.predict_p50 > 0.0);
        assert!(result.predict_p99 >= result.predict_p50);
        assert!(result.snapshots >= 1, "hot-swap never happened");
        assert_eq!(result.checkpoint_sizes.len(), 2);
        // the QO tree's checkpoint must undercut the E-BST tree's — the
        // paper's memory argument, in serialized bytes
        let (qo, ebst) = (&result.checkpoint_sizes[0], &result.checkpoint_sizes[1]);
        assert!(qo.0.contains("QO") && ebst.0.contains("E-BST"));
        assert!(
            qo.1 < ebst.1,
            "QO checkpoint ({} B) must be smaller than E-BST ({} B)",
            qo.1,
            ebst.1
        );
    }
}
