//! Serving scenario: predict latency and training throughput of the
//! [`crate::serve`] server under live load, plus the QO vs E-BST
//! checkpoint-size comparison (the paper's memory story, Sec. 5.3,
//! restated in bytes-on-the-wire).
//!
//! A background client streams Friedman #1 `learn`s over TCP while the
//! foreground client hammers `predict` and records per-request latency;
//! snapshot hot-swapping stays enabled throughout, so the p50/p99 numbers
//! include the swaps. Offline companions measure delta-vs-full checkpoint
//! bytes ([`delta_size_scenario`]), instrumentation overhead
//! ([`obs_overhead_scenario`]), and the snapshot publication cost —
//! codec round-trip vs structural clone, JSON vs binary bytes
//! ([`snapshot_cost_scenario`]). Run via `qostream serve --bench`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::common::table::Table;
use crate::common::timing::human_time;
use crate::eval::Regressor;
use crate::forest::{ArfOptions, ArfRegressor};
use crate::persist::delta::DeltaLog;
use crate::persist::Model;
use crate::serve::replicate::replication_lags;
use crate::serve::{Follower, FollowerOptions, ServeClient, ServeOptions, Server};
use crate::stream::{Friedman1, Stream};
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::forest_bench::{self, ebst_factory, qo_factory};
use super::report::Report;

/// Scenario parameters (CLI-exposed via `qostream serve --bench`).
#[derive(Clone, Copy, Debug)]
pub struct ServeBenchConfig {
    /// Learns the background client streams.
    pub instances: usize,
    /// ARF members of the served model.
    pub members: usize,
    /// Applied learns between snapshot hot-swaps.
    pub snapshot_every: usize,
    /// Minimum predict-latency samples to collect.
    pub min_predict_samples: usize,
    pub seed: u64,
}

impl Default for ServeBenchConfig {
    fn default() -> ServeBenchConfig {
        ServeBenchConfig {
            instances: 5000,
            members: 5,
            snapshot_every: 500,
            min_predict_samples: 500,
            seed: 1,
        }
    }
}

/// Measured outcome of one serving run.
#[derive(Clone, Debug)]
pub struct ServeBenchResult {
    pub learns: usize,
    pub learn_seconds: f64,
    pub predict_samples: usize,
    pub predict_p50: f64,
    pub predict_p99: f64,
    pub snapshots: u64,
    /// (label, bytes, elements) for the checkpoint-size comparison.
    pub checkpoint_sizes: Vec<(String, usize, usize)>,
}

impl ServeBenchResult {
    pub fn learns_per_sec(&self) -> f64 {
        crate::common::timing::throughput(self.learns, self.learn_seconds)
    }
}

/// Percentile over raw samples (nearest-rank; `q` in [0, 1]).
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[rank - 1]
}

/// Drive one full serving scenario against a real TCP server on an
/// ephemeral port.
pub fn run(cfg: &ServeBenchConfig) -> Result<ServeBenchResult> {
    let model = Model::Arf(ArfRegressor::new(
        10,
        ArfOptions {
            n_members: cfg.members,
            lambda: 6.0,
            seed: cfg.seed,
            ..Default::default()
        },
        qo_factory(),
    ));
    let server = Server::start(
        model,
        "127.0.0.1:0",
        ServeOptions { snapshot_every: cfg.snapshot_every, ..Default::default() },
    )?;
    let addr = server.addr();

    // background client: stream learns as fast as the queue admits them
    let done = Arc::new(AtomicBool::new(false));
    let learner = {
        let done = done.clone();
        let (instances, seed) = (cfg.instances, cfg.seed);
        std::thread::spawn(move || -> Result<f64> {
            let out = (|| -> Result<f64> {
                let mut client = ServeClient::connect(addr)?;
                let mut stream = Friedman1::new(seed, 1.0);
                let start = Instant::now();
                for _ in 0..instances {
                    let inst = stream.next_instance().expect("endless stream");
                    client.learn(&inst.x, inst.y)?;
                }
                Ok(start.elapsed().as_secs_f64())
            })();
            // set on EVERY exit path: the foreground latency loop spins
            // until this flips, even when the learner fails
            done.store(true, Ordering::SeqCst);
            out
        })
    };

    // foreground client: predict latency while training runs
    let mut client = ServeClient::connect(addr)?;
    let mut probe = Friedman1::new(cfg.seed ^ 0x5EED, 0.0);
    let mut latencies = Vec::new();
    while !done.load(Ordering::SeqCst) || latencies.len() < cfg.min_predict_samples {
        let inst = probe.next_instance().expect("endless stream");
        let start = Instant::now();
        let p = client.predict(&inst.x)?;
        latencies.push(start.elapsed().as_secs_f64());
        debug_assert!(p.is_finite());
    }
    let learn_seconds = learner
        .join()
        .map_err(|_| anyhow!("learner thread panicked"))?
        .map_err(|e| e.context("background learner failed"))?;

    // force a final hot-swap (checkpoint through the full codec), read
    // the counters, then stop the server
    client.snapshot()?;
    let stats = client.stats()?;
    let snapshots = stats
        .get("snapshots")
        .and_then(crate::common::json::Json::as_f64)
        .unwrap_or(0.0) as u64;
    client.shutdown()?;
    server.join()?;

    let predict_samples = latencies.len();
    let mut sorted = latencies;
    let predict_p50 = percentile(&mut sorted, 0.50);
    let predict_p99 = percentile(&mut sorted, 0.99);

    Ok(ServeBenchResult {
        learns: cfg.instances,
        learn_seconds,
        predict_samples,
        predict_p50,
        predict_p99,
        snapshots,
        checkpoint_sizes: checkpoint_sizes(cfg)?,
    })
}

/// QO vs E-BST checkpoint bytes for the same tree on the same stream:
/// the paper's elements metric, restated as serialized model size.
fn checkpoint_sizes(cfg: &ServeBenchConfig) -> Result<Vec<(String, usize, usize)>> {
    let mut out = Vec::new();
    for factory in [qo_factory(), ebst_factory()] {
        let label = factory.name();
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), factory);
        let mut stream = Friedman1::new(cfg.seed, 1.0);
        for _ in 0..cfg.instances {
            let inst = stream.next_instance().expect("endless stream");
            tree.learn_one(&inst.x, inst.y);
        }
        let elements = tree.total_elements();
        let model = Model::Tree(tree);
        let bytes = model.to_text()?.len();
        out.push((format!("htr[{label}]"), bytes, elements));
    }
    Ok(out)
}

/// Steady-state delta vs full checkpoint sizes (offline, deterministic):
/// train one QO tree, publish a checkpoint into a [`DeltaLog`] every
/// `snapshot_every` learns, and compare the delta ring's bytes against
/// the full document. The acceptance contract is `ratio >= 5` — exact
/// diffs of the paper's O(1)-slot state must be much smaller than
/// re-shipping the model.
#[derive(Clone, Copy, Debug)]
pub struct DeltaSizeResult {
    pub versions: usize,
    /// Mean delta bytes over the post-warmup measurement window (every
    /// published version — the warmup already put the tree in steady
    /// state before version 0).
    pub mean_delta_bytes: f64,
    pub max_delta_bytes: usize,
    /// Full-document bytes at the final version.
    pub full_bytes: usize,
    /// `full_bytes / mean_delta_bytes`.
    pub ratio: f64,
}

/// Train a tree for `warmup` instances first (so the full checkpoint is
/// at its steady-state size), then publish a delta every
/// `snapshot_every` learns for `measured` further instances.
pub fn delta_size_scenario(
    warmup: usize,
    measured: usize,
    snapshot_every: usize,
    seed: u64,
) -> Result<DeltaSizeResult> {
    let snapshot_every = snapshot_every.max(1);
    let mut model =
        Model::Tree(HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory()));
    let mut stream = Friedman1::new(seed, 1.0);
    for _ in 0..warmup {
        let inst = stream.next_instance().expect("endless stream");
        model.learn_one(&inst.x, inst.y);
    }
    let mut log = DeltaLog::new(model.to_checkpoint()?, usize::MAX);
    for i in 1..=measured {
        let inst = stream.next_instance().expect("endless stream");
        model.learn_one(&inst.x, inst.y);
        if i % snapshot_every == 0 {
            log.publish(model.to_checkpoint()?);
        }
    }
    let sizes: Vec<usize> = log.entries().map(|e| e.delta_bytes).collect();
    if sizes.is_empty() {
        return Err(anyhow!("no versions published (measured < snapshot_every?)"));
    }
    let mean_delta_bytes = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
    let full_bytes = log.full_bytes();
    Ok(DeltaSizeResult {
        versions: sizes.len(),
        mean_delta_bytes,
        max_delta_bytes: sizes.iter().copied().max().unwrap_or(0),
        full_bytes,
        ratio: full_bytes as f64 / mean_delta_bytes.max(1.0),
    })
}

/// Snapshot publication cost (offline, deterministic): the retired
/// O(model) codec-round-trip publish against the O(touched) structural
/// clone that [`crate::serve::publish`] now stages, plus JSON vs binary
/// checkpoint bytes for the same document (`docs/FORMATS.md`).
#[derive(Clone, Copy, Debug)]
pub struct SnapshotCostResult {
    pub publishes: usize,
    /// Per-publish seconds for the old path: encode the model to its
    /// canonical document and decode it back (`clone_via_codec`).
    pub codec_p50_s: f64,
    pub codec_p99_s: f64,
    /// Per-publish seconds for the new path: `Model::clone()` behind an
    /// `Arc` — pointer bumps for every untouched subtree.
    pub clone_p50_s: f64,
    pub clone_p99_s: f64,
    /// `codec_p50_s / clone_p50_s` — how much cheaper the hot-swap got.
    pub speedup_p50: f64,
    /// Canonical compact JSON bytes of the final checkpoint.
    pub json_bytes: usize,
    /// Binary envelope bytes of the same document.
    pub binary_bytes: usize,
    /// `json_bytes / binary_bytes` (> 1 means binary is smaller).
    pub bytes_ratio: f64,
}

/// Train a QO tree for `warmup` instances, then alternate `between`
/// learns with one publish measured both ways, `publishes` times.
pub fn snapshot_cost_scenario(
    warmup: usize,
    publishes: usize,
    between: usize,
    seed: u64,
) -> Result<SnapshotCostResult> {
    let mut model =
        Model::Tree(HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory()));
    let mut stream = Friedman1::new(seed, 1.0);
    for _ in 0..warmup {
        let inst = stream.next_instance().expect("endless stream");
        model.learn_one(&inst.x, inst.y);
    }
    let mut codec_times = Vec::with_capacity(publishes);
    let mut clone_times = Vec::with_capacity(publishes);
    for _ in 0..publishes.max(1) {
        for _ in 0..between {
            let inst = stream.next_instance().expect("endless stream");
            model.learn_one(&inst.x, inst.y);
        }
        let start = Instant::now();
        let via_codec = model.clone_via_codec()?;
        codec_times.push(start.elapsed().as_secs_f64());
        std::hint::black_box(via_codec.n_features());
        let start = Instant::now();
        let shared = Arc::new(model.clone());
        clone_times.push(start.elapsed().as_secs_f64());
        std::hint::black_box(shared.n_features());
    }
    let doc = model.to_checkpoint()?;
    let json_bytes = doc.to_compact().len();
    let binary_bytes = crate::persist::binary::encode_doc(&doc).len();
    let codec_p50_s = percentile(&mut codec_times.clone(), 0.50);
    let clone_p50_s = percentile(&mut clone_times.clone(), 0.50);
    Ok(SnapshotCostResult {
        publishes: publishes.max(1),
        codec_p50_s,
        codec_p99_s: percentile(&mut codec_times, 0.99),
        clone_p50_s,
        clone_p99_s: percentile(&mut clone_times, 0.99),
        speedup_p50: codec_p50_s / clone_p50_s.max(1e-12),
        json_bytes,
        binary_bytes,
        bytes_ratio: json_bytes as f64 / (binary_bytes as f64).max(1.0),
    })
}

/// Memory-budget scenario behind the `mem_budget_rmse_ratio` /
/// `mem_budget_peak_ratio` smoke metrics: two identical ARF forests on
/// the same drifting Friedman #1 stream — one unbounded, one governed
/// between "publishes" exactly the way the serve trainer does
/// ([`crate::govern`], docs/MEMORY.md) — with prequential RMSE scored
/// over the post-warmup window.
#[derive(Clone, Copy, Debug)]
pub struct MemBudgetResult {
    pub instances: usize,
    /// The byte budget the governed run was held to (derived: a fixed
    /// fraction of the unbounded run's final footprint, so the scenario
    /// stays meaningful as the model's baseline size drifts).
    pub budget_bytes: usize,
    pub unbounded_rmse: f64,
    pub governed_rmse: f64,
    /// `governed_rmse / unbounded_rmse` — the ≤ 1.10 acceptance bound.
    pub rmse_ratio: f64,
    /// Peak governed `mem_bytes()` at publish boundaries — the only
    /// states snapshots, replication and audit can ever observe.
    pub governed_peak_bytes: usize,
    pub unbounded_final_bytes: usize,
    /// `governed_peak_bytes / budget_bytes` — ≤ 1.0 proves enforcement.
    pub peak_ratio: f64,
    pub compactions: u64,
    pub evictions: u64,
    pub prunes: u64,
}

/// Run the budget comparison: `instances` learns, an abrupt concept
/// drift at the midpoint, governance enforced every `enforce_every`
/// learns (the publish cadence). The budget is 7/10 of the unbounded
/// final footprint — deep enough that governance must act, shallow
/// enough that the exact slot compactions (paper Sec. 3 mergeability)
/// carry most of it.
pub fn mem_budget_scenario(
    instances: usize,
    members: usize,
    enforce_every: usize,
    seed: u64,
) -> Result<MemBudgetResult> {
    let drift_at = instances / 2;
    let stream = || -> Box<dyn Stream> {
        Box::new(crate::stream::AbruptDrift::new(
            Box::new(Friedman1::new(seed, 1.0)),
            Box::new(Friedman1::swapped(seed.wrapping_add(1), 1.0)),
            drift_at,
        ))
    };
    let forest = || {
        Model::Arf(ArfRegressor::new(
            10,
            ArfOptions { n_members: members, lambda: 6.0, seed, ..Default::default() },
            qo_factory(),
        ))
    };
    let skip = instances / 10; // prequential warmup excluded from RMSE

    // pass 1: the unbounded reference
    let mut unbounded = forest();
    let mut s = stream();
    let mut err = 0.0;
    let mut scored = 0usize;
    for i in 0..instances {
        let inst = s.next_instance().expect("endless stream");
        if i >= skip {
            let e = inst.y - unbounded.predict(&inst.x);
            err += e * e;
            scored += 1;
        }
        unbounded.learn_one(&inst.x, inst.y);
    }
    let unbounded_rmse = (err / scored.max(1) as f64).sqrt();
    let unbounded_final_bytes = unbounded.mem_bytes();

    // pass 2: same forest, same stream, governed at the publish cadence
    let budget_bytes = unbounded_final_bytes * 7 / 10;
    let governor = crate::govern::Governor::new(budget_bytes);
    let mut governed = forest();
    let mut s = stream();
    let mut err = 0.0;
    let mut peak = 0usize;
    let (mut compactions, mut evictions, mut prunes) = (0u64, 0u64, 0u64);
    let enforce_every = enforce_every.max(1);
    for i in 0..instances {
        let inst = s.next_instance().expect("endless stream");
        if i >= skip {
            let e = inst.y - governed.predict(&inst.x);
            err += e * e;
        }
        governed.learn_one(&inst.x, inst.y);
        if (i + 1) % enforce_every == 0 || i + 1 == instances {
            let report = governor.enforce(&mut governed);
            if !report.within_budget {
                return Err(anyhow!(
                    "budget {budget_bytes} B below the structural floor \
                     ({} B after the full ladder)",
                    report.end_bytes
                ));
            }
            compactions += report.compactions;
            evictions += report.evictions;
            prunes += report.prunes;
            peak = peak.max(report.end_bytes);
        }
    }
    let governed_rmse = (err / scored.max(1) as f64).sqrt();
    Ok(MemBudgetResult {
        instances,
        budget_bytes,
        unbounded_rmse,
        governed_rmse,
        rmse_ratio: governed_rmse / unbounded_rmse.max(1e-12),
        governed_peak_bytes: peak,
        unbounded_final_bytes,
        peak_ratio: peak as f64 / budget_bytes.max(1) as f64,
        compactions,
        evictions,
        prunes,
    })
}

/// Instrumentation-overhead scenario behind the `obs_overhead_ratio`
/// smoke metric: train identical QO trees on identical streams with the
/// [`crate::obs`] registry disabled and enabled, interleaved, and score
/// each mode by its best round. The contract (hard-gated in CI alongside
/// the baseline diff) is that the instrumented hot path — counters,
/// latency histograms and the split trace ring — costs at most 5% of
/// learn throughput.
#[derive(Clone, Copy, Debug)]
pub struct ObsOverheadResult {
    pub learns_per_round: usize,
    pub rounds: usize,
    /// Best-round learns/sec with the registry disabled (each
    /// instrumentation site pays one relaxed atomic load + branch).
    pub uninstrumented_lps: f64,
    /// Best-round learns/sec with the registry enabled (live counters,
    /// histograms, trace ring).
    pub instrumented_lps: f64,
    /// `instrumented_lps / uninstrumented_lps` — 1.0 means free.
    pub ratio: f64,
}

/// Run the overhead comparison. Interleaves disabled/enabled rounds so
/// machine-load drift hits both modes equally, and takes the best (min
/// time) round per mode — min-of-N is far more stable than the mean
/// under scheduler noise. Restores the registry's prior enabled state.
pub fn obs_overhead_scenario(
    learns_per_round: usize,
    rounds: usize,
    seed: u64,
) -> ObsOverheadResult {
    // serialize with other togglers of the process-global switch (tests
    // run in parallel threads); plain enable() callers are unaffected
    let _toggling = crate::obs::toggle_lock();
    let was_enabled = crate::obs::enabled();
    let round = |round_seed: u64| -> f64 {
        let mut tree =
            HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory());
        let mut stream = Friedman1::new(round_seed, 1.0);
        let start = Instant::now();
        for _ in 0..learns_per_round {
            let inst = stream.next_instance().expect("endless stream");
            tree.learn_one(&inst.x, inst.y);
        }
        let elapsed = start.elapsed().as_secs_f64();
        std::hint::black_box(tree.predict(&[0.5; 10]));
        elapsed
    };
    let rounds = rounds.max(1);
    let mut best_off = f64::INFINITY;
    let mut best_on = f64::INFINITY;
    for r in 0..rounds as u64 {
        crate::obs::disable();
        best_off = best_off.min(round(seed ^ r));
        crate::obs::enable();
        best_on = best_on.min(round(seed ^ r));
    }
    if was_enabled {
        crate::obs::enable();
    } else {
        crate::obs::disable();
    }
    let uninstrumented_lps = learns_per_round as f64 / best_off.max(1e-9);
    let instrumented_lps = learns_per_round as f64 / best_on.max(1e-9);
    ObsOverheadResult {
        learns_per_round,
        rounds,
        uninstrumented_lps,
        instrumented_lps,
        ratio: instrumented_lps / uninstrumented_lps,
    }
}

/// Replicated-serving scenario parameters.
#[derive(Clone, Copy, Debug)]
pub struct ReplicationBenchConfig {
    /// Learns the background client streams through the leader.
    pub instances: usize,
    /// ARF members of the served model.
    pub members: usize,
    /// Applied learns between published versions.
    pub snapshot_every: usize,
    /// Follower replicas.
    pub followers: usize,
    /// Follower poll interval in milliseconds.
    pub poll_ms: u64,
    pub seed: u64,
}

impl Default for ReplicationBenchConfig {
    fn default() -> ReplicationBenchConfig {
        ReplicationBenchConfig {
            instances: 4000,
            members: 3,
            snapshot_every: 100,
            followers: 2,
            poll_ms: 5,
            seed: 1,
        }
    }
}

/// Measured outcome of one replicated-serving run.
#[derive(Clone, Debug)]
pub struct ReplicationBenchResult {
    /// Versions the leader published.
    pub versions: u64,
    /// Delta applications summed over all followers.
    pub deltas_applied: u64,
    /// Full resyncs summed over all followers (0 in a healthy steady run;
    /// the bootstrap sync is not counted).
    pub full_resyncs: u64,
    pub lag_samples: usize,
    /// Publish → apply replication lag, over all followers × versions.
    pub lag_p50_s: f64,
    pub lag_p99_s: f64,
    /// Mean delta bytes over the steady-state half of the leader's ring.
    pub mean_delta_bytes: f64,
    pub full_bytes: usize,
    pub delta_ratio: f64,
    /// Single-connection predict throughput against the leader.
    pub leader_reads_per_sec: f64,
    /// Aggregate single-connection predict throughput over all followers.
    pub follower_reads_per_sec: f64,
    /// Every follower's predictions matched the leader's bit-for-bit on a
    /// held-out batch at the same version.
    pub bit_identical: bool,
    /// Live publish→apply freshness spans recorded by follower apply
    /// during this run (the `qostream_repl_freshness_seconds` histogram,
    /// windowed to the run via [`crate::obs::HistogramSnapshot::minus`]).
    pub freshness_samples: u64,
    /// Live freshness p50/p99 in seconds. Log2-bucket quantiles: each
    /// over-reports its exact sample by less than 2× (bucket upper
    /// bound) — the agreement contract against the offline
    /// [`replication_lags`] join is asserted in the tests.
    pub freshness_p50_s: f64,
    pub freshness_p99_s: f64,
}

/// Predicts/sec over one connection for a fixed wall-clock window.
fn reads_per_sec(addr: std::net::SocketAddr, window: Duration) -> Result<f64> {
    let mut client = ServeClient::connect(addr)?;
    let probe = [0.42; 10];
    let start = Instant::now();
    let mut count = 0u64;
    while start.elapsed() < window {
        client.predict(&probe)?;
        count += 1;
    }
    Ok(count as f64 / start.elapsed().as_secs_f64())
}

/// Drive a leader + follower fleet end-to-end over real sockets and
/// measure replication lag, delta sizes, read scaling and bit-identity.
pub fn run_replication(cfg: &ReplicationBenchConfig) -> Result<ReplicationBenchResult> {
    // live freshness isolation: serialize with enable/disable experiments
    // (the overhead scenario toggles the process-global switch), force
    // the registry on, and window the global freshness histogram to this
    // run via a before/after `minus` — parallel tests recording their own
    // spans would otherwise bleed into our distribution
    let _toggling = crate::obs::toggle_lock();
    crate::obs::enable();
    let freshness_before = crate::obs::global().repl_freshness_ns.snapshot();
    let model = Model::Arf(ArfRegressor::new(
        10,
        ArfOptions {
            n_members: cfg.members,
            lambda: 6.0,
            seed: cfg.seed,
            ..Default::default()
        },
        qo_factory(),
    ));
    let server = Server::start(
        model,
        "127.0.0.1:0",
        ServeOptions {
            snapshot_every: cfg.snapshot_every,
            // retain every delta: the bench reads sizes off the ring
            delta_history: 1 << 16,
            ..Default::default()
        },
    )?;
    let leader_addr = server.addr();

    let mut followers = Vec::with_capacity(cfg.followers);
    for _ in 0..cfg.followers.max(1) {
        followers.push(Follower::start(
            &leader_addr.to_string(),
            "127.0.0.1:0",
            FollowerOptions {
                poll_interval: Duration::from_millis(cfg.poll_ms),
                ..Default::default()
            },
        )?);
    }

    // write path: stream learns through the leader, then force a final
    // publish so the head reflects every acked learn
    let mut client = ServeClient::connect(leader_addr)?;
    let mut stream = Friedman1::new(cfg.seed, 1.0);
    for _ in 0..cfg.instances {
        let inst = stream.next_instance().expect("endless stream");
        client.learn(&inst.x, inst.y)?;
    }
    client.snapshot()?;

    // wait (bounded) for every follower to reach the head version
    // the snapshot() call above materialized the log, so the plain log
    // view is current
    let replication = server.replication();
    let head = replication.log().version();
    let deadline = Instant::now() + Duration::from_secs(30);
    for follower in &followers {
        while follower.version() < head {
            if Instant::now() > deadline {
                return Err(anyhow!(
                    "follower stuck at v{} (leader at v{head})",
                    follower.version()
                ));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    // bit-identity: held-out batch, leader vs every follower at the head
    let mut held_out = Friedman1::new(cfg.seed ^ 0xD00D, 0.0);
    let batch: Vec<Vec<f64>> =
        (0..30).map(|_| held_out.next_instance().expect("endless").x).collect();
    let leader_preds = client.predict_batch(&batch)?;
    let mut bit_identical = true;
    for follower in &followers {
        let mut fc = ServeClient::connect(follower.addr())?;
        let preds = fc.predict_batch(&batch)?;
        bit_identical &= leader_preds
            .iter()
            .zip(&preds)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    }

    // read scaling: per-endpoint single-connection predict throughput
    let window = Duration::from_millis(150);
    let leader_reads_per_sec = reads_per_sec(leader_addr, window)?;
    let mut follower_reads_per_sec = 0.0;
    for follower in &followers {
        follower_reads_per_sec += reads_per_sec(follower.addr(), window)?;
    }

    // replication lag + delta sizes off the leader's log
    let (lags, mean_delta_bytes, full_bytes) = {
        let log = replication.log();
        let mut lags = Vec::new();
        for follower in &followers {
            lags.extend(replication_lags(&log, &follower.applied_log()));
        }
        let sizes: Vec<usize> = log.entries().map(|e| e.delta_bytes).collect();
        let steady = &sizes[sizes.len() / 2..];
        let mean = if steady.is_empty() {
            0.0
        } else {
            steady.iter().sum::<usize>() as f64 / steady.len() as f64
        };
        (lags, mean, log.full_bytes())
    };
    let mut sorted = lags.clone();
    let lag_p50_s = percentile(&mut sorted, 0.50);
    let lag_p99_s = percentile(&mut sorted, 0.99);

    let mut deltas_applied = 0u64;
    let mut full_resyncs = 0u64;
    for follower in followers {
        let mut fc = ServeClient::connect(follower.addr())?;
        let stats = fc.stats()?;
        deltas_applied +=
            stats.get("deltas_applied").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        full_resyncs +=
            stats.get("full_resyncs").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        fc.shutdown()?;
        follower.join()?;
    }
    client.shutdown()?;
    server.join()?;

    // everything this run's followers applied, minus what the histogram
    // held before the run started
    let freshness =
        crate::obs::global().repl_freshness_ns.snapshot().minus(&freshness_before);

    Ok(ReplicationBenchResult {
        versions: head,
        deltas_applied,
        full_resyncs,
        lag_samples: lags.len(),
        lag_p50_s,
        lag_p99_s,
        mean_delta_bytes,
        full_bytes,
        delta_ratio: full_bytes as f64 / mean_delta_bytes.max(1.0),
        leader_reads_per_sec,
        follower_reads_per_sec,
        bit_identical,
        freshness_samples: freshness.count,
        freshness_p50_s: freshness.quantile(0.50) as f64 / 1e9,
        freshness_p99_s: freshness.quantile(0.99) as f64 / 1e9,
    })
}

/// The pinned-seed micro-bench behind `qostream serve --bench --smoke`:
/// serving latency/throughput, a forest-training subset, and the delta
/// steady-state ratio, as one flat JSON document (`BENCH_ci.json`) the CI
/// gate diffs against the committed `BENCH_baseline.json`.
pub fn run_smoke(seed: u64) -> Result<Json> {
    let serve = run(&ServeBenchConfig {
        instances: 2500,
        members: 3,
        snapshot_every: 250,
        min_predict_samples: 300,
        seed,
    })?;
    let rows = forest_bench::run(&forest_bench::ForestBenchConfig {
        instances: 3000,
        members: 3,
        drift_at: 0,
        seed,
        ..Default::default()
    });
    let forest_inst_per_sec = rows
        .iter()
        .find(|r| r.model.starts_with("arf["))
        .map(|r| r.throughput)
        .ok_or_else(|| anyhow!("forest subset produced no ARF row"))?;
    let delta = delta_size_scenario(8000, 600, 5, seed)?;
    let overhead = obs_overhead_scenario(4000, 5, seed);
    let snapshot = snapshot_cost_scenario(6000, 40, 25, seed)?;
    let mem_budget = mem_budget_scenario(6000, 3, 250, seed)?;
    let replication = run_replication(&ReplicationBenchConfig {
        instances: 800,
        members: 2,
        snapshot_every: 100,
        followers: 2,
        poll_ms: 2,
        seed,
    })?;

    let mut j = Json::obj();
    j.set("schema", "qostream-bench-smoke/1")
        .set("seed", seed)
        .set("learns_per_sec", serve.learns_per_sec())
        .set("predict_p50_s", serve.predict_p50)
        .set("predict_p99_s", serve.predict_p99)
        .set("predict_samples", serve.predict_samples)
        .set("forest_inst_per_sec", forest_inst_per_sec)
        .set("delta_ratio", delta.ratio)
        .set("mean_delta_bytes", delta.mean_delta_bytes)
        .set("full_checkpoint_bytes", delta.full_bytes)
        .set("obs_overhead_ratio", overhead.ratio)
        .set("obs_uninstrumented_lps", overhead.uninstrumented_lps)
        .set("obs_instrumented_lps", overhead.instrumented_lps)
        .set("snapshot_codec_p50_s", snapshot.codec_p50_s)
        .set("snapshot_clone_p50_s", snapshot.clone_p50_s)
        .set("snapshot_speedup_p50", snapshot.speedup_p50)
        .set("binary_checkpoint_bytes", snapshot.binary_bytes)
        .set("binary_bytes_ratio", snapshot.bytes_ratio)
        .set("mem_budget_rmse_ratio", mem_budget.rmse_ratio)
        .set("mem_budget_peak_ratio", mem_budget.peak_ratio)
        .set("mem_budget_bytes", mem_budget.budget_bytes)
        .set("mem_budget_governed_rmse", mem_budget.governed_rmse)
        .set("mem_budget_unbounded_rmse", mem_budget.unbounded_rmse)
        .set("freshness_p99_s", replication.freshness_p99_s)
        .set("freshness_p50_s", replication.freshness_p50_s)
        .set("freshness_samples", replication.freshness_samples);
    Ok(j)
}

/// Compare a smoke run against the committed baseline. Returns the list
/// of violations (empty = the gate passes). Throughput metrics fail when
/// they drop more than `tolerance` below baseline; latency metrics fail
/// when they rise more than `tolerance` above it; the delta ratio has a
/// hard functional floor of 5× and the instrumentation-overhead ratio a
/// hard floor of 0.95 (the [`crate::obs`] ≤5% contract), both independent
/// of the baseline.
pub fn gate(current: &Json, baseline: &Json) -> Vec<String> {
    let tolerance = baseline.get("tolerance").and_then(Json::as_f64).unwrap_or(0.30);
    let metric = |doc: &Json, key: &str| doc.get(key).and_then(Json::as_f64);
    let mut violations = Vec::new();
    // a key the baseline tracks but the run lacks would silently disable
    // the gate — treat it as a failure, not a pass
    let require = |key: &str, violations: &mut Vec<String>| -> Option<(f64, f64)> {
        match (metric(current, key), metric(baseline, key)) {
            (Some(cur), Some(base)) => Some((cur, base)),
            (None, Some(_)) => {
                violations.push(format!(
                    "{key} missing from the current run (the baseline gates on it)"
                ));
                None
            }
            _ => None, // not a baseline-tracked metric
        }
    };
    for key in ["learns_per_sec", "forest_inst_per_sec"] {
        if let Some((cur, base)) = require(key, &mut violations) {
            let floor = base * (1.0 - tolerance);
            if base > 0.0 && cur < floor {
                violations.push(format!(
                    "{key} regressed >{:.0}%: {cur:.1} < {floor:.1} (baseline {base:.1})",
                    tolerance * 100.0
                ));
            }
        }
    }
    for key in ["predict_p99_s", "predict_p50_s"] {
        if let Some((cur, base)) = require(key, &mut violations) {
            let ceiling = base * (1.0 + tolerance);
            if base > 0.0 && cur > ceiling {
                violations.push(format!(
                    "{key} regressed >{:.0}%: {} > {} (baseline {})",
                    tolerance * 100.0,
                    human_time(cur),
                    human_time(ceiling),
                    human_time(base)
                ));
            }
        }
    }
    match metric(current, "delta_ratio") {
        Some(ratio) if ratio < 5.0 => violations.push(format!(
            "delta_ratio {ratio:.2} below the 5x floor (deltas must stay \
             much smaller than full checkpoints)"
        )),
        Some(_) => {}
        None => violations
            .push("delta_ratio missing from the current run (5x floor unchecked)".into()),
    }
    match metric(current, "obs_overhead_ratio") {
        Some(ratio) if ratio < 0.95 => violations.push(format!(
            "obs_overhead_ratio {ratio:.3} below the 0.95 floor (instrumentation \
             must cost at most 5% of learn throughput)"
        )),
        Some(_) => {}
        None => violations.push(
            "obs_overhead_ratio missing from the current run (5% overhead floor unchecked)"
                .into(),
        ),
    }
    match metric(current, "snapshot_speedup_p50") {
        Some(speedup) if speedup < 2.0 => violations.push(format!(
            "snapshot_speedup_p50 {speedup:.2} below the 2x floor (structural-clone \
             publish must beat the codec round-trip)"
        )),
        Some(_) => {}
        None => violations.push(
            "snapshot_speedup_p50 missing from the current run (2x floor unchecked)".into(),
        ),
    }
    match metric(current, "binary_bytes_ratio") {
        Some(ratio) if ratio < 1.1 => violations.push(format!(
            "binary_bytes_ratio {ratio:.2} below the 1.1x floor (binary checkpoints \
             must be smaller than canonical JSON)"
        )),
        Some(_) => {}
        None => violations.push(
            "binary_bytes_ratio missing from the current run (1.1x floor unchecked)".into(),
        ),
    }
    // memory governance has absolute functional ceilings, independent of
    // the baseline's values: a budgeted forest must stay within 10% of
    // unbounded RMSE, and no published state may ever exceed its budget
    match metric(current, "mem_budget_rmse_ratio") {
        Some(ratio) if ratio > 1.10 => violations.push(format!(
            "mem_budget_rmse_ratio {ratio:.3} above the 1.10 ceiling (budgeted \
             forest must stay within 10% of unbounded RMSE)"
        )),
        Some(_) => {}
        None => violations.push(
            "mem_budget_rmse_ratio missing from the current run (10% budget-accuracy \
             ceiling unchecked)"
                .into(),
        ),
    }
    match metric(current, "mem_budget_peak_ratio") {
        Some(ratio) if ratio > 1.0 => violations.push(format!(
            "mem_budget_peak_ratio {ratio:.3} above 1.0 (published state exceeded \
             its memory budget)"
        )),
        Some(_) => {}
        None => violations.push(
            "mem_budget_peak_ratio missing from the current run (budget enforcement \
             unchecked)"
                .into(),
        ),
    }
    // live replication freshness is poll-interval-dominated and its log2
    // bucket quantile can land one power-of-two step higher run to run,
    // so a ±tolerance band would flap — the baseline value is an
    // absolute ceiling instead
    match (metric(current, "freshness_p99_s"), metric(baseline, "freshness_p99_s")) {
        (Some(cur), Some(ceiling)) if cur > ceiling => violations.push(format!(
            "freshness_p99_s {cur:.3}s above the {ceiling:.3}s ceiling \
             (live publish->apply freshness regressed)"
        )),
        (None, Some(_)) => violations.push(
            "freshness_p99_s missing from the current run (the baseline gates on it)"
                .into(),
        ),
        _ => {}
    }
    violations
}

/// CLI entry for `serve --bench --smoke`: run, write `out`, and (when a
/// baseline is given) gate — a violation is an `Err`, which the CLI turns
/// into a nonzero exit for CI.
pub fn run_smoke_cli(out: &str, baseline: Option<&str>) -> Result<String> {
    let current = run_smoke(1)?;
    let mut text = current.to_pretty();
    text.push('\n');
    std::fs::write(out, &text)
        .map_err(|e| anyhow!("writing bench output {out}: {e}"))?;
    let mut rendered = format!("bench smoke (pinned seed) written to {out}\n{text}");
    if let Some(baseline_path) = baseline {
        let baseline_text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow!("reading baseline {baseline_path}: {e}"))?;
        let baseline_doc =
            Json::parse(&baseline_text).map_err(|e| anyhow!("baseline: {e}"))?;
        let violations = gate(&current, &baseline_doc);
        if violations.is_empty() {
            rendered.push_str(&format!("gate: PASS vs {baseline_path}\n"));
        } else {
            return Err(anyhow!(
                "bench gate FAILED vs {baseline_path}:\n  {}",
                violations.join("\n  ")
            ));
        }
    }
    Ok(rendered)
}

/// Render + persist under `results/serve/`.
pub fn generate(cfg: &ServeBenchConfig) -> Result<String> {
    let result = run(cfg)?;
    let mut out = String::new();
    out.push_str(&format!(
        "serving scenario: {} learns streamed over TCP, {}-member ARF, \
         snapshot hot-swap every {} learns\n",
        result.learns, cfg.members, cfg.snapshot_every
    ));
    out.push_str(&format!(
        "  learns/sec     : {:.1}k ({} in {})\n",
        result.learns_per_sec() / 1e3,
        result.learns,
        human_time(result.learn_seconds)
    ));
    out.push_str(&format!(
        "  predict latency: p50 {}  p99 {}  ({} samples, concurrent with training)\n",
        human_time(result.predict_p50),
        human_time(result.predict_p99),
        result.predict_samples
    ));
    out.push_str(&format!("  snapshots published: {}\n", result.snapshots));
    out.push_str("checkpoint sizes (same tree, same stream):\n");
    let mut table = Table::new(vec!["model", "checkpoint_bytes", "elements"]);
    for (label, bytes, elements) in &result.checkpoint_sizes {
        table.row(vec![label.clone(), bytes.to_string(), elements.to_string()]);
    }
    out.push_str(&table.render());

    let delta = delta_size_scenario(8000, 1000, 10, cfg.seed)?;
    out.push_str(&format!(
        "delta checkpoints (steady-state QO tree, publish every 10 learns, {} versions):\n  \
         mean delta {:.0} B vs full {} B -> {:.1}x smaller (max delta {} B)\n",
        delta.versions,
        delta.mean_delta_bytes,
        delta.full_bytes,
        delta.ratio,
        delta.max_delta_bytes
    ));

    let overhead = obs_overhead_scenario(4000, 5, cfg.seed);
    out.push_str(&format!(
        "instrumentation overhead ({} learns x {} interleaved rounds, best-of):\n  \
         uninstrumented {:.1}k learns/sec vs instrumented {:.1}k -> ratio {:.3} \
         (contract: >= 0.95)\n",
        overhead.learns_per_round,
        overhead.rounds,
        overhead.uninstrumented_lps / 1e3,
        overhead.instrumented_lps / 1e3,
        overhead.ratio
    ));

    let snapshot = snapshot_cost_scenario(6000, 40, 25, cfg.seed)?;
    out.push_str(&format!(
        "snapshot publication cost ({} publishes on a steady-state QO tree):\n  \
         codec round-trip p50 {} vs structural clone p50 {} -> {:.1}x cheaper\n  \
         checkpoint bytes: json {} B vs binary {} B -> {:.2}x smaller\n",
        snapshot.publishes,
        human_time(snapshot.codec_p50_s),
        human_time(snapshot.clone_p50_s),
        snapshot.speedup_p50,
        snapshot.json_bytes,
        snapshot.binary_bytes,
        snapshot.bytes_ratio
    ));

    let mem_budget = mem_budget_scenario(6000, 3, 250, cfg.seed)?;
    out.push_str(&format!(
        "memory governance ({} learns, drift at the midpoint, enforce every 250):\n  \
         budget {} B (7/10 of unbounded {} B), peak governed {} B -> ratio {:.3}\n  \
         RMSE governed {:.4} vs unbounded {:.4} -> ratio {:.3} (contract: <= 1.10)\n  \
         ladder: {} compactions, {} evictions, {} prunes\n",
        mem_budget.instances,
        mem_budget.budget_bytes,
        mem_budget.unbounded_final_bytes,
        mem_budget.governed_peak_bytes,
        mem_budget.peak_ratio,
        mem_budget.governed_rmse,
        mem_budget.unbounded_rmse,
        mem_budget.rmse_ratio,
        mem_budget.compactions,
        mem_budget.evictions,
        mem_budget.prunes
    ));

    let repl_cfg = ReplicationBenchConfig { seed: cfg.seed, ..Default::default() };
    let replication = run_replication(&repl_cfg)?;
    out.push_str(&format!(
        "replicated serving ({} followers, {} versions, {} deltas applied, \
         {} full resyncs):\n  replication lag: p50 {}  p99 {}  ({} samples)\n  \
         live freshness:  p50 {}  p99 {}  ({} spans, wall-clock stamps)\n  \
         steady-state delta {:.0} B vs full {} B -> {:.1}x smaller\n  \
         reads/sec: leader {:.0}, followers {:.0} aggregate  \
         (bit-identical: {})\n",
        repl_cfg.followers,
        replication.versions,
        replication.deltas_applied,
        replication.full_resyncs,
        human_time(replication.lag_p50_s),
        human_time(replication.lag_p99_s),
        replication.lag_samples,
        human_time(replication.freshness_p50_s),
        human_time(replication.freshness_p99_s),
        replication.freshness_samples,
        replication.mean_delta_bytes,
        replication.full_bytes,
        replication.delta_ratio,
        replication.leader_reads_per_sec,
        replication.follower_reads_per_sec,
        replication.bit_identical
    ));

    let report = Report::create("serve")?;
    report.write_text("serve.txt", &out)?;
    let mut j = crate::common::json::Json::obj();
    j.set("learns", result.learns)
        .set("learn_seconds", result.learn_seconds)
        .set("learns_per_sec", result.learns_per_sec())
        .set("predict_p50_s", result.predict_p50)
        .set("predict_p99_s", result.predict_p99)
        .set("predict_samples", result.predict_samples)
        .set("snapshots", result.snapshots)
        .set("delta_versions", delta.versions)
        .set("delta_mean_bytes", delta.mean_delta_bytes)
        .set("delta_full_bytes", delta.full_bytes)
        .set("delta_ratio", delta.ratio)
        .set("obs_overhead_ratio", overhead.ratio)
        .set("obs_uninstrumented_lps", overhead.uninstrumented_lps)
        .set("obs_instrumented_lps", overhead.instrumented_lps)
        .set("snapshot_codec_p50_s", snapshot.codec_p50_s)
        .set("snapshot_clone_p50_s", snapshot.clone_p50_s)
        .set("snapshot_speedup_p50", snapshot.speedup_p50)
        .set("binary_checkpoint_bytes", snapshot.binary_bytes)
        .set("binary_bytes_ratio", snapshot.bytes_ratio)
        .set("mem_budget_rmse_ratio", mem_budget.rmse_ratio)
        .set("mem_budget_peak_ratio", mem_budget.peak_ratio)
        .set("mem_budget_bytes", mem_budget.budget_bytes)
        .set("replication_versions", replication.versions)
        .set("replication_deltas_applied", replication.deltas_applied)
        .set("replication_full_resyncs", replication.full_resyncs)
        .set("replication_lag_p50_s", replication.lag_p50_s)
        .set("replication_lag_p99_s", replication.lag_p99_s)
        .set("replication_freshness_p50_s", replication.freshness_p50_s)
        .set("replication_freshness_p99_s", replication.freshness_p99_s)
        .set("replication_freshness_samples", replication.freshness_samples)
        .set("replication_delta_ratio", replication.delta_ratio)
        .set("leader_reads_per_sec", replication.leader_reads_per_sec)
        .set("follower_reads_per_sec", replication.follower_reads_per_sec)
        .set("replication_bit_identical", replication.bit_identical);
    let mut sizes = crate::common::json::Json::Arr(Vec::new());
    for (label, bytes, elements) in &result.checkpoint_sizes {
        let mut row = crate::common::json::Json::obj();
        row.set("model", label.as_str()).set("bytes", *bytes).set("elements", *elements);
        sizes.push(row);
    }
    j.set("checkpoint_sizes", sizes);
    report.write_json("serve.json", &j)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_checkpoints_are_much_smaller_than_full() {
        // acceptance contract: steady-state deltas >= 5x smaller than the
        // full checkpoint (exactness is covered by persist_roundtrip)
        let result = delta_size_scenario(8000, 600, 5, 7).expect("scenario");
        assert!(result.versions >= 100);
        assert!(result.full_bytes > 0);
        assert!(
            result.ratio >= 5.0,
            "delta ratio {:.2} below the 5x floor (mean delta {:.0} B, full {} B)",
            result.ratio,
            result.mean_delta_bytes,
            result.full_bytes
        );
    }

    #[test]
    fn tiny_replication_scenario_reports_sane_numbers() {
        let cfg = ReplicationBenchConfig {
            instances: 900,
            members: 2,
            snapshot_every: 150,
            followers: 2,
            poll_ms: 2,
            seed: 5,
        };
        let result = run_replication(&cfg).expect("replication scenario");
        assert!(result.versions >= 2, "too few versions: {result:?}");
        assert!(result.bit_identical, "follower diverged from the leader");
        assert!(result.deltas_applied >= 1, "no deltas ever applied: {result:?}");
        assert_eq!(result.full_resyncs, 0, "healthy run must not full-resync");
        assert!(result.lag_samples >= 1);
        assert!(result.lag_p99_s >= result.lag_p50_s);
        assert!(result.leader_reads_per_sec > 0.0);
        assert!(result.follower_reads_per_sec > 0.0);
        // live freshness (wall-clock stamps recorded by follower apply)
        // must agree with the offline publish-instant/apply-log join: both
        // observe the same publish->apply events, and the live quantile is
        // a log2 bucket upper bound, so it may over-report by < 2x. The
        // 50ms slack absorbs clock-source skew (Instant vs SystemTime).
        assert!(result.freshness_samples >= 1, "no live freshness spans: {result:?}");
        assert!(result.freshness_p99_s >= result.freshness_p50_s);
        assert!(
            result.freshness_p99_s + 0.05 >= result.lag_p99_s,
            "live p99 {:.4}s under offline p99 {:.4}s",
            result.freshness_p99_s,
            result.lag_p99_s
        );
        assert!(
            result.freshness_p99_s <= result.lag_p99_s * 2.0 + 0.05,
            "live p99 {:.4}s above 2x offline p99 {:.4}s",
            result.freshness_p99_s,
            result.lag_p99_s
        );
    }

    #[test]
    fn gate_passes_and_fails_on_the_right_sides() {
        let doc = |learns: f64, p99: f64, ratio: f64| {
            let mut j = Json::obj();
            j.set("learns_per_sec", learns)
                .set("forest_inst_per_sec", 10_000.0)
                .set("predict_p99_s", p99)
                .set("predict_p50_s", p99 / 2.0)
                .set("delta_ratio", ratio)
                .set("obs_overhead_ratio", 1.0)
                .set("snapshot_speedup_p50", 20.0)
                .set("binary_bytes_ratio", 1.8)
                .set("mem_budget_rmse_ratio", 1.0)
                .set("mem_budget_peak_ratio", 0.9)
                .set("freshness_p99_s", 0.5);
            j
        };
        let baseline = doc(10_000.0, 0.001, 10.0);
        // identical run: pass
        assert!(gate(&doc(10_000.0, 0.001, 10.0), &baseline).is_empty());
        // 20% slower learns: within the 30% tolerance
        assert!(gate(&doc(8_000.0, 0.001, 10.0), &baseline).is_empty());
        // 40% slower learns: fail
        let v = gate(&doc(6_000.0, 0.001, 10.0), &baseline);
        assert!(v.iter().any(|m| m.contains("learns_per_sec")), "{v:?}");
        // 40% higher p99: fail
        let v = gate(&doc(10_000.0, 0.0014, 10.0), &baseline);
        assert!(v.iter().any(|m| m.contains("predict_p99_s")), "{v:?}");
        // delta ratio under the hard floor: fail regardless of baseline
        let v = gate(&doc(10_000.0, 0.001, 3.0), &baseline);
        assert!(v.iter().any(|m| m.contains("delta_ratio")), "{v:?}");
        // instrumentation overhead past 5%: fail regardless of baseline
        let mut slow = doc(10_000.0, 0.001, 10.0);
        slow.set("obs_overhead_ratio", 0.90);
        let v = gate(&slow, &baseline);
        assert!(v.iter().any(|m| m.contains("obs_overhead_ratio")), "{v:?}");
        // exactly at the floor: pass
        let mut at_floor = doc(10_000.0, 0.001, 10.0);
        at_floor.set("obs_overhead_ratio", 0.95);
        assert!(gate(&at_floor, &baseline).is_empty());
        // faster-than-baseline never fails
        assert!(gate(&doc(50_000.0, 0.0001, 50.0), &baseline).is_empty());
        // custom tolerance is honored
        let mut tight = doc(10_000.0, 0.001, 10.0);
        tight.set("tolerance", 0.05);
        let v = gate(&doc(9_000.0, 0.001, 10.0), &tight);
        assert!(v.iter().any(|m| m.contains("learns_per_sec")), "{v:?}");
        // snapshot publish slower than 2x the structural clone: fail
        let mut slow_publish = doc(10_000.0, 0.001, 10.0);
        slow_publish.set("snapshot_speedup_p50", 1.2);
        let v = gate(&slow_publish, &baseline);
        assert!(v.iter().any(|m| m.contains("snapshot_speedup_p50")), "{v:?}");
        // binary checkpoints not smaller than JSON: fail
        let mut fat_binary = doc(10_000.0, 0.001, 10.0);
        fat_binary.set("binary_bytes_ratio", 0.9);
        let v = gate(&fat_binary, &baseline);
        assert!(v.iter().any(|m| m.contains("binary_bytes_ratio")), "{v:?}");
        // budgeted RMSE more than 10% over unbounded: fail
        let mut lossy = doc(10_000.0, 0.001, 10.0);
        lossy.set("mem_budget_rmse_ratio", 1.2);
        let v = gate(&lossy, &baseline);
        assert!(v.iter().any(|m| m.contains("mem_budget_rmse_ratio")), "{v:?}");
        // exactly at the 1.10 ceiling: pass
        let mut at_rmse_ceiling = doc(10_000.0, 0.001, 10.0);
        at_rmse_ceiling.set("mem_budget_rmse_ratio", 1.10);
        assert!(gate(&at_rmse_ceiling, &baseline).is_empty());
        // published state over its budget: fail
        let mut over_budget = doc(10_000.0, 0.001, 10.0);
        over_budget.set("mem_budget_peak_ratio", 1.01);
        let v = gate(&over_budget, &baseline);
        assert!(v.iter().any(|m| m.contains("mem_budget_peak_ratio")), "{v:?}");
        // freshness above the baseline's absolute ceiling: fail
        let mut stale = doc(10_000.0, 0.001, 10.0);
        stale.set("freshness_p99_s", 0.9);
        let v = gate(&stale, &baseline);
        assert!(v.iter().any(|m| m.contains("freshness_p99_s")), "{v:?}");
        // exactly at the ceiling: pass (already covered by the identical
        // run above, but make the boundary explicit)
        let mut at_ceiling = doc(10_000.0, 0.001, 10.0);
        at_ceiling.set("freshness_p99_s", 0.5);
        assert!(gate(&at_ceiling, &baseline).is_empty());
        // schema drift must FAIL the gate, not silently disable it
        let mut partial = Json::obj();
        partial.set("predict_p99_s", 0.001);
        let v = gate(&partial, &baseline);
        assert!(v.iter().any(|m| m.contains("learns_per_sec missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("delta_ratio missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("obs_overhead_ratio missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("snapshot_speedup_p50 missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("binary_bytes_ratio missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("mem_budget_rmse_ratio missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("mem_budget_peak_ratio missing")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("freshness_p99_s missing")), "{v:?}");
    }

    #[test]
    fn mem_budget_scenario_enforces_the_budget() {
        // plumbing-sized: the 1.10 RMSE ceiling is enforced by the CI
        // smoke gate; here the functional core must hold — every publish
        // boundary within budget, governance actually acted, and the
        // accuracy cost of exact compaction stays small
        let result = mem_budget_scenario(3000, 2, 200, 9).expect("scenario");
        assert_eq!(result.instances, 3000);
        assert!(result.budget_bytes > 0);
        assert!(result.budget_bytes < result.unbounded_final_bytes);
        assert!(
            result.governed_peak_bytes <= result.budget_bytes,
            "published state exceeded the budget: {result:?}"
        );
        assert!(result.peak_ratio <= 1.0);
        assert!(
            result.compactions + result.evictions + result.prunes > 0,
            "a 7/10 budget must force the ladder to act: {result:?}"
        );
        assert!(result.unbounded_rmse > 0.0);
        assert!(result.rmse_ratio.is_finite());
        assert!(
            result.rmse_ratio < 1.5,
            "governed RMSE wildly off unbounded: {result:?}"
        );
    }

    #[test]
    fn snapshot_cost_scenario_reports_sane_numbers() {
        // plumbing-sized: the 2x floor is enforced by the CI smoke gate,
        // but even here the structural clone should not lose to a full
        // codec round-trip, and binary must undercut JSON
        let result = snapshot_cost_scenario(2500, 8, 10, 7).expect("scenario");
        assert_eq!(result.publishes, 8);
        assert!(result.codec_p50_s > 0.0);
        assert!(result.clone_p50_s > 0.0);
        assert!(result.codec_p99_s >= result.codec_p50_s);
        assert!(result.clone_p99_s >= result.clone_p50_s);
        assert!(
            result.speedup_p50 > 1.0,
            "structural clone ({:.2e}s) should beat the codec round-trip ({:.2e}s)",
            result.clone_p50_s,
            result.codec_p50_s
        );
        assert!(result.json_bytes > 0 && result.binary_bytes > 0);
        assert!(
            result.binary_bytes < result.json_bytes,
            "binary checkpoint ({} B) must be smaller than JSON ({} B)",
            result.binary_bytes,
            result.json_bytes
        );
    }

    #[test]
    fn obs_overhead_scenario_reports_sane_numbers() {
        // small rounds: this checks plumbing, not the 5% contract — that
        // is enforced by the CI smoke gate where the run owns the machine
        let result = obs_overhead_scenario(1200, 2, 11);
        assert_eq!(result.rounds, 2);
        assert!(result.uninstrumented_lps > 0.0);
        assert!(result.instrumented_lps > 0.0);
        assert!(result.ratio.is_finite() && result.ratio > 0.0, "{result:?}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&mut xs, 0.5), 2.0);
        assert_eq!(percentile(&mut xs, 0.99), 4.0);
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }

    #[test]
    fn tiny_scenario_reports_sane_numbers() {
        // a real end-to-end run, sized for CI: the acceptance contract
        // (p50/p99 + learns/sec with hot-swap enabled) must hold
        let cfg = ServeBenchConfig {
            instances: 400,
            members: 2,
            snapshot_every: 100,
            min_predict_samples: 20,
            seed: 3,
        };
        let result = run(&cfg).expect("scenario must complete");
        assert_eq!(result.learns, 400);
        assert!(result.learn_seconds > 0.0);
        assert!(result.predict_samples >= 20);
        assert!(result.predict_p50 > 0.0);
        assert!(result.predict_p99 >= result.predict_p50);
        assert!(result.snapshots >= 1, "hot-swap never happened");
        assert_eq!(result.checkpoint_sizes.len(), 2);
        // the QO tree's checkpoint must undercut the E-BST tree's — the
        // paper's memory argument, in serialized bytes
        let (qo, ebst) = (&result.checkpoint_sizes[0], &result.checkpoint_sizes[1]);
        assert!(qo.0.contains("QO") && ebst.0.contains("E-BST"));
        assert!(
            qo.1 < ebst.1,
            "QO checkpoint ({} B) must be smaller than E-BST ({} B)",
            qo.1,
            ebst.1
        );
    }
}
