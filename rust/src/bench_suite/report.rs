//! Results-directory writer: CSV + JSON + ASCII charts under `results/`.

use std::fs;
use std::path::{Path, PathBuf};

use crate::common::json::Json;
use crate::common::table::Table;

/// A named output directory under `results/`.
pub struct Report {
    pub dir: PathBuf,
}

impl Report {
    /// Create (or reuse) `results/<name>/`.
    pub fn create(name: &str) -> anyhow::Result<Report> {
        let dir = Path::new("results").join(name);
        fs::create_dir_all(&dir)?;
        Ok(Report { dir })
    }

    pub fn write_text(&self, file: &str, content: &str) -> anyhow::Result<()> {
        fs::write(self.dir.join(file), content)?;
        Ok(())
    }

    pub fn write_table(&self, stem: &str, table: &Table) -> anyhow::Result<()> {
        self.write_text(&format!("{stem}.csv"), &table.to_csv())?;
        self.write_text(&format!("{stem}.txt"), &table.render())
    }

    pub fn write_json(&self, file: &str, json: &Json) -> anyhow::Result<()> {
        self.write_text(file, &json.to_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_all_formats() {
        let name = format!("test-report-{}", std::process::id());
        let report = Report::create(&name).unwrap();
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1"]);
        report.write_table("t", &t).unwrap();
        let mut j = Json::obj();
        j.set("k", 1.0);
        report.write_json("j.json", &j).unwrap();
        assert!(report.dir.join("t.csv").exists());
        assert!(report.dir.join("t.txt").exists());
        assert!(report.dir.join("j.json").exists());
        std::fs::remove_dir_all(&report.dir).ok();
    }
}
