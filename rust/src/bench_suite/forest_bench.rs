//! Forest-vs-single-tree scenario: ensembles (online bagging, ARF) against
//! one Hoeffding tree on a drifting Friedman #1 stream, with both QO and
//! E-BST observers inside the ensemble — where the paper's cheap-observer
//! economics actually compound (every instance fans out to λ·members tree
//! updates).
//!
//! CLI: `qostream forest [--instances N --members M --lambda L ...]`;
//! bench: `cargo bench --bench tree_throughput`. Results land in
//! `results/forest/`.

use crate::common::table::{fnum, Table};
use crate::common::timing::time_once;
use crate::coordinator::{fit_sharded_voting, ForestCoordinatorConfig};
use crate::eval::{
    prequential, MeanRegressor, PrequentialReport, RegressionMetrics, Regressor,
};
use crate::forest::{
    fit_parallel, ArfOptions, ArfRegressor, OnlineBaggingRegressor, ParallelFitConfig,
    SubspaceSize,
};
use crate::observer::{factory, EBst, ObserverFactory, QuantizationObserver, RadiusPolicy};
use crate::runtime::backend::SplitBackendKind;
use crate::stream::{AbruptDrift, Friedman1, GradualDrift, Stream};
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::report::Report;

/// Scenario parameters (CLI-exposed).
#[derive(Clone, Copy, Debug)]
pub struct ForestBenchConfig {
    pub instances: usize,
    pub members: usize,
    pub lambda: f64,
    pub subspace: SubspaceSize,
    pub seed: u64,
    /// Abrupt concept change position (0 = stationary stream).
    pub drift_at: usize,
    /// Split-query engine for every tree in the lineup
    /// (`--split-backend`; bit-identical results, different wall-clock).
    pub split_backend: SplitBackendKind,
}

impl Default for ForestBenchConfig {
    fn default() -> ForestBenchConfig {
        ForestBenchConfig {
            instances: 20_000,
            members: 10,
            lambda: 6.0,
            subspace: SubspaceSize::Sqrt,
            seed: 1,
            drift_at: 10_000,
            split_backend: SplitBackendKind::default(),
        }
    }
}

impl ForestBenchConfig {
    /// The scenario's stream: Friedman #1 that abruptly swaps the roles of
    /// its informative features at `drift_at` (stationary when 0).
    pub fn stream(&self) -> Box<dyn Stream> {
        if self.drift_at == 0 {
            Box::new(Friedman1::new(self.seed, 1.0))
        } else {
            Box::new(AbruptDrift::new(
                Box::new(Friedman1::new(self.seed, 1.0)),
                Box::new(Friedman1::swapped(self.seed.wrapping_add(1), 1.0)),
                self.drift_at,
            ))
        }
    }
}

/// One row of the forest comparison.
#[derive(Clone, Debug)]
pub struct ForestRow {
    pub model: String,
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
    pub seconds: f64,
    pub throughput: f64,
    pub elements: usize,
    pub warnings: usize,
    pub drifts: usize,
}

fn row_of(report: &PrequentialReport, warnings: usize, drifts: usize) -> ForestRow {
    ForestRow {
        model: report.model.clone(),
        mae: report.metrics.mae(),
        rmse: report.metrics.rmse(),
        r2: report.metrics.r2(),
        seconds: report.seconds,
        throughput: report.throughput(),
        elements: report.n_elements,
        warnings,
        drifts,
    }
}

/// The scenario's QO observer configuration (paper QO_s2) — shared with
/// the CLI so the `--parallel` demo runs the exact same observers as the
/// bench table it prints next to.
pub fn qo_factory() -> Box<dyn ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

/// The scenario's E-BST observer configuration (shared with the CLI).
pub fn ebst_factory() -> Box<dyn ObserverFactory> {
    factory("E-BST", || Box::new(EBst::new()))
}

fn tree_options(cfg: &ForestBenchConfig) -> HtrOptions {
    HtrOptions { split_backend: cfg.split_backend, ..Default::default() }
}

fn arf_options(cfg: &ForestBenchConfig) -> ArfOptions {
    ArfOptions {
        n_members: cfg.members,
        lambda: cfg.lambda,
        subspace: cfg.subspace,
        seed: cfg.seed,
        tree: tree_options(cfg),
        ..Default::default()
    }
}

/// Run the scenario lineup: mean baseline, single trees, bagging, and ARF
/// with both observer families.
pub fn run(cfg: &ForestBenchConfig) -> Vec<ForestRow> {
    let n_features = 10;
    let mut rows = Vec::new();
    {
        let mut model = MeanRegressor::new();
        let report = prequential(&mut model, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    for fac in [qo_factory(), ebst_factory()] {
        let mut tree = HoeffdingTreeRegressor::new(n_features, tree_options(cfg), fac);
        let report = prequential(&mut tree, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    {
        let mut bag = OnlineBaggingRegressor::new(
            n_features,
            cfg.members,
            cfg.lambda,
            tree_options(cfg),
            qo_factory(),
            cfg.seed,
        );
        let report = prequential(&mut bag, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    for fac in [qo_factory(), ebst_factory()] {
        let mut arf = ArfRegressor::new(n_features, arf_options(cfg), fac);
        let report = prequential(&mut arf, &mut *cfg.stream(), cfg.instances, 0);
        let (w, d) = (arf.n_warnings(), arf.n_drifts());
        rows.push(row_of(&report, w, d));
    }
    rows
}

/// Head-to-head split-query paths on the same forest: a ≥ 10-member ARF
/// trained twice with identical seeds — per-observer queries vs the
/// batched backend. The models must agree bit-for-bit (same splits, same
/// predictions); only the query path, and so the wall-clock, differs.
#[derive(Clone, Copy, Debug)]
pub struct BackendComparison {
    pub members: usize,
    pub instances: usize,
    /// Seconds to train with per-observer split queries.
    pub per_observer_secs: f64,
    /// Seconds to train with the batched native backend.
    pub batched_secs: f64,
    /// Whether the two forests ended bit-identical (they must).
    pub identical: bool,
}

impl BackendComparison {
    pub fn speedup(&self) -> f64 {
        if self.batched_secs > 0.0 {
            self.per_observer_secs / self.batched_secs
        } else {
            f64::INFINITY
        }
    }

    pub fn render(&self) -> String {
        format!(
            "split-query paths on arf[{}x] over {} instances: \
             per-observer {:.3}s vs native-batch {:.3}s ({:.2}x), bit-identical: {}",
            self.members,
            self.instances,
            self.per_observer_secs,
            self.batched_secs,
            self.speedup(),
            self.identical,
        )
    }
}

/// Run the per-observer vs batched split-query comparison (the scenario
/// the batched-backend PR is benchmarked by). Uses at least 10 members
/// regardless of `cfg.members`.
pub fn backend_comparison(cfg: &ForestBenchConfig) -> BackendComparison {
    let members = cfg.members.max(10);
    let train = |kind: SplitBackendKind| -> (ArfRegressor, f64) {
        let opts = ArfOptions {
            n_members: members,
            lambda: cfg.lambda,
            subspace: cfg.subspace,
            seed: cfg.seed,
            tree: HtrOptions { split_backend: kind, ..Default::default() },
            ..Default::default()
        };
        let mut arf = ArfRegressor::new(10, opts, qo_factory());
        let mut stream = cfg.stream();
        let (secs, _) = time_once(|| {
            for _ in 0..cfg.instances {
                let Some(inst) = stream.next_instance() else { break };
                arf.learn_one(&inst.x, inst.y);
            }
        });
        (arf, secs)
    };
    let (reference, per_observer_secs) = train(SplitBackendKind::PerObserver);
    let (batched, batched_secs) = train(SplitBackendKind::NativeBatch);
    let mut probe = Friedman1::new(cfg.seed ^ 0x5EED, 0.0);
    let identical = (0..100).all(|_| {
        let inst = probe.next_instance().unwrap();
        reference.predict(&inst.x).to_bits() == batched.predict(&inst.x).to_bits()
    });
    BackendComparison {
        members,
        instances: cfg.instances,
        per_observer_secs,
        batched_secs,
        identical,
    }
}

/// Head-to-head execution schedules on the same forest: the sequential
/// `learn_one` loop, multi-core `fit_parallel`, and the leader/shard
/// distributed fit ([`crate::coordinator::forest`]) — three times the same
/// seeds, so all three must end bit-identical; only the schedule, and so
/// the wall-clock, differs. `identical` covers both the *leader-merged
/// distributed vote* and the `fit_parallel` model against the sequential
/// `predict`.
#[derive(Clone, Copy, Debug)]
pub struct ShardedComparison {
    pub members: usize,
    pub instances: usize,
    pub shards: usize,
    /// Seconds for the sequential learn loop.
    pub sequential_secs: f64,
    /// Seconds for `fit_parallel` with `shards` workers.
    pub parallel_secs: f64,
    /// Seconds for the sharded leader/shard fit.
    pub sharded_secs: f64,
    /// Whether the leader-merged distributed vote AND the `fit_parallel`
    /// model matched the sequential predictions bit-for-bit (they must).
    pub identical: bool,
}

impl ShardedComparison {
    fn throughput(&self, secs: f64) -> f64 {
        crate::common::timing::throughput(self.instances, secs)
    }

    pub fn render(&self) -> String {
        format!(
            "execution schedules on arf[{}x] over {} instances ({} shards): \
             sequential {:.1}k inst/s, fit_parallel {:.1}k inst/s ({:.2}x), \
             sharded {:.1}k inst/s ({:.2}x, one split round-trip per shard per tick), \
             leader-merged vote bit-identical: {}",
            self.members,
            self.instances,
            self.shards,
            self.throughput(self.sequential_secs) / 1e3,
            self.throughput(self.parallel_secs) / 1e3,
            self.sequential_secs / self.parallel_secs.max(1e-12),
            self.throughput(self.sharded_secs) / 1e3,
            self.sequential_secs / self.sharded_secs.max(1e-12),
            self.identical,
        )
    }
}

/// Run the sequential vs `fit_parallel` vs sharded-coordinator comparison
/// (the distributed-forest PR's benchmark scenario).
pub fn sharded_comparison(cfg: &ForestBenchConfig, shards: usize) -> ShardedComparison {
    let opts = arf_options(cfg);

    let mut sequential = ArfRegressor::new(10, opts, qo_factory());
    let mut stream = cfg.stream();
    let (sequential_secs, _) = time_once(|| {
        for _ in 0..cfg.instances {
            let Some(inst) = stream.next_instance() else { break };
            sequential.learn_one(&inst.x, inst.y);
        }
    });

    let mut parallel = ArfRegressor::new(10, opts, qo_factory());
    let parallel_report = fit_parallel(
        &mut parallel,
        &mut *cfg.stream(),
        cfg.instances,
        ParallelFitConfig { n_workers: shards, ..Default::default() },
    );

    let mut sharded = ArfRegressor::new(10, opts, qo_factory());
    let mut probe = Friedman1::new(cfg.seed ^ 0xA11, 0.0);
    let probes: Vec<Vec<f64>> =
        (0..100).map(|_| probe.next_instance().unwrap().x).collect();
    let (sharded_report, merged) = fit_sharded_voting(
        &mut sharded,
        &mut *cfg.stream(),
        cfg.instances,
        &probes,
        ForestCoordinatorConfig { n_shards: shards, ..Default::default() },
    );

    // all three schedules must agree: the leader-merged distributed vote
    // AND the fit_parallel model against the sequential predictions
    let identical = probes.iter().zip(&merged).all(|(x, &v)| {
        let want = sequential.predict(x).to_bits();
        v.to_bits() == want && parallel.predict(x).to_bits() == want
    });
    ShardedComparison {
        members: sequential.n_members(),
        instances: cfg.instances,
        shards,
        sequential_secs,
        parallel_secs: parallel_report.seconds,
        sharded_secs: sharded_report.seconds,
        identical,
    }
}

/// Gradual/recurring-drift recovery scenario: a [`GradualDrift`] sigmoid
/// hand-over between the Friedman #1 concept and its swapped variant, with
/// windowed RMSE before, during and after the transition — the open
/// ROADMAP item asserting ARF actually *recovers* (post-drift RMSE back
/// within a factor of the pre-drift RMSE) instead of merely degrading
/// gracefully.
#[derive(Clone, Copy, Debug)]
pub struct DriftRecovery {
    pub instances: usize,
    /// Sigmoid center of the hand-over.
    pub position: usize,
    /// Sigmoid width of the hand-over.
    pub width: usize,
    /// Instances per measurement window.
    pub window: usize,
    /// RMSE over the window ending where the hand-over effectively begins.
    /// The sigmoid is centered at `position`, so the clean pre-drift
    /// window must end at `position - width` (p_new ≈ 2% there), not at
    /// `position` (p_new = 50%).
    pub pre_rmse: f64,
    /// RMSE over the hand-over window (mixture of both concepts).
    pub during_rmse: f64,
    /// RMSE over the final window, after re-convergence.
    pub post_rmse: f64,
    pub warnings: usize,
    pub drifts: usize,
}

impl DriftRecovery {
    /// post / pre RMSE: ~1 means full recovery on the new concept.
    pub fn recovery_factor(&self) -> f64 {
        self.post_rmse / self.pre_rmse
    }

    pub fn render(&self) -> String {
        format!(
            "gradual drift (center {}, width {}): RMSE pre {:.4} -> during {:.4} -> \
             post {:.4} (recovery factor {:.2}; {} warnings, {} drifts)",
            self.position,
            self.width,
            self.pre_rmse,
            self.during_rmse,
            self.post_rmse,
            self.recovery_factor(),
            self.warnings,
            self.drifts,
        )
    }
}

/// Run the gradual-drift recovery scenario on an ARF built from `cfg`.
pub fn gradual_drift_recovery(cfg: &ForestBenchConfig) -> DriftRecovery {
    let position = cfg.instances / 2;
    let width = (cfg.instances / 10).max(1);
    let window = (cfg.instances / 8).max(1);
    let mut stream = GradualDrift::new(
        Box::new(Friedman1::new(cfg.seed, 1.0)),
        Box::new(Friedman1::swapped(cfg.seed.wrapping_add(1), 1.0)),
        position,
        width,
        cfg.seed ^ 0xD81F,
    );
    let mut arf = ArfRegressor::new(10, arf_options(cfg), qo_factory());
    let mut pre = RegressionMetrics::new();
    let mut during = RegressionMetrics::new();
    let mut post = RegressionMetrics::new();
    // `position` is the sigmoid CENTER (p_new = 50% there), so instances
    // near it are already drift-contaminated; the pre-drift baseline
    // window ends at `position - width`, where p_new ≈ 2%.
    let drift_start = position.saturating_sub(width);
    for i in 0..cfg.instances {
        let Some(inst) = stream.next_instance() else { break };
        let pred = arf.predict(&inst.x);
        if i >= drift_start.saturating_sub(window) && i < drift_start {
            pre.update(inst.y, pred);
        } else if i >= position && i < position + width {
            during.update(inst.y, pred);
        } else if i + window >= cfg.instances {
            post.update(inst.y, pred);
        }
        arf.learn_one(&inst.x, inst.y);
    }
    DriftRecovery {
        instances: cfg.instances,
        position,
        width,
        window,
        pre_rmse: pre.rmse(),
        during_rmse: during.rmse(),
        post_rmse: post.rmse(),
        warnings: arf.n_warnings(),
        drifts: arf.n_drifts(),
    }
}

/// Render + persist under `results/forest/`.
pub fn generate(cfg: &ForestBenchConfig) -> anyhow::Result<String> {
    let rows = run(cfg);
    let mut table = Table::new(vec![
        "model", "MAE", "RMSE", "R2", "time_s", "inst/s", "elements", "warnings", "drifts",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fnum(r.mae),
            fnum(r.rmse),
            fnum(r.r2),
            fnum(r.seconds),
            fnum(r.throughput),
            r.elements.to_string(),
            r.warnings.to_string(),
            r.drifts.to_string(),
        ]);
    }
    let comparison = backend_comparison(cfg);
    // the sharded execution-schedule comparison is CLI-gated (`qostream
    // forest --shards N`) — running it here too would train three more
    // full forests per bench run and duplicate the CLI path's work
    let recovery = gradual_drift_recovery(cfg);
    let rendered = format!(
        "Forest benchmark ({} instances, {} members, lambda={}, subspace={}, drift@{}, \
         split-backend={})\n{}\n{}\n{}\n",
        cfg.instances,
        cfg.members,
        cfg.lambda,
        cfg.subspace.label(),
        cfg.drift_at,
        cfg.split_backend.label(),
        table.render(),
        comparison.render(),
        recovery.render(),
    );
    let report = Report::create("forest")?;
    report.write_table("forest", &table)?;
    report.write_text("summary.txt", &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ForestBenchConfig {
        ForestBenchConfig {
            instances: 4000,
            members: 3,
            lambda: 1.0,
            drift_at: 2000,
            ..Default::default()
        }
    }

    #[test]
    fn lineup_shape_and_sanity() {
        let rows = run(&small_cfg());
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[0].model, "mean");
        let baseline = rows[0].rmse;
        for r in &rows[1..] {
            assert!(r.rmse.is_finite() && r.mae.is_finite(), "{}", r.model);
            assert!(
                r.rmse < baseline,
                "{} rmse {} should beat mean {baseline}",
                r.model,
                r.rmse
            );
        }
        assert!(rows[4].model.starts_with("arf["));
        assert!(rows[5].model.contains("E-BST"));
    }

    #[test]
    fn generate_writes_results() {
        let text = generate(&small_cfg()).unwrap();
        assert!(text.contains("arf["));
        assert!(text.contains("bag["));
        assert!(std::path::Path::new("results/forest/forest.csv").exists());
    }

    #[test]
    fn backend_comparison_is_bit_identical() {
        let cfg = ForestBenchConfig { instances: 2500, ..small_cfg() };
        let cmp = backend_comparison(&cfg);
        assert_eq!(cmp.members, 10, "the scenario contract is a >= 10-member forest");
        assert!(
            cmp.identical,
            "native-batch split queries diverged from the per-observer path"
        );
        assert!(cmp.per_observer_secs > 0.0 && cmp.batched_secs > 0.0);
        assert!(cmp.render().contains("bit-identical: true"));
    }

    #[test]
    fn sharded_comparison_is_bit_identical_and_timed() {
        let cfg = ForestBenchConfig { instances: 2500, ..small_cfg() };
        let cmp = sharded_comparison(&cfg, 3);
        assert_eq!(cmp.shards, 3);
        assert_eq!(cmp.members, cfg.members);
        assert!(
            cmp.identical,
            "the leader-merged distributed vote diverged from the sequential forest"
        );
        assert!(cmp.sequential_secs > 0.0 && cmp.parallel_secs > 0.0 && cmp.sharded_secs > 0.0);
        assert!(cmp.render().contains("bit-identical: true"));
    }

    #[test]
    fn arf_recovers_from_gradual_drift() {
        // the open ROADMAP item: after the sigmoid hand-over to the
        // swapped Friedman concept completes, the forest's windowed RMSE
        // must re-converge to within a factor of its pre-drift RMSE
        let cfg = ForestBenchConfig {
            instances: 12_000,
            members: 5,
            lambda: 6.0,
            seed: 1,
            ..Default::default()
        };
        let rec = gradual_drift_recovery(&cfg);
        assert_eq!(rec.position, 6_000);
        assert!(rec.pre_rmse > 0.0 && rec.pre_rmse.is_finite());
        assert!(rec.post_rmse.is_finite());
        assert!(
            rec.recovery_factor() < 2.0,
            "no recovery: pre {} -> post {} (factor {:.2})",
            rec.pre_rmse,
            rec.post_rmse,
            rec.recovery_factor()
        );
        assert!(
            rec.warnings + rec.drifts >= 1,
            "the adaptation machinery never engaged on the gradual drift"
        );
    }

    #[test]
    fn stationary_config_uses_plain_stream() {
        let cfg = ForestBenchConfig { drift_at: 0, ..small_cfg() };
        assert_eq!(cfg.stream().name(), "friedman1[sigma=1]");
        let drifting = small_cfg();
        assert!(drifting.stream().name().starts_with("abrupt["));
    }
}
