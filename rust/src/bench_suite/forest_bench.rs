//! Forest-vs-single-tree scenario: ensembles (online bagging, ARF) against
//! one Hoeffding tree on a drifting Friedman #1 stream, with both QO and
//! E-BST observers inside the ensemble — where the paper's cheap-observer
//! economics actually compound (every instance fans out to λ·members tree
//! updates).
//!
//! CLI: `qostream forest [--instances N --members M --lambda L ...]`;
//! bench: `cargo bench --bench tree_throughput`. Results land in
//! `results/forest/`.

use crate::common::table::{fnum, Table};
use crate::common::timing::time_once;
use crate::eval::{prequential, MeanRegressor, PrequentialReport, Regressor};
use crate::forest::{ArfOptions, ArfRegressor, OnlineBaggingRegressor, SubspaceSize};
use crate::observer::{factory, EBst, ObserverFactory, QuantizationObserver, RadiusPolicy};
use crate::runtime::backend::SplitBackendKind;
use crate::stream::{AbruptDrift, Friedman1, Stream};
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::report::Report;

/// Scenario parameters (CLI-exposed).
#[derive(Clone, Copy, Debug)]
pub struct ForestBenchConfig {
    pub instances: usize,
    pub members: usize,
    pub lambda: f64,
    pub subspace: SubspaceSize,
    pub seed: u64,
    /// Abrupt concept change position (0 = stationary stream).
    pub drift_at: usize,
    /// Split-query engine for every tree in the lineup
    /// (`--split-backend`; bit-identical results, different wall-clock).
    pub split_backend: SplitBackendKind,
}

impl Default for ForestBenchConfig {
    fn default() -> ForestBenchConfig {
        ForestBenchConfig {
            instances: 20_000,
            members: 10,
            lambda: 6.0,
            subspace: SubspaceSize::Sqrt,
            seed: 1,
            drift_at: 10_000,
            split_backend: SplitBackendKind::default(),
        }
    }
}

impl ForestBenchConfig {
    /// The scenario's stream: Friedman #1 that abruptly swaps the roles of
    /// its informative features at `drift_at` (stationary when 0).
    pub fn stream(&self) -> Box<dyn Stream> {
        if self.drift_at == 0 {
            Box::new(Friedman1::new(self.seed, 1.0))
        } else {
            Box::new(AbruptDrift::new(
                Box::new(Friedman1::new(self.seed, 1.0)),
                Box::new(Friedman1::swapped(self.seed.wrapping_add(1), 1.0)),
                self.drift_at,
            ))
        }
    }
}

/// One row of the forest comparison.
#[derive(Clone, Debug)]
pub struct ForestRow {
    pub model: String,
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
    pub seconds: f64,
    pub throughput: f64,
    pub elements: usize,
    pub warnings: usize,
    pub drifts: usize,
}

fn row_of(report: &PrequentialReport, warnings: usize, drifts: usize) -> ForestRow {
    ForestRow {
        model: report.model.clone(),
        mae: report.metrics.mae(),
        rmse: report.metrics.rmse(),
        r2: report.metrics.r2(),
        seconds: report.seconds,
        throughput: report.throughput(),
        elements: report.n_elements,
        warnings,
        drifts,
    }
}

/// The scenario's QO observer configuration (paper QO_s2) — shared with
/// the CLI so the `--parallel` demo runs the exact same observers as the
/// bench table it prints next to.
pub fn qo_factory() -> Box<dyn ObserverFactory> {
    factory("QO_s2", || {
        Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
    })
}

/// The scenario's E-BST observer configuration (shared with the CLI).
pub fn ebst_factory() -> Box<dyn ObserverFactory> {
    factory("E-BST", || Box::new(EBst::new()))
}

fn tree_options(cfg: &ForestBenchConfig) -> HtrOptions {
    HtrOptions { split_backend: cfg.split_backend, ..Default::default() }
}

fn arf_options(cfg: &ForestBenchConfig) -> ArfOptions {
    ArfOptions {
        n_members: cfg.members,
        lambda: cfg.lambda,
        subspace: cfg.subspace,
        seed: cfg.seed,
        tree: tree_options(cfg),
        ..Default::default()
    }
}

/// Run the scenario lineup: mean baseline, single trees, bagging, and ARF
/// with both observer families.
pub fn run(cfg: &ForestBenchConfig) -> Vec<ForestRow> {
    let n_features = 10;
    let mut rows = Vec::new();
    {
        let mut model = MeanRegressor::new();
        let report = prequential(&mut model, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    for fac in [qo_factory(), ebst_factory()] {
        let mut tree = HoeffdingTreeRegressor::new(n_features, tree_options(cfg), fac);
        let report = prequential(&mut tree, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    {
        let mut bag = OnlineBaggingRegressor::new(
            n_features,
            cfg.members,
            cfg.lambda,
            tree_options(cfg),
            qo_factory(),
            cfg.seed,
        );
        let report = prequential(&mut bag, &mut *cfg.stream(), cfg.instances, 0);
        rows.push(row_of(&report, 0, 0));
    }
    for fac in [qo_factory(), ebst_factory()] {
        let mut arf = ArfRegressor::new(n_features, arf_options(cfg), fac);
        let report = prequential(&mut arf, &mut *cfg.stream(), cfg.instances, 0);
        let (w, d) = (arf.n_warnings(), arf.n_drifts());
        rows.push(row_of(&report, w, d));
    }
    rows
}

/// Head-to-head split-query paths on the same forest: a ≥ 10-member ARF
/// trained twice with identical seeds — per-observer queries vs the
/// batched backend. The models must agree bit-for-bit (same splits, same
/// predictions); only the query path, and so the wall-clock, differs.
#[derive(Clone, Copy, Debug)]
pub struct BackendComparison {
    pub members: usize,
    pub instances: usize,
    /// Seconds to train with per-observer split queries.
    pub per_observer_secs: f64,
    /// Seconds to train with the batched native backend.
    pub batched_secs: f64,
    /// Whether the two forests ended bit-identical (they must).
    pub identical: bool,
}

impl BackendComparison {
    pub fn speedup(&self) -> f64 {
        if self.batched_secs > 0.0 {
            self.per_observer_secs / self.batched_secs
        } else {
            f64::INFINITY
        }
    }

    pub fn render(&self) -> String {
        format!(
            "split-query paths on arf[{}x] over {} instances: \
             per-observer {:.3}s vs native-batch {:.3}s ({:.2}x), bit-identical: {}",
            self.members,
            self.instances,
            self.per_observer_secs,
            self.batched_secs,
            self.speedup(),
            self.identical,
        )
    }
}

/// Run the per-observer vs batched split-query comparison (the scenario
/// the batched-backend PR is benchmarked by). Uses at least 10 members
/// regardless of `cfg.members`.
pub fn backend_comparison(cfg: &ForestBenchConfig) -> BackendComparison {
    let members = cfg.members.max(10);
    let train = |kind: SplitBackendKind| -> (ArfRegressor, f64) {
        let opts = ArfOptions {
            n_members: members,
            lambda: cfg.lambda,
            subspace: cfg.subspace,
            seed: cfg.seed,
            tree: HtrOptions { split_backend: kind, ..Default::default() },
            ..Default::default()
        };
        let mut arf = ArfRegressor::new(10, opts, qo_factory());
        let mut stream = cfg.stream();
        let (secs, _) = time_once(|| {
            for _ in 0..cfg.instances {
                let Some(inst) = stream.next_instance() else { break };
                arf.learn_one(&inst.x, inst.y);
            }
        });
        (arf, secs)
    };
    let (reference, per_observer_secs) = train(SplitBackendKind::PerObserver);
    let (batched, batched_secs) = train(SplitBackendKind::NativeBatch);
    let mut probe = Friedman1::new(cfg.seed ^ 0x5EED, 0.0);
    let identical = (0..100).all(|_| {
        let inst = probe.next_instance().unwrap();
        reference.predict(&inst.x).to_bits() == batched.predict(&inst.x).to_bits()
    });
    BackendComparison {
        members,
        instances: cfg.instances,
        per_observer_secs,
        batched_secs,
        identical,
    }
}

/// Render + persist under `results/forest/`.
pub fn generate(cfg: &ForestBenchConfig) -> anyhow::Result<String> {
    let rows = run(cfg);
    let mut table = Table::new(vec![
        "model", "MAE", "RMSE", "R2", "time_s", "inst/s", "elements", "warnings", "drifts",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fnum(r.mae),
            fnum(r.rmse),
            fnum(r.r2),
            fnum(r.seconds),
            fnum(r.throughput),
            r.elements.to_string(),
            r.warnings.to_string(),
            r.drifts.to_string(),
        ]);
    }
    let comparison = backend_comparison(cfg);
    let rendered = format!(
        "Forest benchmark ({} instances, {} members, lambda={}, subspace={}, drift@{}, \
         split-backend={})\n{}\n{}\n",
        cfg.instances,
        cfg.members,
        cfg.lambda,
        cfg.subspace.label(),
        cfg.drift_at,
        cfg.split_backend.label(),
        table.render(),
        comparison.render(),
    );
    let report = Report::create("forest")?;
    report.write_table("forest", &table)?;
    report.write_text("summary.txt", &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ForestBenchConfig {
        ForestBenchConfig {
            instances: 4000,
            members: 3,
            lambda: 1.0,
            drift_at: 2000,
            ..Default::default()
        }
    }

    #[test]
    fn lineup_shape_and_sanity() {
        let rows = run(&small_cfg());
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[0].model, "mean");
        let baseline = rows[0].rmse;
        for r in &rows[1..] {
            assert!(r.rmse.is_finite() && r.mae.is_finite(), "{}", r.model);
            assert!(
                r.rmse < baseline,
                "{} rmse {} should beat mean {baseline}",
                r.model,
                r.rmse
            );
        }
        assert!(rows[4].model.starts_with("arf["));
        assert!(rows[5].model.contains("E-BST"));
    }

    #[test]
    fn generate_writes_results() {
        let text = generate(&small_cfg()).unwrap();
        assert!(text.contains("arf["));
        assert!(text.contains("bag["));
        assert!(std::path::Path::new("results/forest/forest.csv").exists());
    }

    #[test]
    fn backend_comparison_is_bit_identical() {
        let cfg = ForestBenchConfig { instances: 2500, ..small_cfg() };
        let cmp = backend_comparison(&cfg);
        assert_eq!(cmp.members, 10, "the scenario contract is a >= 10-member forest");
        assert!(
            cmp.identical,
            "native-batch split queries diverged from the per-observer path"
        );
        assert!(cmp.per_observer_secs > 0.0 && cmp.batched_secs > 0.0);
        assert!(cmp.render().contains("bit-identical: true"));
    }

    #[test]
    fn stationary_config_uses_plain_stream() {
        let cfg = ForestBenchConfig { drift_at: 0, ..small_cfg() };
        assert_eq!(cfg.stream().name(), "friedman1[sigma=1]");
        let drifting = small_cfg();
        assert!(drifting.stream().name().starts_with("abrupt["));
    }
}
