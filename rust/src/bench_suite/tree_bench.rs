//! Sec. 7 extension experiment: the Hoeffding tree with each observer on
//! realistic multi-feature streams — prequential accuracy, throughput and
//! memory. This is the paper's "future work" (QO inside Hoeffding trees),
//! implemented as a first-class benchmark.

use crate::common::table::{fnum, Table};
use crate::eval::{prequential, MeanRegressor, PrequentialReport};
use crate::observer::paper_lineup;
use crate::stream::Friedman1;
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::report::Report;

/// One row of the tree comparison.
#[derive(Clone, Debug)]
pub struct TreeRow {
    pub model: String,
    pub mae: f64,
    pub rmse: f64,
    pub r2: f64,
    pub seconds: f64,
    pub throughput: f64,
    pub elements: usize,
    pub leaves: usize,
    pub splits: usize,
}

/// Run the tree comparison on Friedman #1 (noise σ=1) with `instances`.
pub fn run(instances: usize, seed: u64) -> Vec<TreeRow> {
    let mut rows = Vec::new();
    // mean baseline
    {
        let mut model = MeanRegressor::new();
        let report = prequential(&mut model, &mut Friedman1::new(seed, 1.0), instances, 0);
        rows.push(row_of("mean-baseline", &report, 1, 0, 0));
    }
    for fac in paper_lineup() {
        let name = format!("htr[{}]", fac.name());
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), fac);
        let report = prequential(&mut tree, &mut Friedman1::new(seed, 1.0), instances, 0);
        let (leaves, splits, elements) =
            (tree.n_leaves(), tree.n_splits(), tree.total_elements());
        rows.push(row_of(&name, &report, elements, leaves, splits));
    }
    rows
}

fn row_of(
    name: &str,
    report: &PrequentialReport,
    elements: usize,
    leaves: usize,
    splits: usize,
) -> TreeRow {
    TreeRow {
        model: name.to_string(),
        mae: report.metrics.mae(),
        rmse: report.metrics.rmse(),
        r2: report.metrics.r2(),
        seconds: report.seconds,
        throughput: report.throughput(),
        elements,
        leaves,
        splits,
    }
}

/// Render + persist under `results/tree/`.
pub fn generate(instances: usize, seed: u64) -> anyhow::Result<String> {
    let rows = run(instances, seed);
    let mut table = Table::new(vec![
        "model", "MAE", "RMSE", "R2", "time_s", "inst/s", "elements", "leaves", "splits",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fnum(r.mae),
            fnum(r.rmse),
            fnum(r.r2),
            fnum(r.seconds),
            fnum(r.throughput),
            r.elements.to_string(),
            r.leaves.to_string(),
            r.splits.to_string(),
        ]);
    }
    let rendered = format!(
        "Tree integration benchmark (Friedman #1, {instances} instances, prequential)\n{}",
        table.render()
    );
    let report = Report::create("tree")?;
    report.write_table("tree", &table)?;
    report.write_text("summary.txt", &rendered)?;
    Ok(rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_trees_beat_the_mean_baseline() {
        let rows = run(8000, 3);
        let baseline = rows[0].rmse;
        assert_eq!(rows.len(), 6);
        for r in &rows[1..] {
            assert!(r.rmse < baseline, "{}: {} vs {}", r.model, r.rmse, baseline);
            assert!(r.splits >= 1, "{} never split", r.model);
        }
    }

    #[test]
    fn generate_writes_results() {
        let text = generate(4000, 5).unwrap();
        assert!(text.contains("htr[QO_s2]"));
        assert!(std::path::Path::new("results/tree/tree.csv").exists());
    }
}
