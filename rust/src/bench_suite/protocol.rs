//! The simulation protocol of the paper's Table 1: the cross product of
//! sample sizes × sampling distributions × target functions × noise
//! settings × repetitions.

use crate::stream::synth::{Distribution, NoiseSpec, TargetFn};

/// The paper's 19 sample sizes.
pub const PAPER_SIZES: &[usize] = &[
    50, 100, 200, 400, 500, 750, 1000, 2500, 5000, 7000, 10_000, 15_000, 25_000, 50_000, 75_000,
    100_000, 200_000, 500_000, 1_000_000,
];

/// How much of the grid to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Everything in Table 1 (hours of wall-clock on one core).
    Full,
    /// Sizes up to 50k, 5 repetitions — preserves every qualitative
    /// comparison at ~1% of the cost. Default for `qostream`.
    Standard,
    /// Sizes up to 5k, 2 repetitions — smoke profile for `cargo bench`.
    Quick,
}

impl Profile {
    pub fn sizes(&self) -> Vec<usize> {
        match self {
            Profile::Full => PAPER_SIZES.to_vec(),
            Profile::Standard => PAPER_SIZES.iter().copied().filter(|&s| s <= 50_000).collect(),
            Profile::Quick => PAPER_SIZES.iter().copied().filter(|&s| s <= 5_000).collect(),
        }
    }

    pub fn repetitions(&self) -> usize {
        match self {
            Profile::Full => 10,
            Profile::Standard => 5,
            Profile::Quick => 2,
        }
    }

    pub fn parse(s: &str) -> Option<Profile> {
        match s {
            "full" => Some(Profile::Full),
            "standard" => Some(Profile::Standard),
            "quick" => Some(Profile::Quick),
            _ => None,
        }
    }
}

/// One experimental cell: a fully specified sample generation setting.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub size: usize,
    pub dist: Distribution,
    pub target: TargetFn,
    pub noise_fraction: f64,
    pub repetition: usize,
}

impl Cell {
    pub fn noise(&self) -> NoiseSpec {
        NoiseSpec::for_distribution(&self.dist, self.noise_fraction)
    }

    /// Deterministic seed: every (cell, repetition) gets its own stream.
    pub fn seed(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a over the cell identity
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        mix(self.size as u64);
        for b in self.dist.label().bytes() {
            mix(b as u64);
        }
        for b in self.target.label().bytes() {
            mix(b as u64);
        }
        mix((self.noise_fraction * 1000.0) as u64);
        mix(self.repetition as u64);
        h
    }

    /// The "dataset" identity used for Friedman ranking (everything except
    /// the repetition; the paper averages repetitions before ranking).
    pub fn dataset_key(&self) -> String {
        format!(
            "{}|{}|{}|{}",
            self.size,
            self.dist.label(),
            self.target.label(),
            self.noise_fraction
        )
    }
}

/// The full grid for a profile.
#[derive(Clone, Debug)]
pub struct Protocol {
    pub profile: Profile,
    pub sizes: Vec<usize>,
    pub repetitions: usize,
}

impl Protocol {
    pub fn new(profile: Profile) -> Protocol {
        Protocol { profile, sizes: profile.sizes(), repetitions: profile.repetitions() }
    }

    /// Restrict to explicit sizes (CLI `--sizes`).
    pub fn with_sizes(mut self, sizes: Vec<usize>) -> Protocol {
        self.sizes = sizes;
        self
    }

    pub fn with_repetitions(mut self, reps: usize) -> Protocol {
        self.repetitions = reps;
        self
    }

    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::new();
        for &size in &self.sizes {
            for dist in Distribution::table1() {
                for target in [TargetFn::Linear, TargetFn::Cubic] {
                    for noise_fraction in [0.0, 0.1] {
                        for repetition in 0..self.repetitions {
                            out.push(Cell { size, dist, target, noise_fraction, repetition });
                        }
                    }
                }
            }
        }
        out
    }

    pub fn describe(&self) -> String {
        format!(
            "profile={:?} sizes={:?} dists=9 targets=[lin,cub] noise=[0%,10%] reps={} -> {} cells",
            self.profile,
            self.sizes,
            self.repetitions,
            self.cells().len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_match_table1() {
        assert_eq!(PAPER_SIZES.len(), 19);
        assert_eq!(PAPER_SIZES[0], 50);
        assert_eq!(*PAPER_SIZES.last().unwrap(), 1_000_000);
    }

    #[test]
    fn full_grid_cell_count() {
        // 19 sizes x 9 dists x 2 targets x 2 noise x 10 reps
        let p = Protocol::new(Profile::Full);
        assert_eq!(p.cells().len(), 19 * 9 * 2 * 2 * 10);
    }

    #[test]
    fn quick_profile_is_small() {
        let p = Protocol::new(Profile::Quick);
        assert!(p.cells().len() < 2000);
        assert!(p.sizes.iter().all(|&s| s <= 5000));
    }

    #[test]
    fn seeds_differ_across_cells_and_reps() {
        let p = Protocol::new(Profile::Quick);
        let cells = p.cells();
        let mut seeds: Vec<u64> = cells.iter().map(|c| c.seed()).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), cells.len(), "seed collision");
    }

    #[test]
    fn dataset_key_ignores_repetition() {
        let p = Protocol::new(Profile::Quick);
        let cells = p.cells();
        let a = &cells[0];
        let b = cells.iter().find(|c| c.repetition == 1).unwrap();
        // same generation settings, different rep -> same dataset key when
        // the rest matches
        if a.size == b.size
            && a.dist == b.dist
            && a.target == b.target
            && a.noise_fraction == b.noise_fraction
        {
            assert_eq!(a.dataset_key(), b.dataset_key());
        }
        assert_ne!(a.seed(), b.seed());
    }
}
