//! `SplitBackend`: pluggable engines for split-candidate evaluation.
//!
//! The paper makes split *queries* sub-linear per observer; this module
//! makes them batched across observers — all features of a leaf, and (via
//! [`crate::forest::batch`]) all due leaves across every forest member —
//! so one engine call amortizes the query loop the way the XLA artifact
//! amortizes its PJRT dispatch:
//!
//! * [`PerObserverBackend`] — the original path: each observer answers its
//!   own `best_split` query independently.
//! * [`NativeBatchBackend`] — packs every frozen Quantization Observer
//!   into one flat slot arena (reusing [`SlotTable::from_qo`]) and
//!   evaluates the whole batch in a single cache-friendly pass. Produces
//!   **bit-identical** results to the per-observer path (asserted by a
//!   property test below); non-QO observers fall back transparently.
//! * [`XlaSplitBackend`] — the AOT JAX/Pallas `split_eval` artifact on
//!   PJRT behind the same trait; construction fails cleanly when the
//!   runtime or artifacts are absent (callers fall back, exactly like the
//!   `runtime_roundtrip` tests self-skip).
//!
//! [`SplitBackendKind`] is the `Copy` configuration knob carried by
//! [`crate::tree::HtrOptions`] and exposed by the CLI's
//! `--split-backend` flag.

use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::criterion::SplitCriterion;
use crate::observer::qo::SplitPointStrategy;
use crate::observer::{AttributeObserver, SplitSuggestion};
use crate::stats::VarStats;

use super::artifact::{find_artifacts_dir, Manifest};
use super::split_engine::{SlotTable, XlaSplit, XlaSplitEngine};

/// One split-candidate query: an observer plus the merit criterion its
/// owning tree evaluates candidates under.
#[derive(Clone, Copy)]
pub struct SplitQuery<'a> {
    pub observer: &'a dyn AttributeObserver,
    pub criterion: &'a dyn SplitCriterion,
}

/// A split-candidate evaluation engine. `best_splits` answers one query
/// per input observer, in order; `None` means the observer has no
/// admissible candidate (fewer than two partitions observed).
pub trait SplitBackend: Send + Sync {
    fn name(&self) -> &'static str;

    fn best_splits(&self, queries: &[SplitQuery<'_>]) -> Vec<Option<SplitSuggestion>>;
}

/// The original query path: every observer answers independently.
#[derive(Clone, Copy, Debug, Default)]
pub struct PerObserverBackend;

impl SplitBackend for PerObserverBackend {
    fn name(&self) -> &'static str {
        "per-observer"
    }

    fn best_splits(&self, queries: &[SplitQuery<'_>]) -> Vec<Option<SplitSuggestion>> {
        queries.iter().map(|q| q.observer.best_split(q.criterion)).collect()
    }
}

/// How one packed query resolves its candidate thresholds.
enum ThresholdRule {
    /// Midpoint of consecutive slot prototypes (paper Alg. 2).
    Prototype,
    /// Grid edge after the left slot: `(code + 1) · r` (ablation strategy).
    Grid { radius: f64, codes_start: usize },
}

/// One packed query: a contiguous segment of the flat slot arena.
struct Segment {
    start: usize,
    len: usize,
    total: VarStats,
    rule: ThresholdRule,
}

enum Plan {
    /// Not packable (non-QO, warming radius, < 2 slots): query directly.
    Direct,
    Packed(Segment),
}

/// Batched native evaluation: all packable observers share one flat slot
/// arena and are answered in a single pass. Bit-identical to
/// [`PerObserverBackend`] by construction — the evaluation replays exactly
/// the per-observer query arithmetic (same merges, same order, same
/// threshold formulas) over the packed copies of the same slot statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBatchBackend;

impl SplitBackend for NativeBatchBackend {
    fn name(&self) -> &'static str {
        "native-batch"
    }

    fn best_splits(&self, queries: &[SplitQuery<'_>]) -> Vec<Option<SplitSuggestion>> {
        // Pack phase: one flat arena across every packable query.
        let mut flat = SlotTable::default();
        let mut codes: Vec<i64> = Vec::new();
        let mut plans: Vec<Plan> = Vec::with_capacity(queries.len());
        for q in queries {
            let Some(qo) = q.observer.as_qo() else {
                plans.push(Plan::Direct);
                continue;
            };
            let Some(radius) = qo.radius() else {
                // still warming: the buffered sweep is not slot-shaped
                plans.push(Plan::Direct);
                continue;
            };
            // single pass: the observer's sorted slots land directly in
            // the arena (same sort the per-observer query pays, no
            // intermediate per-query table)
            let start = flat.n.len();
            let len = flat.append_qo(qo);
            if len < 2 {
                flat.truncate(start);
                plans.push(Plan::Direct);
                continue;
            }
            let rule = match qo.strategy() {
                SplitPointStrategy::PrototypeMidpoint => ThresholdRule::Prototype,
                SplitPointStrategy::GridBoundary => {
                    // bucket codes are only needed for the ablation-only
                    // grid strategy; the extra sorted pass is acceptable
                    // off the default path
                    let codes_start = codes.len();
                    codes.extend(qo.sorted_slots().iter().map(|&(code, _)| code));
                    ThresholdRule::Grid { radius, codes_start }
                }
            };
            plans.push(Plan::Packed(Segment { start, len, total: qo.total(), rule }));
        }

        // Eval phase: one pass over the arena, segment by segment.
        queries
            .iter()
            .zip(plans)
            .map(|(q, plan)| match plan {
                Plan::Direct => q.observer.best_split(q.criterion),
                Plan::Packed(seg) => eval_segment(&flat, &codes, &seg, q.criterion),
            })
            .collect()
    }
}

#[inline]
fn slot_stats(flat: &SlotTable, i: usize) -> VarStats {
    VarStats { n: flat.n[i], mean: flat.mean[i], m2: flat.m2[i] }
}

#[inline]
fn prototype(flat: &SlotTable, i: usize) -> f64 {
    if flat.n[i] > 0.0 {
        flat.sum_x[i] / flat.n[i]
    } else {
        0.0
    }
}

/// Replays `QuantizationObserver::best_split` over a packed segment —
/// every operation, order and comparison matches the observer's own query
/// so the result is bit-identical.
fn eval_segment(
    flat: &SlotTable,
    codes: &[i64],
    seg: &Segment,
    criterion: &dyn SplitCriterion,
) -> Option<SplitSuggestion> {
    let total = seg.total;
    let end = seg.start + seg.len;
    let mut left = VarStats::new();
    let mut best: Option<SplitSuggestion> = None;
    for i in seg.start..end - 1 {
        left += slot_stats(flat, i);
        let right = total - left;
        let merit = criterion.merit(&total, &left, &right);
        if best.map(|b| merit > b.merit).unwrap_or(true) {
            let threshold = match seg.rule {
                ThresholdRule::Prototype => {
                    0.5 * (prototype(flat, i) + prototype(flat, i + 1))
                }
                ThresholdRule::Grid { radius, codes_start } => {
                    let code = codes[codes_start + (i - seg.start)];
                    code.saturating_add(1) as f64 * radius
                }
            };
            best = Some(SplitSuggestion { threshold, merit, left, right });
        }
    }
    best
}

/// The AOT `split_eval` artifact behind the [`SplitBackend`] trait.
///
/// Only frozen prototype-midpoint QO tables that fit the engine's static
/// (F, S) shape ride the PJRT path; everything else (and any execution
/// error) falls back to the per-observer query. Branch statistics for the
/// winning cut are reconstructed natively — the artifact returns only
/// `(best_idx, merit, threshold)`.
pub struct XlaSplitBackend {
    engine: XlaSplitEngine,
}

impl XlaSplitBackend {
    /// Load from the discovered artifacts. Errors when PJRT or the
    /// artifacts are absent — callers fall back (the CLI, benches and
    /// [`SplitBackendKind::build`] self-skip exactly like the
    /// `runtime_roundtrip` tests).
    pub fn load() -> Result<XlaSplitBackend> {
        let dir = find_artifacts_dir()?;
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let engine = XlaSplitEngine::load(&client, &manifest)?;
        Ok(XlaSplitBackend { engine })
    }

    /// Wrap an already-loaded engine (tests / custom clients).
    pub fn from_engine(engine: XlaSplitEngine) -> XlaSplitBackend {
        XlaSplitBackend { engine }
    }
}

/// Rebuild branch statistics for an artifact cut. Callers must have
/// validated `xs.best_idx` as an internal boundary (`< table.len() - 1`),
/// otherwise the right branch would be empty.
fn suggestion_from(table: &SlotTable, total: &VarStats, xs: XlaSplit) -> SplitSuggestion {
    debug_assert!(xs.best_idx + 1 < table.len());
    let mut left = VarStats::new();
    for i in 0..=xs.best_idx {
        left += VarStats { n: table.n[i], mean: table.mean[i], m2: table.m2[i] };
    }
    let right = *total - left;
    SplitSuggestion { threshold: xs.threshold, merit: xs.merit, left, right }
}

impl SplitBackend for XlaSplitBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn best_splits(&self, queries: &[SplitQuery<'_>]) -> Vec<Option<SplitSuggestion>> {
        let mut tables: Vec<SlotTable> = Vec::new();
        let mut totals: Vec<VarStats> = Vec::new();
        let mut map: Vec<Option<usize>> = Vec::with_capacity(queries.len());
        for q in queries {
            // the artifact hard-codes variance-reduction scoring and
            // prototype-midpoint thresholds: anything else must take the
            // per-observer path so merits stay comparable within a leaf
            let criterion_matches =
                q.criterion.name() == crate::criterion::VarianceReduction.name();
            let packed = q.observer.as_qo().filter(|_| criterion_matches).and_then(|qo| {
                if qo.radius().is_none()
                    || qo.strategy() != SplitPointStrategy::PrototypeMidpoint
                {
                    return None;
                }
                let table = SlotTable::from_qo(qo);
                if table.len() >= 2 && table.len() <= self.engine.s {
                    Some((table, qo.total()))
                } else {
                    None
                }
            });
            match packed {
                Some((table, total)) => {
                    map.push(Some(tables.len()));
                    tables.push(table);
                    totals.push(total);
                }
                None => map.push(None),
            }
        }
        let evaluated = match self.engine.best_splits(&tables) {
            Ok(results) => results,
            Err(_) => vec![None; tables.len()],
        };
        queries
            .iter()
            .zip(&map)
            .map(|(q, slot)| match slot {
                Some(ti) => match evaluated[*ti] {
                    // the cut index must name an internal boundary;
                    // anything else from the artifact is a shape bug and
                    // falls back like every other engine error
                    Some(xs) if xs.best_idx + 1 < tables[*ti].len() => {
                        Some(suggestion_from(&tables[*ti], &totals[*ti], xs))
                    }
                    _ => q.observer.best_split(q.criterion),
                },
                None => q.observer.best_split(q.criterion),
            })
            .collect()
    }
}

/// Configuration-level backend selector (CLI `--split-backend`, carried by
/// [`crate::tree::HtrOptions::split_backend`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SplitBackendKind {
    /// Query each observer independently (the original path).
    PerObserver,
    /// Flat-packed native batch evaluation (always available,
    /// bit-identical to `PerObserver`). The default.
    #[default]
    NativeBatch,
    /// The AOT PJRT artifact; falls back to `NativeBatch` when the
    /// runtime or artifacts are absent.
    Xla,
}

impl SplitBackendKind {
    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Option<SplitBackendKind> {
        match s {
            "per-observer" | "observer" => Some(SplitBackendKind::PerObserver),
            "native-batch" | "native" | "batch" => Some(SplitBackendKind::NativeBatch),
            "xla" => Some(SplitBackendKind::Xla),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SplitBackendKind::PerObserver => "per-observer",
            SplitBackendKind::NativeBatch => "native-batch",
            SplitBackendKind::Xla => "xla",
        }
    }

    /// Backend object for this kind. `Xla` tries the artifact path once
    /// per process (the engine is shared) and falls back to the native
    /// batch when unavailable.
    pub fn build(&self) -> Arc<dyn SplitBackend> {
        match self {
            SplitBackendKind::PerObserver => Arc::new(PerObserverBackend),
            SplitBackendKind::NativeBatch => Arc::new(NativeBatchBackend),
            SplitBackendKind::Xla => xla_or_fallback(),
        }
    }

    /// Backend object for a tree: `None` for `PerObserver`, whose inline
    /// query loop needs no backend object at all.
    pub fn instantiate(&self) -> Option<Arc<dyn SplitBackend>> {
        match self {
            SplitBackendKind::PerObserver => None,
            other => Some(other.build()),
        }
    }
}

fn xla_or_fallback() -> Arc<dyn SplitBackend> {
    static CACHE: OnceLock<Option<Arc<XlaSplitBackend>>> = OnceLock::new();
    let cached = CACHE.get_or_init(|| match XlaSplitBackend::load() {
        Ok(backend) => Some(Arc::new(backend)),
        Err(err) => {
            eprintln!(
                "split-backend xla unavailable ({err}); falling back to native-batch"
            );
            None
        }
    });
    match cached {
        Some(backend) => {
            let shared: Arc<dyn SplitBackend> = backend.clone();
            shared
        }
        None => Arc::new(NativeBatchBackend),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::check;
    use crate::common::Rng;
    use crate::criterion::VarianceReduction;
    use crate::observer::{EBst, QuantizationObserver, RadiusPolicy};

    fn queries_of<'a>(
        observers: &'a [Box<dyn AttributeObserver>],
        criterion: &'a dyn SplitCriterion,
    ) -> Vec<SplitQuery<'a>> {
        observers
            .iter()
            .map(|ao| SplitQuery { observer: ao.as_ref(), criterion })
            .collect()
    }

    fn bits_identical(a: &Option<SplitSuggestion>, b: &Option<SplitSuggestion>) -> bool {
        match (a, b) {
            (None, None) => true,
            (Some(a), Some(b)) => {
                a.threshold.to_bits() == b.threshold.to_bits()
                    && a.merit.to_bits() == b.merit.to_bits()
                    && a.left.n.to_bits() == b.left.n.to_bits()
                    && a.left.mean.to_bits() == b.left.mean.to_bits()
                    && a.left.m2.to_bits() == b.left.m2.to_bits()
                    && a.right.n.to_bits() == b.right.n.to_bits()
                    && a.right.mean.to_bits() == b.right.mean.to_bits()
                    && a.right.m2.to_bits() == b.right.m2.to_bits()
            }
            _ => false,
        }
    }

    /// The argmax/runner-up selection the tree applies to backend results;
    /// used to assert the chosen (feature, threshold, merit) agrees.
    fn select(results: &[Option<SplitSuggestion>]) -> Option<(usize, u64, u64)> {
        let mut best: Option<(usize, SplitSuggestion)> = None;
        for (slot, s) in results.iter().enumerate() {
            let Some(s) = s else { continue };
            match &best {
                Some((_, b)) if s.merit <= b.merit => {}
                _ => best = Some((slot, *s)),
            }
        }
        best.map(|(slot, s)| (slot, s.threshold.to_bits(), s.merit.to_bits()))
    }

    #[test]
    fn prop_native_batch_bit_identical_to_per_observer() {
        // the satellite contract: across random streams, radii (fixed,
        // dynamic/warming) strategies and observer mixes, the batched
        // backend returns bit-identical (feature, threshold, merit) —
        // and branch statistics — to the per-observer query loop.
        check("native-batch-vs-per-observer", 0xBA7C, 40, |rng| {
            let n_obs = 1 + rng.below(6) as usize;
            let mut observers: Vec<Box<dyn AttributeObserver>> = Vec::new();
            for _ in 0..n_obs {
                let pick = rng.below(5);
                let ao: Box<dyn AttributeObserver> = match pick {
                    0 => Box::new(EBst::new()),
                    1 => Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(
                        2.0,
                    ))),
                    2 => Box::new(
                        QuantizationObserver::with_radius(0.02 + rng.f64() * 0.3)
                            .with_strategy(SplitPointStrategy::GridBoundary),
                    ),
                    _ => Box::new(QuantizationObserver::with_radius(
                        0.02 + rng.f64() * 0.3,
                    )),
                };
                observers.push(ao);
            }
            // random stream; sometimes tiny so warming/no-split paths run
            let n = 3 + rng.below(500);
            for _ in 0..n {
                let x = rng.normal(0.0, 1.0 + rng.f64());
                let y = if rng.bool(0.5) { 3.0 * x } else { x * x } + rng.normal(0.0, 0.2);
                for ao in observers.iter_mut() {
                    ao.observe(x, y, 1.0);
                }
            }
            let criterion = VarianceReduction;
            let queries = queries_of(&observers, &criterion);
            let batched = NativeBatchBackend.best_splits(&queries);
            let direct = PerObserverBackend.best_splits(&queries);
            for (i, (b, d)) in batched.iter().zip(&direct).enumerate() {
                if !bits_identical(b, d) {
                    return Err(format!("observer {i}: {b:?} != {d:?}"));
                }
            }
            if select(&batched) != select(&direct) {
                return Err(format!(
                    "selection disagrees: {:?} vs {:?}",
                    select(&batched),
                    select(&direct)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn native_batch_packs_many_observers_in_one_arena() {
        let mut rng = Rng::new(5);
        let observers: Vec<Box<dyn AttributeObserver>> = (0..8)
            .map(|_| {
                let mut qo = QuantizationObserver::with_radius(0.1);
                for _ in 0..2000 {
                    let x = rng.normal(0.0, 1.0);
                    qo.observe(x, if x <= 0.2 { 0.0 } else { 1.0 }, 1.0);
                }
                Box::new(qo) as Box<dyn AttributeObserver>
            })
            .collect();
        let criterion = VarianceReduction;
        let queries = queries_of(&observers, &criterion);
        let results = NativeBatchBackend.best_splits(&queries);
        assert_eq!(results.len(), 8);
        for (ao, r) in observers.iter().zip(&results) {
            let s = r.expect("step function must split");
            assert!((s.threshold - 0.2).abs() < 0.15, "threshold={}", s.threshold);
            assert!(bits_identical(r, &ao.best_split(&VarianceReduction)));
        }
    }

    #[test]
    fn kind_parse_and_labels_roundtrip() {
        for kind in [
            SplitBackendKind::PerObserver,
            SplitBackendKind::NativeBatch,
            SplitBackendKind::Xla,
        ] {
            assert_eq!(SplitBackendKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(SplitBackendKind::parse("native"), Some(SplitBackendKind::NativeBatch));
        assert_eq!(SplitBackendKind::parse("nope"), None);
        assert_eq!(SplitBackendKind::default(), SplitBackendKind::NativeBatch);
    }

    #[test]
    fn xla_kind_falls_back_without_runtime() {
        // the offline stub has no PJRT: building the xla kind must yield a
        // working backend (native-batch fallback), never a panic
        let backend = SplitBackendKind::Xla.build();
        let mut qo = QuantizationObserver::with_radius(0.1);
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let x = rng.uniform(-1.0, 1.0);
            qo.observe(x, if x <= 0.0 { 0.0 } else { 1.0 }, 1.0);
        }
        let criterion = VarianceReduction;
        let queries = [SplitQuery { observer: &qo, criterion: &criterion }];
        let results = backend.best_splits(&queries);
        assert_eq!(results.len(), 1);
        assert!(results[0].is_some());
    }

    #[test]
    fn per_observer_kind_instantiates_to_none() {
        assert!(SplitBackendKind::PerObserver.instantiate().is_none());
        assert!(SplitBackendKind::NativeBatch.instantiate().is_some());
    }
}
