//! PJRT/XLA runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from rust. Python never runs on this path.
//!
//! The interchange format is HLO *text*: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids which the crate's bundled XLA (0.5.1) rejects;
//! the text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

pub mod artifact;
pub mod backend;
pub mod quantize_engine;
pub mod split_engine;

pub use artifact::{find_artifacts_dir, Manifest};
pub use backend::{
    NativeBatchBackend, PerObserverBackend, SplitBackend, SplitBackendKind, SplitQuery,
    XlaSplitBackend,
};
pub use quantize_engine::XlaQuantizeEngine;
pub use split_engine::{SlotTable, XlaSplitEngine};
