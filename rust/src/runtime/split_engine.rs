//! `XlaSplitEngine`: the AOT-compiled split-candidate evaluator.
//!
//! Executes the `split_eval` artifact (L2 JAX graph wrapping the L1
//! `vr_split` Pallas kernel) on batches of packed slot tables — evaluating
//! the best split of up to F features in one PJRT call. The tree and the
//! benches use it as an alternative backend to the native rust query path
//! (`cargo bench --bench xla_vs_native` compares them).

use anyhow::{anyhow, Context, Result};

use crate::observer::qo::QuantizationObserver;
use crate::stats::VarStats;

use super::artifact::Manifest;

/// Packed, key-sorted slot statistics for one feature (padding implicit).
#[derive(Clone, Debug, Default)]
pub struct SlotTable {
    pub n: Vec<f64>,
    pub sum_x: Vec<f64>,
    pub mean: Vec<f64>,
    pub m2: Vec<f64>,
}

impl SlotTable {
    pub fn len(&self) -> usize {
        self.n.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n.is_empty()
    }

    /// Extract from a Quantization Observer's hash (sorted by code).
    pub fn from_qo(qo: &QuantizationObserver) -> SlotTable {
        let mut t = SlotTable::default();
        t.append_qo(qo);
        t
    }

    /// Append one observer's slots (sorted by code) to this table in a
    /// single pass — the batched backend packs many observers into one
    /// flat arena this way, with no intermediate per-query table. Returns
    /// the number of appended slots.
    pub fn append_qo(&mut self, qo: &QuantizationObserver) -> usize {
        let slots = qo.sorted_slots();
        self.n.reserve(slots.len());
        self.sum_x.reserve(slots.len());
        self.mean.reserve(slots.len());
        self.m2.reserve(slots.len());
        for (_, slot) in &slots {
            self.n.push(slot.stats.n);
            self.sum_x.push(slot.sum_x);
            self.mean.push(slot.stats.mean);
            self.m2.push(slot.stats.m2);
        }
        slots.len()
    }

    /// Drop every row from `len` on (undo of a partial [`Self::append_qo`]).
    pub fn truncate(&mut self, len: usize) {
        self.n.truncate(len);
        self.sum_x.truncate(len);
        self.mean.truncate(len);
        self.m2.truncate(len);
    }
}

/// Result of the XLA evaluation for one feature.
#[derive(Clone, Copy, Debug)]
pub struct XlaSplit {
    pub best_idx: usize,
    pub merit: f64,
    pub threshold: f64,
}

/// PJRT-compiled `split_eval` executable with its static (F, S) shape.
pub struct XlaSplitEngine {
    exe: xla::PjRtLoadedExecutable,
    /// features per call (AOT batch dimension)
    pub f: usize,
    /// slot capacity per feature
    pub s: usize,
}

impl XlaSplitEngine {
    /// Compile the artifact recorded in the manifest.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<XlaSplitEngine> {
        let path = manifest.path_of("split_eval")?;
        let f = manifest.get_usize("split_eval.f")?;
        let s = manifest.get_usize("split_eval.s")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling split_eval artifact")?;
        Ok(XlaSplitEngine { exe, f, s })
    }

    /// Evaluate best splits for up to `self.f` features per call; longer
    /// inputs are processed in chunks. Features whose table exceeds `s`
    /// slots or has fewer than 2 slots yield `None` (callers fall back to
    /// the native query path).
    pub fn best_splits(&self, tables: &[SlotTable]) -> Result<Vec<Option<XlaSplit>>> {
        let mut out = Vec::with_capacity(tables.len());
        for chunk in tables.chunks(self.f) {
            out.extend(self.eval_chunk(chunk)?);
        }
        Ok(out)
    }

    fn eval_chunk(&self, chunk: &[SlotTable]) -> Result<Vec<Option<XlaSplit>>> {
        let (f, s) = (self.f, self.s);
        let mut n = vec![0f64; f * s];
        let mut sum_x = vec![0f64; f * s];
        let mut mean = vec![0f64; f * s];
        let mut m2 = vec![0f64; f * s];
        let mut evaluable = vec![false; chunk.len()];
        for (fi, table) in chunk.iter().enumerate() {
            if table.len() < 2 || table.len() > s {
                continue; // not evaluable on this engine shape
            }
            evaluable[fi] = true;
            let base = fi * s;
            n[base..base + table.len()].copy_from_slice(&table.n);
            sum_x[base..base + table.len()].copy_from_slice(&table.sum_x);
            mean[base..base + table.len()].copy_from_slice(&table.mean);
            m2[base..base + table.len()].copy_from_slice(&table.m2);
        }

        let lit = |data: &[f64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[f as i64, s as i64])?)
        };
        let args = [lit(&n)?, lit(&sum_x)?, lit(&mean)?, lit(&m2)?];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True:
        // (vr[F,S], split[F,S], best_idx[F] s32, best_vr[F], best_split[F])
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
        let best_idx = parts[2].to_vec::<i32>()?;
        let best_vr = parts[3].to_vec::<f64>()?;
        let best_split = parts[4].to_vec::<f64>()?;

        Ok((0..chunk.len())
            .map(|fi| {
                if !evaluable[fi] || !best_vr[fi].is_finite() {
                    None
                } else {
                    Some(XlaSplit {
                        best_idx: best_idx[fi] as usize,
                        merit: best_vr[fi],
                        threshold: best_split[fi],
                    })
                }
            })
            .collect())
    }

    /// Convenience: evaluate a set of QO observers directly.
    pub fn best_splits_for_observers(
        &self,
        observers: &[&QuantizationObserver],
    ) -> Result<Vec<Option<XlaSplit>>> {
        let tables: Vec<SlotTable> = observers.iter().map(|qo| SlotTable::from_qo(qo)).collect();
        self.best_splits(&tables)
    }
}

/// Native reference computation over a [`SlotTable`] — the exact same math
/// as the artifact, used by the round-trip tests and the comparison bench.
///
/// Zero-weight slots (possible in hand-built or padded tables; a live QO
/// never produces them) are skipped entirely, matching the XLA path's
/// `evaluable` guard: they contribute no statistics, host no cut, and —
/// crucially — never enter the `sum_x / n` prototype division, which would
/// otherwise yield a NaN threshold that silently poisons the suggestion.
pub fn native_best_split(table: &SlotTable) -> Option<XlaSplit> {
    let occupied: Vec<usize> = (0..table.len()).filter(|&i| table.n[i] > 0.0).collect();
    if occupied.len() < 2 {
        return None;
    }
    let mut total = VarStats::new();
    for &i in &occupied {
        total += VarStats { n: table.n[i], mean: table.mean[i], m2: table.m2[i] };
    }
    let mut left = VarStats::new();
    let mut best: Option<XlaSplit> = None;
    for pair in occupied.windows(2) {
        let (i, j) = (pair[0], pair[1]);
        left += VarStats { n: table.n[i], mean: table.mean[i], m2: table.m2[i] };
        let right = total - left;
        let merit = crate::criterion::SplitCriterion::merit(
            &crate::criterion::VarianceReduction,
            &total,
            &left,
            &right,
        );
        let proto_i = table.sum_x[i] / table.n[i];
        let proto_j = table.sum_x[j] / table.n[j];
        if best.map(|b| merit > b.merit).unwrap_or(true) {
            best = Some(XlaSplit { best_idx: i, merit, threshold: 0.5 * (proto_i + proto_j) });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observer::AttributeObserver;

    #[test]
    fn slot_table_from_qo_sorted() {
        let mut qo = QuantizationObserver::with_radius(0.5);
        for (x, y) in [(1.2, 1.0), (-0.7, 2.0), (0.1, 3.0), (1.4, 4.0)] {
            qo.observe(x, y, 1.0);
        }
        let t = SlotTable::from_qo(&qo);
        assert_eq!(t.len(), 3); // codes -2, 0, 2
        // sorted by code: prototypes increase
        assert!(t.sum_x[0] / t.n[0] < t.sum_x[1] / t.n[1]);
        assert!(t.sum_x[1] / t.n[1] < t.sum_x[2] / t.n[2]);
    }

    #[test]
    fn native_best_split_step() {
        let t = SlotTable {
            n: vec![5.0, 5.0, 5.0, 5.0],
            sum_x: vec![-10.0, -5.0, 5.0, 10.0],
            mean: vec![0.0, 0.0, 8.0, 8.0],
            m2: vec![0.0; 4],
        };
        let s = native_best_split(&t).unwrap();
        assert_eq!(s.best_idx, 1);
        assert!((s.threshold - 0.0).abs() < 1e-12);
    }

    #[test]
    fn native_none_for_single_slot() {
        let t = SlotTable { n: vec![3.0], sum_x: vec![1.0], mean: vec![0.5], m2: vec![0.1] };
        assert!(native_best_split(&t).is_none());
    }

    #[test]
    fn native_skips_zero_weight_slots() {
        // regression: a padded table used to divide sum_x/n on an empty
        // slot, propagating a NaN threshold into the suggestion
        let dense = SlotTable {
            n: vec![5.0, 5.0],
            sum_x: vec![-5.0, 5.0],
            mean: vec![0.0, 8.0],
            m2: vec![0.0, 0.0],
        };
        let padded = SlotTable {
            n: vec![0.0, 5.0, 0.0, 5.0, 0.0],
            sum_x: vec![0.0, -5.0, 0.0, 5.0, 0.0],
            mean: vec![0.0, 0.0, 0.0, 8.0, 0.0],
            m2: vec![0.0; 5],
        };
        let a = native_best_split(&dense).unwrap();
        let b = native_best_split(&padded).unwrap();
        assert!(b.threshold.is_finite(), "padding leaked a NaN: {}", b.threshold);
        assert_eq!(b.best_idx, 1, "cut must sit on the occupied slot");
        assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
        assert_eq!(a.merit.to_bits(), b.merit.to_bits());
    }

    #[test]
    fn native_none_when_fewer_than_two_occupied() {
        let t = SlotTable {
            n: vec![0.0, 4.0, 0.0],
            sum_x: vec![0.0, 2.0, 0.0],
            mean: vec![0.0, 1.5, 0.0],
            m2: vec![0.0, 0.2, 0.0],
        };
        assert!(native_best_split(&t).is_none());
        let empty = SlotTable {
            n: vec![0.0, 0.0],
            sum_x: vec![0.0, 0.0],
            mean: vec![0.0, 0.0],
            m2: vec![0.0, 0.0],
        };
        assert!(native_best_split(&empty).is_none());
    }
}
