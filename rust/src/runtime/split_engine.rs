//! `XlaSplitEngine`: the AOT-compiled split-candidate evaluator.
//!
//! Executes the `split_eval` artifact (L2 JAX graph wrapping the L1
//! `vr_split` Pallas kernel) on batches of packed slot tables — evaluating
//! the best split of up to F features in one PJRT call. The tree and the
//! benches use it as an alternative backend to the native rust query path
//! (`cargo bench --bench xla_vs_native` compares them).

use anyhow::{anyhow, Context, Result};

use crate::observer::qo::QuantizationObserver;
use crate::stats::VarStats;

use super::artifact::Manifest;

/// Packed, key-sorted slot statistics for one feature (padding implicit).
#[derive(Clone, Debug, Default)]
pub struct SlotTable {
    pub n: Vec<f64>,
    pub sum_x: Vec<f64>,
    pub mean: Vec<f64>,
    pub m2: Vec<f64>,
}

impl SlotTable {
    pub fn len(&self) -> usize {
        self.n.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n.is_empty()
    }

    /// Extract from a Quantization Observer's hash (sorted by code).
    pub fn from_qo(qo: &QuantizationObserver) -> SlotTable {
        let slots = qo.sorted_slots();
        let mut t = SlotTable {
            n: Vec::with_capacity(slots.len()),
            sum_x: Vec::with_capacity(slots.len()),
            mean: Vec::with_capacity(slots.len()),
            m2: Vec::with_capacity(slots.len()),
        };
        for (_, slot) in slots {
            t.n.push(slot.stats.n);
            t.sum_x.push(slot.sum_x);
            t.mean.push(slot.stats.mean);
            t.m2.push(slot.stats.m2);
        }
        t
    }
}

/// Result of the XLA evaluation for one feature.
#[derive(Clone, Copy, Debug)]
pub struct XlaSplit {
    pub best_idx: usize,
    pub merit: f64,
    pub threshold: f64,
}

/// PJRT-compiled `split_eval` executable with its static (F, S) shape.
pub struct XlaSplitEngine {
    exe: xla::PjRtLoadedExecutable,
    /// features per call (AOT batch dimension)
    pub f: usize,
    /// slot capacity per feature
    pub s: usize,
}

impl XlaSplitEngine {
    /// Compile the artifact recorded in the manifest.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<XlaSplitEngine> {
        let path = manifest.path_of("split_eval")?;
        let f = manifest.get_usize("split_eval.f")?;
        let s = manifest.get_usize("split_eval.s")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling split_eval artifact")?;
        Ok(XlaSplitEngine { exe, f, s })
    }

    /// Evaluate best splits for up to `self.f` features per call; longer
    /// inputs are processed in chunks. Features whose table exceeds `s`
    /// slots or has fewer than 2 slots yield `None` (callers fall back to
    /// the native query path).
    pub fn best_splits(&self, tables: &[SlotTable]) -> Result<Vec<Option<XlaSplit>>> {
        let mut out = Vec::with_capacity(tables.len());
        for chunk in tables.chunks(self.f) {
            out.extend(self.eval_chunk(chunk)?);
        }
        Ok(out)
    }

    fn eval_chunk(&self, chunk: &[SlotTable]) -> Result<Vec<Option<XlaSplit>>> {
        let (f, s) = (self.f, self.s);
        let mut n = vec![0f64; f * s];
        let mut sum_x = vec![0f64; f * s];
        let mut mean = vec![0f64; f * s];
        let mut m2 = vec![0f64; f * s];
        let mut evaluable = vec![false; chunk.len()];
        for (fi, table) in chunk.iter().enumerate() {
            if table.len() < 2 || table.len() > s {
                continue; // not evaluable on this engine shape
            }
            evaluable[fi] = true;
            let base = fi * s;
            n[base..base + table.len()].copy_from_slice(&table.n);
            sum_x[base..base + table.len()].copy_from_slice(&table.sum_x);
            mean[base..base + table.len()].copy_from_slice(&table.mean);
            m2[base..base + table.len()].copy_from_slice(&table.m2);
        }

        let lit = |data: &[f64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[f as i64, s as i64])?)
        };
        let args = [lit(&n)?, lit(&sum_x)?, lit(&mean)?, lit(&m2)?];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True:
        // (vr[F,S], split[F,S], best_idx[F] s32, best_vr[F], best_split[F])
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
        let best_idx = parts[2].to_vec::<i32>()?;
        let best_vr = parts[3].to_vec::<f64>()?;
        let best_split = parts[4].to_vec::<f64>()?;

        Ok((0..chunk.len())
            .map(|fi| {
                if !evaluable[fi] || !best_vr[fi].is_finite() {
                    None
                } else {
                    Some(XlaSplit {
                        best_idx: best_idx[fi] as usize,
                        merit: best_vr[fi],
                        threshold: best_split[fi],
                    })
                }
            })
            .collect())
    }

    /// Convenience: evaluate a set of QO observers directly.
    pub fn best_splits_for_observers(
        &self,
        observers: &[&QuantizationObserver],
    ) -> Result<Vec<Option<XlaSplit>>> {
        let tables: Vec<SlotTable> = observers.iter().map(|qo| SlotTable::from_qo(qo)).collect();
        self.best_splits(&tables)
    }
}

/// Native reference computation over a [`SlotTable`] — the exact same math
/// as the artifact, used by the round-trip tests and the comparison bench.
pub fn native_best_split(table: &SlotTable) -> Option<XlaSplit> {
    if table.len() < 2 {
        return None;
    }
    let mut total = VarStats::new();
    for i in 0..table.len() {
        total += VarStats { n: table.n[i], mean: table.mean[i], m2: table.m2[i] };
    }
    let mut left = VarStats::new();
    let mut best: Option<XlaSplit> = None;
    for i in 0..table.len() - 1 {
        left += VarStats { n: table.n[i], mean: table.mean[i], m2: table.m2[i] };
        let right = total - left;
        let merit = crate::criterion::SplitCriterion::merit(
            &crate::criterion::VarianceReduction,
            &total,
            &left,
            &right,
        );
        let proto_i = table.sum_x[i] / table.n[i];
        let proto_j = table.sum_x[i + 1] / table.n[i + 1];
        if best.map(|b| merit > b.merit).unwrap_or(true) {
            best = Some(XlaSplit { best_idx: i, merit, threshold: 0.5 * (proto_i + proto_j) });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observer::AttributeObserver;

    #[test]
    fn slot_table_from_qo_sorted() {
        let mut qo = QuantizationObserver::with_radius(0.5);
        for (x, y) in [(1.2, 1.0), (-0.7, 2.0), (0.1, 3.0), (1.4, 4.0)] {
            qo.observe(x, y, 1.0);
        }
        let t = SlotTable::from_qo(&qo);
        assert_eq!(t.len(), 3); // codes -2, 0, 2
        // sorted by code: prototypes increase
        assert!(t.sum_x[0] / t.n[0] < t.sum_x[1] / t.n[1]);
        assert!(t.sum_x[1] / t.n[1] < t.sum_x[2] / t.n[2]);
    }

    #[test]
    fn native_best_split_step() {
        let t = SlotTable {
            n: vec![5.0, 5.0, 5.0, 5.0],
            sum_x: vec![-10.0, -5.0, 5.0, 10.0],
            mean: vec![0.0, 0.0, 8.0, 8.0],
            m2: vec![0.0; 4],
        };
        let s = native_best_split(&t).unwrap();
        assert_eq!(s.best_idx, 1);
        assert!((s.threshold - 0.0).abs() < 1e-12);
    }

    #[test]
    fn native_none_for_single_slot() {
        let t = SlotTable { n: vec![3.0], sum_x: vec![1.0], mean: vec![0.5], m2: vec![0.1] };
        assert!(native_best_split(&t).is_none());
    }
}
