//! Artifact discovery and the `manifest.txt` parser.
//!
//! `make artifacts` writes a plain `key=value` manifest next to the HLO
//! text files; this module locates the directory (``QOSTREAM_ARTIFACTS``
//! env var, or an ``artifacts/`` directory walking up from the current
//! directory) and exposes the recorded shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Parsed `manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: BTreeMap<String, String>,
}

impl Manifest {
    /// Load `manifest.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Manifest { dir: dir.to_path_buf(), entries: parse_manifest(&text) })
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.entries
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("manifest missing key {key:?}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("manifest key {key:?} not an integer"))
    }

    /// Absolute path of the artifact file recorded under `key`.
    pub fn path_of(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.get(key)?))
    }
}

fn parse_manifest(text: &str) -> BTreeMap<String, String> {
    text.lines()
        .filter_map(|line| {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                return None;
            }
            line.split_once('=').map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

/// Locate the artifacts directory: `QOSTREAM_ARTIFACTS`, else walk up from
/// the working directory looking for `artifacts/manifest.txt`.
pub fn find_artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("QOSTREAM_ARTIFACTS") {
        let p = PathBuf::from(dir);
        anyhow::ensure!(p.join("manifest.txt").exists(), "QOSTREAM_ARTIFACTS has no manifest.txt");
        return Ok(p);
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("manifest.txt").exists() {
            return Ok(candidate);
        }
        if !cur.pop() {
            return Err(anyhow!(
                "artifacts/ not found (run `make artifacts` or set QOSTREAM_ARTIFACTS)"
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ignores_comments_and_blank_lines() {
        let m = parse_manifest("# c\n\na=1\n b = two \n");
        assert_eq!(m.get("a").unwrap(), "1");
        assert_eq!(m.get("b").unwrap(), "two");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn manifest_accessors() {
        let dir = std::env::temp_dir().join(format!("qostream-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "split_eval=se.hlo.txt\nsplit_eval.f=8\n")
            .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.get("split_eval").unwrap(), "se.hlo.txt");
        assert_eq!(m.get_usize("split_eval.f").unwrap(), 8);
        assert!(m.get("nope").is_err());
        assert!(m.path_of("split_eval").unwrap().ends_with("se.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_artifacts_in_repo() {
        // the repo's own artifacts/ should be discoverable from the test cwd
        if let Ok(dir) = find_artifacts_dir() {
            assert!(dir.join("manifest.txt").exists());
        }
    }
}
