//! `XlaQuantizeEngine`: the AOT-compiled bulk Quantization-Observer update
//! (paper Alg. 1 as a batched segment-sum, L1 `quantize` Pallas kernel).
//!
//! Used by replay/warm-start paths: ingest a window of (x, y) pairs in one
//! PJRT call, producing a dense slot table that merges into a
//! [`QuantizationObserver`] via the Chan formulas.

use anyhow::{anyhow, Context, Result};

use crate::observer::qo::QuantizationObserver;
use crate::stats::VarStats;

use super::artifact::Manifest;

/// One aggregated slot from a batched ingest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IngestedSlot {
    pub code: i64,
    pub n: f64,
    pub sum_x: f64,
    pub sum_y: f64,
    pub sum_y2: f64,
}

impl IngestedSlot {
    /// Robust (n, mean, M2) view of the slot's target statistics.
    pub fn stats(&self) -> VarStats {
        if self.n <= 0.0 {
            return VarStats::EMPTY;
        }
        let mean = self.sum_y / self.n;
        let m2 = (self.sum_y2 - self.sum_y * self.sum_y / self.n).max(0.0);
        VarStats { n: self.n, mean, m2 }
    }
}

/// PJRT-compiled `quantize_ingest` executable with its static (B, S) shape.
pub struct XlaQuantizeEngine {
    exe: xla::PjRtLoadedExecutable,
    /// batch capacity per call
    pub b: usize,
    /// slot-window size per call
    pub s: usize,
}

impl XlaQuantizeEngine {
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<XlaQuantizeEngine> {
        let path = manifest.path_of("quantize")?;
        let b = manifest.get_usize("quantize.b")?;
        let s = manifest.get_usize("quantize.s")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling quantize artifact")?;
        Ok(XlaQuantizeEngine { exe, b, s })
    }

    /// Ingest one batch (padded/truncated to the engine's B) and return
    /// the occupied slots. The kernel windows codes to `[min_code,
    /// min_code + S)`; values outside the window are re-ingested by the
    /// caller loop in [`Self::ingest_all`].
    fn ingest_batch(&self, xs: &[f64], ys: &[f64], radius: f64) -> Result<(Vec<IngestedSlot>, Vec<usize>)> {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty() && xs.len() <= self.b);
        // pad by repeating the first element; subtract its contribution after
        let mut px = xs.to_vec();
        let mut py = ys.to_vec();
        let pad = self.b - xs.len();
        px.resize(self.b, xs[0]);
        py.resize(self.b, ys[0]);

        let x_lit = xla::Literal::vec1(&px);
        let y_lit = xla::Literal::vec1(&py);
        let r_lit = xla::Literal::scalar(radius);
        let result =
            self.exe.execute::<xla::Literal>(&[x_lit, y_lit, r_lit])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 2, "expected 2 outputs, got {}", parts.len());
        let base = parts[0].to_vec::<i32>()?[0] as i64;
        let table = parts[1].to_vec::<f64>()?; // (S, 4) row-major

        // subtract the padding contribution (pad copies of (xs[0], ys[0]))
        let pad_code = QuantizationObserver::code(xs[0], radius) - base;
        let mut slots = Vec::new();
        let mut overflow = Vec::new();
        for si in 0..self.s {
            let row = &table[si * 4..si * 4 + 4];
            let (mut n, mut sx, mut sy, mut sy2) = (row[0], row[1], row[2], row[3]);
            if pad > 0 && si as i64 == pad_code {
                n -= pad as f64;
                sx -= pad as f64 * xs[0];
                sy -= pad as f64 * ys[0];
                sy2 -= pad as f64 * ys[0] * ys[0];
            }
            if n > 1e-9 {
                slots.push(IngestedSlot {
                    code: base + si as i64,
                    n,
                    sum_x: sx,
                    sum_y: sy,
                    sum_y2: sy2,
                });
            }
        }
        // detect dropped elements (codes >= base + S)
        let total: f64 = slots.iter().map(|s| s.n).sum();
        if (total - xs.len() as f64).abs() > 1e-6 {
            for (i, &x) in xs.iter().enumerate() {
                let c = QuantizationObserver::code(x, radius);
                if c - base >= self.s as i64 {
                    overflow.push(i);
                }
            }
        }
        Ok((slots, overflow))
    }

    /// Ingest an arbitrary-length sample, retrying window overflow until
    /// every element is aggregated. Returns slots merged across batches,
    /// sorted by code.
    pub fn ingest_all(&self, xs: &[f64], ys: &[f64], radius: f64) -> Result<Vec<IngestedSlot>> {
        use std::collections::BTreeMap;
        let mut merged: BTreeMap<i64, IngestedSlot> = BTreeMap::new();
        let mut queue: Vec<(f64, f64)> = xs.iter().copied().zip(ys.iter().copied()).collect();
        // sorting bounds the per-batch code range, minimizing overflow passes
        queue.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        while !queue.is_empty() {
            let take = queue.len().min(self.b);
            let batch: Vec<(f64, f64)> = queue.drain(..take).collect();
            let bx: Vec<f64> = batch.iter().map(|p| p.0).collect();
            let by: Vec<f64> = batch.iter().map(|p| p.1).collect();
            let (slots, overflow) = self.ingest_batch(&bx, &by, radius)?;
            for s in slots {
                merged
                    .entry(s.code)
                    .and_modify(|m| {
                        m.n += s.n;
                        m.sum_x += s.sum_x;
                        m.sum_y += s.sum_y;
                        m.sum_y2 += s.sum_y2;
                    })
                    .or_insert(s);
            }
            for i in overflow {
                queue.push(batch[i]);
            }
        }
        Ok(merged.into_values().collect())
    }

    /// Ingest and materialize a ready-to-query [`QuantizationObserver`].
    pub fn build_observer(
        &self,
        xs: &[f64],
        ys: &[f64],
        radius: f64,
    ) -> Result<QuantizationObserver> {
        let slots = self.ingest_all(xs, ys, radius)?;
        let mut qo = QuantizationObserver::with_radius(radius);
        for s in &slots {
            qo.absorb_slot(s.code, s.sum_x, s.stats());
        }
        Ok(qo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingested_slot_stats_roundtrip() {
        // slot holding ys {1, 3}: mean 2, m2 2
        let s = IngestedSlot { code: 0, n: 2.0, sum_x: 0.5, sum_y: 4.0, sum_y2: 10.0 };
        let v = s.stats();
        assert_eq!(v.n, 2.0);
        assert!((v.mean - 2.0).abs() < 1e-12);
        assert!((v.m2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slot_stats() {
        let s = IngestedSlot { code: 0, n: 0.0, sum_x: 0.0, sum_y: 0.0, sum_y2: 0.0 };
        assert!(s.stats().is_empty());
    }
}
