//! Split-merit heuristics for regression trees.
//!
//! The paper evaluates candidates with **Variance Reduction** (Eq. 1,
//! sign-corrected to the FIMT/CART form — see DESIGN.md §4):
//!
//! ```text
//! VR(d, {l-, l+}) = s²(d) − (|l−|/|d|)·s²(l−) − (|l+|/|d|)·s²(l+)
//! ```
//!
//! [`SdReduction`] (FIMT's standard-deviation reduction) is provided as an
//! alternative; both implement [`SplitCriterion`].

use crate::stats::VarStats;

/// A merit function over a (total, left, right) partition of target stats.
pub trait SplitCriterion: Send + Sync {
    /// Merit of the partition; larger is better.
    fn merit(&self, total: &VarStats, left: &VarStats, right: &VarStats) -> f64;

    /// Upper bound of the merit's range for Hoeffding-bound normalization
    /// (FIMT normalizes merit *ratios*, for which the range is 1).
    fn range(&self, _total: &VarStats) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str;
}

/// Variance Reduction (paper Eq. 1, FIMT form).
#[derive(Clone, Copy, Debug, Default)]
pub struct VarianceReduction;

impl SplitCriterion for VarianceReduction {
    #[inline]
    fn merit(&self, total: &VarStats, left: &VarStats, right: &VarStats) -> f64 {
        if total.n <= 0.0 {
            return 0.0;
        }
        total.variance()
            - (left.n / total.n) * left.variance()
            - (right.n / total.n) * right.variance()
    }

    fn name(&self) -> &'static str {
        "variance-reduction"
    }
}

/// Standard-deviation reduction (FIMT-DD): like VR but in the target's
/// units, which makes the Hoeffding ratio comparison less scale-sensitive.
#[derive(Clone, Copy, Debug, Default)]
pub struct SdReduction;

impl SplitCriterion for SdReduction {
    #[inline]
    fn merit(&self, total: &VarStats, left: &VarStats, right: &VarStats) -> f64 {
        if total.n <= 0.0 {
            return 0.0;
        }
        total.std() - (left.n / total.n) * left.std() - (right.n / total.n) * right.std()
    }

    fn name(&self) -> &'static str {
        "sd-reduction"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(ys: &[f64]) -> VarStats {
        VarStats::from_slice(ys)
    }

    #[test]
    fn perfect_split_recovers_total_variance() {
        let left = stats(&[0.0; 10]);
        let right = stats(&[10.0; 10]);
        let total = left + right;
        let vr = VarianceReduction.merit(&total, &left, &right);
        assert!((vr - total.variance()).abs() < 1e-9);
    }

    #[test]
    fn useless_split_near_zero() {
        let half = stats(&[1.0, 2.0, 3.0, 4.0]);
        let total = half + half;
        let vr = VarianceReduction.merit(&total, &half, &half);
        assert!(vr.abs() < total.variance() * 0.2);
    }

    #[test]
    fn vr_increases_with_separation() {
        let mut last = f64::NEG_INFINITY;
        for sep in [0.0, 1.0, 5.0, 25.0] {
            let left = stats(&[0.0, 1.0, 2.0]);
            let right = stats(&[sep, sep + 1.0, sep + 2.0]);
            let total = left + right;
            let vr = VarianceReduction.merit(&total, &left, &right);
            assert!(vr >= last - 1e-12, "sep={sep}");
            last = vr;
        }
    }

    #[test]
    fn sdr_units_are_sqrt_of_vr_scale() {
        let left = stats(&[0.0; 8]);
        let right = stats(&[100.0; 8]);
        let total = left + right;
        let vr = VarianceReduction.merit(&total, &left, &right);
        let sdr = SdReduction.merit(&total, &left, &right);
        // scaling y by 10 scales VR by 100 but SDR by 10
        let left10 = stats(&[0.0; 8]);
        let right10 = stats(&[1000.0; 8]);
        let total10 = left10 + right10;
        let vr10 = VarianceReduction.merit(&total10, &left10, &right10);
        let sdr10 = SdReduction.merit(&total10, &left10, &right10);
        assert!((vr10 / vr - 100.0).abs() < 1e-6);
        assert!((sdr10 / sdr - 10.0).abs() < 1e-6);
    }

    #[test]
    fn empty_total_zero_merit() {
        let e = VarStats::EMPTY;
        assert_eq!(VarianceReduction.merit(&e, &e, &e), 0.0);
        assert_eq!(SdReduction.merit(&e, &e, &e), 0.0);
    }
}
