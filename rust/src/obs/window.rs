//! Time-windowed metrics: rates and rolling-window quantiles.
//!
//! Lifetime totals (the base registry in [`crate::obs`]) answer "how much
//! ever"; operations needs "how much *lately*" — learns/sec over the last
//! minute, predict p99 over the last five. This module adds two
//! time-rotated primitives that stay inside the registry's constraints
//! (std-only, `const`-constructible, atomics-only recording):
//!
//! * [`WindowedCounter`] — a ring of [`N_TIME_BUCKETS`] per-epoch
//!   counters, each covering [`BUCKET_SECS`] seconds. Recording stamps
//!   the bucket with its epoch and `fetch_add`s; a bucket whose epoch is
//!   stale is claimed via compare-and-swap and reset. Reading sums the
//!   buckets whose epochs fall inside the requested window.
//! * [`WindowedHistogram`] — the same ring, but each time bucket holds a
//!   full log2 histogram (`[AtomicU64; N_BUCKETS]` + sum + count).
//!   Reading merges the live time buckets bucketwise — the **same exact
//!   merge** as [`HistogramSnapshot::merge`] — into one snapshot, so
//!   windowed quantiles carry the identical accuracy contract as
//!   lifetime ones (over-report < 2×, never under-report).
//!
//! ## Accuracy contract
//!
//! These are monitoring-grade, not accounting-grade:
//!
//! * Window edges are quantized to [`BUCKET_SECS`]: a "60 s" window
//!   covers the last 12 whole epochs plus the in-progress one, so it
//!   reads up to one bucket width long.
//! * Rotation races: when an epoch rolls over, the first recorder CASes
//!   the bucket's epoch and resets its counts; a concurrent recorder
//!   landing between the claim and the reset can lose its sample. This
//!   happens at most once per bucket per [`BUCKET_SECS`] and only under
//!   contention — bounded, and irrelevant at monitoring precision.
//!
//! Lifetime totals stay exact; only the windowed view is approximate.
//! The windowed instruments are recorded from the **serve layer** (learn
//! batches, predict responses, replication applies), never from the tree
//! learn hot path, so the `obs_overhead_ratio ≥ 0.95` contract
//! (`docs/OBSERVABILITY.md`) is untouched by them.
//!
//! ## Clock
//!
//! Wall-clock unix seconds ([`now_unix_secs`]) — windows must be
//! meaningful across scrapes and across processes (the fleet aggregator
//! compares nodes), so a process-local monotonic origin is not enough.
//! Every read/record method has an `_at` variant taking an explicit
//! timestamp; tests drive those deterministically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use super::{HistogramSnapshot, N_BUCKETS};

/// Seconds covered by one time bucket.
pub const BUCKET_SECS: u64 = 5;

/// Time buckets in the ring: 64 × 5 s = 320 s of history, enough for the
/// 5-minute window with headroom.
pub const N_TIME_BUCKETS: usize = 64;

/// The two windows the exposition reports, as `(label, seconds)`.
pub const WINDOWS: &[(&str, u64)] = &[("1m", 60), ("5m", 300)];

/// Wall-clock unix seconds (0 if the clock reads before the epoch).
pub fn now_unix_secs() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

/// Wall-clock unix microseconds (0 if the clock reads before the epoch).
/// The freshness span stamps (`serve/publish.rs` → `serve/replicate.rs`)
/// use this resolution: publish→apply spans are tens of milliseconds.
pub fn now_unix_us() -> u64 {
    u64::try_from(
        SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_micros()).unwrap_or(0),
    )
    .unwrap_or(u64::MAX)
}

#[inline]
fn epoch_of(now_secs: u64) -> u64 {
    now_secs / BUCKET_SECS
}

/// Is a bucket stamped `slot_epoch` inside the `window_secs` window
/// ending at the epoch of `now_secs`? Includes the in-progress epoch.
#[inline]
fn in_window(slot_epoch: u64, now_secs: u64, window_secs: u64) -> bool {
    let now_epoch = epoch_of(now_secs);
    let span = window_secs.div_ceil(BUCKET_SECS);
    slot_epoch <= now_epoch && slot_epoch + span > now_epoch
}

/// One time-rotated counter bucket: the epoch it currently covers plus
/// the count recorded during that epoch.
struct CounterSlot {
    epoch: AtomicU64,
    count: AtomicU64,
}

impl CounterSlot {
    const fn new() -> CounterSlot {
        // epoch 0 would collide with a live epoch only for clocks reading
        // the first 5 s after 1970 — stamp u64::MAX as "never written"
        CounterSlot { epoch: AtomicU64::new(u64::MAX), count: AtomicU64::new(0) }
    }

    /// Claim the slot for `epoch` if it is stamped with an older one.
    /// Returns after the slot is stamped `epoch` (by us or a racer).
    #[inline]
    fn rotate(&self, epoch: u64) {
        let seen = self.epoch.load(Ordering::Relaxed);
        if seen == epoch {
            return;
        }
        if self.epoch.compare_exchange(seen, epoch, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
            // we won the claim: discard the previous epoch's count
            self.count.store(0, Ordering::Relaxed);
        }
    }
}

/// A counter whose recent history is readable per time window. Recording
/// is a load + (rarely) one CAS + one `fetch_add`, all relaxed.
pub struct WindowedCounter {
    slots: [CounterSlot; N_TIME_BUCKETS],
}

impl WindowedCounter {
    pub const fn new() -> WindowedCounter {
        const SLOT: CounterSlot = CounterSlot::new();
        WindowedCounter { slots: [SLOT; N_TIME_BUCKETS] }
    }

    /// Record `n` events now.
    #[inline]
    pub fn add(&self, n: u64) {
        self.add_at(n, now_unix_secs());
    }

    /// Record `n` events at an explicit unix-seconds instant (tests).
    #[inline]
    pub fn add_at(&self, n: u64, now_secs: u64) {
        let epoch = epoch_of(now_secs);
        let slot = &self.slots[(epoch % N_TIME_BUCKETS as u64) as usize];
        slot.rotate(epoch);
        slot.count.fetch_add(n, Ordering::Relaxed);
    }

    /// Events recorded over the trailing `window_secs` (quantized to
    /// bucket width, see the module docs).
    pub fn sum_window(&self, window_secs: u64) -> u64 {
        self.sum_window_at(window_secs, now_unix_secs())
    }

    /// [`WindowedCounter::sum_window`] at an explicit instant.
    pub fn sum_window_at(&self, window_secs: u64, now_secs: u64) -> u64 {
        self.slots
            .iter()
            .filter(|s| in_window(s.epoch.load(Ordering::Relaxed), now_secs, window_secs))
            .map(|s| s.count.load(Ordering::Relaxed))
            .sum()
    }

    /// Resident bytes: the ring is a fixed inline array of atomics —
    /// no heap, so the struct size is exact (pinned in `obs` tests).
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<WindowedCounter>()
    }

    /// Events per second over the trailing window.
    pub fn rate_at(&self, window_secs: u64, now_secs: u64) -> f64 {
        if window_secs == 0 {
            return 0.0;
        }
        self.sum_window_at(window_secs, now_secs) as f64 / window_secs as f64
    }
}

impl Default for WindowedCounter {
    fn default() -> WindowedCounter {
        WindowedCounter::new()
    }
}

/// One time-rotated histogram bucket: a full log2 histogram stamped with
/// the epoch it covers.
struct HistSlot {
    epoch: AtomicU64,
    counts: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl HistSlot {
    const fn new() -> HistSlot {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        HistSlot {
            epoch: AtomicU64::new(u64::MAX),
            counts: [ZERO; N_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    fn rotate(&self, epoch: u64) {
        let seen = self.epoch.load(Ordering::Relaxed);
        if seen == epoch {
            return;
        }
        if self.epoch.compare_exchange(seen, epoch, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
            for c in &self.counts {
                c.store(0, Ordering::Relaxed);
            }
            self.sum.store(0, Ordering::Relaxed);
            self.count.store(0, Ordering::Relaxed);
        }
    }
}

/// A histogram whose recent samples are readable per time window as an
/// exact-merged [`HistogramSnapshot`].
pub struct WindowedHistogram {
    slots: [HistSlot; N_TIME_BUCKETS],
}

impl WindowedHistogram {
    pub const fn new() -> WindowedHistogram {
        const SLOT: HistSlot = HistSlot::new();
        WindowedHistogram { slots: [SLOT; N_TIME_BUCKETS] }
    }

    /// Record one sample now.
    #[inline]
    pub fn record(&self, v: u64) {
        self.record_at(v, now_unix_secs());
    }

    /// Record one sample at an explicit unix-seconds instant (tests).
    #[inline]
    pub fn record_at(&self, v: u64, now_secs: u64) {
        let epoch = epoch_of(now_secs);
        let slot = &self.slots[(epoch % N_TIME_BUCKETS as u64) as usize];
        slot.rotate(epoch);
        slot.counts[super::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        slot.sum.fetch_add(v, Ordering::Relaxed);
        slot.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Merge the trailing `window_secs` of samples into one snapshot —
    /// bucketwise addition, the same exact merge as
    /// [`HistogramSnapshot::merge`].
    pub fn snapshot_window(&self, window_secs: u64) -> HistogramSnapshot {
        self.snapshot_window_at(window_secs, now_unix_secs())
    }

    /// Resident bytes: fixed inline atomics, no heap — exact
    /// (pinned in `obs` tests).
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<WindowedHistogram>()
    }

    /// [`WindowedHistogram::snapshot_window`] at an explicit instant.
    pub fn snapshot_window_at(&self, window_secs: u64, now_secs: u64) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::empty();
        for slot in &self.slots {
            if !in_window(slot.epoch.load(Ordering::Relaxed), now_secs, window_secs) {
                continue;
            }
            for (o, c) in out.counts.iter_mut().zip(&slot.counts) {
                *o += c.load(Ordering::Relaxed);
            }
            out.sum += slot.sum.load(Ordering::Relaxed);
            out.count += slot.count.load(Ordering::Relaxed);
        }
        out
    }
}

impl Default for WindowedHistogram {
    fn default() -> WindowedHistogram {
        WindowedHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T0: u64 = 1_700_000_000; // an arbitrary fixed "now"

    #[test]
    fn counter_windows_include_recent_and_drop_old_epochs() {
        let c = WindowedCounter::new();
        c.add_at(10, T0); // in-progress epoch
        c.add_at(5, T0 - 30); // 30 s ago: inside 1m and 5m
        c.add_at(7, T0 - 120); // 2 min ago: inside 5m only
        c.add_at(100, T0 - 400); // beyond the 5m window entirely
        assert_eq!(c.sum_window_at(60, T0), 15);
        assert_eq!(c.sum_window_at(300, T0), 22);
        assert!((c.rate_at(60, T0) - 15.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn counter_ring_reuse_overwrites_stale_epochs() {
        let c = WindowedCounter::new();
        c.add_at(3, T0);
        // one full ring later the same slot covers a new epoch: the old
        // count must be discarded, not summed
        let later = T0 + BUCKET_SECS * N_TIME_BUCKETS as u64;
        c.add_at(4, later);
        assert_eq!(c.sum_window_at(60, later), 4);
        assert_eq!(c.sum_window_at(300, later), 4);
    }

    #[test]
    fn histogram_window_merge_matches_direct_recording() {
        // samples inside the window must merge to exactly the snapshot of
        // a plain histogram that recorded them (same bucketing, same
        // bucketwise addition)
        let w = WindowedHistogram::new();
        let reference = super::super::Histogram::new();
        for (v, age) in [(100u64, 0u64), (1000, 10), (9, 55)] {
            w.record_at(v, T0 - age);
            reference.record(v);
        }
        w.record_at(1 << 20, T0 - 200); // inside 5m, outside 1m
        assert_eq!(w.snapshot_window_at(60, T0), reference.snapshot());
        let five = w.snapshot_window_at(300, T0);
        assert_eq!(five.count, 4);
        assert_eq!(five.sum, reference.snapshot().sum + (1 << 20));
    }

    #[test]
    fn windowed_quantiles_reflect_only_the_window() {
        let w = WindowedHistogram::new();
        for _ in 0..100 {
            w.record_at(1_000_000, T0 - 200); // old slow samples
        }
        for _ in 0..100 {
            w.record_at(100, T0); // recent fast samples
        }
        // the 1m view only sees the fast samples; the 5m view is
        // dominated by the slow ones at p99
        assert!(w.snapshot_window_at(60, T0).quantile(0.99) < 256);
        assert!(w.snapshot_window_at(300, T0).quantile(0.99) >= 1_000_000);
    }

    #[test]
    fn window_edges_are_quantized_to_bucket_width() {
        // a sample "60 s ago" may still be visible in a 60 s window
        // because the in-progress epoch extends it (documented); one full
        // extra bucket earlier it must be gone
        let t0 = T0 - (T0 % BUCKET_SECS); // align for determinism
        let c = WindowedCounter::new();
        c.add_at(1, t0 - 60 - BUCKET_SECS);
        assert_eq!(c.sum_window_at(60, t0), 0);
        c.add_at(1, t0 - 60 + BUCKET_SECS);
        assert_eq!(c.sum_window_at(60, t0), 1);
    }

    #[test]
    fn clock_helpers_are_sane() {
        let s = now_unix_secs();
        let us = now_unix_us();
        assert!(s > 1_500_000_000, "unix clock reads before 2017: {s}");
        assert!(us / 1_000_000 >= s);
    }
}
