//! `obs/` — a dependency-free metrics + tracing layer.
//!
//! The paper's central claim is a *cost profile* — O(1) monitoring per
//! instance and sub-linear split evaluation (PAPER.md Sec. 3–4) — and the
//! serving layer's north star is operating that profile under real
//! traffic. This module makes both observable from a running process
//! with `std` only (no external crates, matching the vendor-shim policy):
//!
//! * **Counters / gauges** — single relaxed `AtomicU64`s.
//! * **Histograms** — log2-bucketed `AtomicU64` arrays with an exact
//!   merge (bucketwise add: merging two recordings is *identical* to
//!   having recorded into one histogram, property-tested below) and
//!   p50/p90/p99 readout. A quantile answer is the inclusive upper bound
//!   of its bucket, so it over-reports by strictly less than 2× and
//!   never under-reports.
//! * **Split-decision trace ring** — a bounded ring recording every
//!   split attempt's outcome (accepted / tie-broken / Hoeffding-rejected
//!   / no-merit / branch-too-small), merit gap, slots evaluated and
//!   elapsed ns. Split attempts are grace-period-rare, so a mutexed ring
//!   is fine; the hot learn path never touches it.
//!
//! ## Overhead contract
//!
//! The registry is **disabled by default**. Every recording site goes
//! through [`m()`], which is one relaxed load + branch when disabled —
//! the instrumented binary runs the uninstrumented hot path. When
//! enabled (servers enable on start), recording is 1–3 uncontended
//! relaxed RMWs. `bench_suite::serve_bench::obs_overhead_scenario`
//! measures enabled-vs-disabled learns/sec and the CI smoke gate asserts
//! the ratio stays ≥ 0.95 (within 5%).
//!
//! ## Metric naming scheme
//!
//! `qostream_<component>_<name>[_total|_bytes|_ns]` where component is
//! one of `tree`, `qo`, `backend`, `forest`, `serve`, `repl`, `model`.
//! Counters end in `_total`; byte and nanosecond distributions carry
//! their unit as the suffix.
//!
//! ## Exposition format
//!
//! [`exposition()`] renders Prometheus text exposition: counters and
//! gauges as single samples, histograms as Prometheus *summaries*
//! (`{quantile="0.5|0.9|0.99"}` samples plus `_sum`/`_count`). The serve
//! protocol exposes it via the `metrics` command (and the ring via
//! `trace_splits`) on leaders and followers alike.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Global on/off switch. Off (the default) means every recording site is
/// a relaxed load + branch — effectively free.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the global registry recording?
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global registry on (servers call this on start).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn the global registry off (recording sites become no-ops).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Serializes enable/disable *experiments*: the overhead bench and the
/// gate's own tests flip the process-global switch back and forth, and
/// concurrent flippers (cargo runs tests in parallel threads) would
/// corrupt each other's measurements. Hold this while toggling.
/// Recording sites and plain [`enable()`] callers (servers) never take it.
pub fn toggle_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The gated accessor every instrumentation site uses:
/// `if let Some(m) = obs::m() { m.tree_learns.inc(); }`.
/// Returns `None` when the registry is disabled, so the instrumented
/// path compiles down to a load + branch around the recording code.
#[inline(always)]
pub fn m() -> Option<&'static Metrics> {
    if enabled() {
        Some(global())
    } else {
        None
    }
}

/// The global registry, independent of the enabled gate (readout paths —
/// exposition, stats — always see it).
pub fn global() -> &'static Metrics {
    static METRICS: Metrics = Metrics::new();
    &METRICS
}

/// A monotone counter. Recording is one relaxed `fetch_add`.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline(always)]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline(always)]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// A last-write-wins gauge.
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { v: AtomicU64::new(0) }
    }

    #[inline(always)]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// log2 buckets: index 0 holds the value 0, index `i ≥ 1` holds
/// `[2^(i-1), 2^i - 1]`, and index 64 holds everything from `2^63` up.
pub const N_BUCKETS: usize = 65;

/// Bucket index of a recorded value.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound of a bucket (the quantile representative).
pub fn bucket_upper_bound(i: usize) -> u64 {
    match i {
        0 => 0,
        1..=63 => (1u64 << i) - 1,
        _ => u64::MAX,
    }
}

/// A lock-free log2-bucketed histogram of `u64` samples (latencies in
/// ns, sizes in bytes, depths, batch sizes...).
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [ZERO; N_BUCKETS], sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    /// Record one sample: three relaxed `fetch_add`s.
    #[inline(always)]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy (not a cross-field atomic snapshot; under
    /// concurrent recording the fields may be a few samples apart, which
    /// is fine for monitoring readout).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; N_BUCKETS];
        for (c, b) in counts.iter_mut().zip(&self.buckets) {
            *c = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Plain-data copy of a [`Histogram`], mergeable and queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: [u64; N_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot { counts: [0; N_BUCKETS], sum: 0, count: 0 }
    }

    /// Exact merge: bucketwise addition. `a.merge(&b)` is identical to
    /// the snapshot of one histogram that recorded both sample sets
    /// (bucketing is a pure function of the value).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut out = self.clone();
        for (c, o) in out.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        out.sum += other.sum;
        out.count += other.count;
        out
    }

    /// The q-quantile (`0 < q <= 1`) as the inclusive upper bound of the
    /// bucket holding the ⌈q·count⌉-th smallest sample; 0 when empty.
    /// Over-reports by < 2× (the bucket's width), never under-reports.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(N_BUCKETS - 1)
    }

    /// Mean of the recorded samples (exact — the sum is tracked exactly).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// How a split attempt resolved (mirrors the decision branches of
/// `tree::HoeffdingTreeRegressor`'s Hoeffding test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitOutcome {
    /// Merit ratio cleared the Hoeffding bound: split materialized.
    Accepted,
    /// Bound not cleared but ε shrank under the tie threshold: split
    /// materialized as a tie-break.
    TieBroken,
    /// Candidates too close for the current ε: leaf keeps observing.
    HoeffdingRejected,
    /// Best candidate had no positive merit.
    NoMerit,
    /// Best candidate would create an under-populated branch.
    BranchTooSmall,
}

impl SplitOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            SplitOutcome::Accepted => "accepted",
            SplitOutcome::TieBroken => "tie_broken",
            SplitOutcome::HoeffdingRejected => "hoeffding_rejected",
            SplitOutcome::NoMerit => "no_merit",
            SplitOutcome::BranchTooSmall => "branch_too_small",
        }
    }

    /// Did this outcome materialize a split?
    pub fn split(&self) -> bool {
        matches!(self, SplitOutcome::Accepted | SplitOutcome::TieBroken)
    }
}

/// One recorded split attempt.
#[derive(Clone, Copy, Debug)]
pub struct SplitEvent {
    pub outcome: SplitOutcome,
    /// `best.merit - second.merit` (0 when there was no runner-up).
    pub merit_gap: f64,
    /// Stored elements across the leaf's observers at decision time —
    /// the paper's "slots" cost axis for the evaluated query.
    pub slots_evaluated: u64,
    /// Wall-clock ns from gathering suggestions to the decision.
    pub elapsed_ns: u64,
}

/// Bounded ring of recent [`SplitEvent`]s plus a total-attempts counter.
/// Mutexed: split attempts fire once per `grace_period` learns, so this
/// is far off the hot path.
pub struct TraceRing {
    capacity: usize,
    inner: Mutex<TraceInner>,
}

struct TraceInner {
    events: VecDeque<SplitEvent>,
    total: u64,
}

impl TraceRing {
    pub const fn new(capacity: usize) -> TraceRing {
        TraceRing {
            capacity,
            inner: Mutex::new(TraceInner { events: VecDeque::new(), total: 0 }),
        }
    }

    pub fn record(&self, event: SplitEvent) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.total += 1;
        if inner.events.len() >= self.capacity {
            inner.events.pop_front();
        }
        inner.events.push_back(event);
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> Vec<SplitEvent> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.events.iter().copied().collect()
    }

    /// Attempts ever recorded (including evicted ones).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).total
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Every metric the system records, by name. One static instance backs
/// the process ([`global()`]); tests build their own.
pub struct Metrics {
    // tree
    pub tree_learns: Counter,
    pub tree_route_depth: Histogram,
    pub tree_splits_accepted: Counter,
    pub tree_splits_tie_broken: Counter,
    pub tree_splits_hoeffding_rejected: Counter,
    pub tree_splits_no_merit: Counter,
    pub tree_splits_branch_too_small: Counter,
    // observer
    pub qo_inserts: Counter,
    pub qo_slots_occupied: Histogram,
    // split backend
    pub backend_batches: Counter,
    pub backend_batch_size: Histogram,
    pub backend_latency_ns: Histogram,
    // forest
    pub forest_warnings: Counter,
    pub forest_drifts: Counter,
    pub forest_bg_promotions: Counter,
    // serve
    pub serve_learn_ns: Histogram,
    pub serve_predict_ns: Histogram,
    pub serve_delta_publish_bytes: Histogram,
    pub serve_snapshot_failures_consecutive: Gauge,
    /// Wall-clock of one snapshot publication (structural clone + `Arc`
    /// swap + staging). Recorded in nanoseconds; exposed as the
    /// `qostream_snapshot_publish_seconds` summary.
    pub snapshot_publish_ns: Histogram,
    /// Canonical-JSON bytes of materialized checkpoint documents
    /// (`qostream_snapshot_bytes{format="json"}`).
    pub snapshot_bytes_json: Counter,
    /// Binary-envelope bytes of encoded checkpoint/delta payloads
    /// (`qostream_snapshot_bytes{format="binary"}`).
    pub snapshot_bytes_binary: Counter,
    // model
    pub model_mem_bytes: Gauge,
    // replication (follower side)
    pub repl_lag_versions: Gauge,
    pub repl_lag_learns: Gauge,
    pub repl_deltas_applied: Counter,
    pub repl_full_resyncs: Counter,
    // split-decision trace
    pub split_trace: TraceRing,
}

impl Metrics {
    pub const fn new() -> Metrics {
        Metrics {
            tree_learns: Counter::new(),
            tree_route_depth: Histogram::new(),
            tree_splits_accepted: Counter::new(),
            tree_splits_tie_broken: Counter::new(),
            tree_splits_hoeffding_rejected: Counter::new(),
            tree_splits_no_merit: Counter::new(),
            tree_splits_branch_too_small: Counter::new(),
            qo_inserts: Counter::new(),
            qo_slots_occupied: Histogram::new(),
            backend_batches: Counter::new(),
            backend_batch_size: Histogram::new(),
            backend_latency_ns: Histogram::new(),
            forest_warnings: Counter::new(),
            forest_drifts: Counter::new(),
            forest_bg_promotions: Counter::new(),
            serve_learn_ns: Histogram::new(),
            serve_predict_ns: Histogram::new(),
            serve_delta_publish_bytes: Histogram::new(),
            serve_snapshot_failures_consecutive: Gauge::new(),
            snapshot_publish_ns: Histogram::new(),
            snapshot_bytes_json: Counter::new(),
            snapshot_bytes_binary: Counter::new(),
            model_mem_bytes: Gauge::new(),
            repl_lag_versions: Gauge::new(),
            repl_lag_learns: Gauge::new(),
            repl_deltas_applied: Counter::new(),
            repl_full_resyncs: Counter::new(),
            split_trace: TraceRing::new(256),
        }
    }

    /// Route a split outcome to its per-outcome counter.
    pub fn count_split_outcome(&self, outcome: SplitOutcome) {
        match outcome {
            SplitOutcome::Accepted => self.tree_splits_accepted.inc(),
            SplitOutcome::TieBroken => self.tree_splits_tie_broken.inc(),
            SplitOutcome::HoeffdingRejected => self.tree_splits_hoeffding_rejected.inc(),
            SplitOutcome::NoMerit => self.tree_splits_no_merit.inc(),
            SplitOutcome::BranchTooSmall => self.tree_splits_branch_too_small.inc(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

fn write_counter(out: &mut String, name: &str, c: &Counter) {
    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
}

fn write_gauge(out: &mut String, name: &str, g: &Gauge) {
    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
}

/// Render a nanosecond histogram as a seconds-unit summary (Prometheus
/// convention for durations): quantiles and `_sum` divide by 1e9 and
/// print as floats; `_count` stays a sample count.
fn write_summary_ns_as_seconds(out: &mut String, name: &str, h: &Histogram) {
    let s = h.snapshot();
    out.push_str(&format!("# TYPE {name} summary\n"));
    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
        out.push_str(&format!(
            "{name}{{quantile=\"{label}\"}} {}\n",
            s.quantile(q) as f64 / 1e9
        ));
    }
    out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", s.sum as f64 / 1e9, s.count));
}

/// Render one counter family whose samples split over a `format` label
/// (the byte-size-by-encoding counters).
fn write_format_counters(out: &mut String, name: &str, json: &Counter, binary: &Counter) {
    out.push_str(&format!(
        "# TYPE {name} counter\n{name}{{format=\"json\"}} {}\n{name}{{format=\"binary\"}} {}\n",
        json.get(),
        binary.get()
    ));
}

fn write_summary(out: &mut String, name: &str, h: &Histogram) {
    let s = h.snapshot();
    out.push_str(&format!("# TYPE {name} summary\n"));
    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
        out.push_str(&format!("{name}{{quantile=\"{label}\"}} {}\n", s.quantile(q)));
    }
    out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", s.sum, s.count));
}

/// Prometheus text exposition of one registry.
pub fn exposition_of(m: &Metrics) -> String {
    let mut out = String::with_capacity(4096);
    write_counter(&mut out, "qostream_tree_learns_total", &m.tree_learns);
    write_summary(&mut out, "qostream_tree_route_depth", &m.tree_route_depth);
    write_counter(&mut out, "qostream_tree_splits_accepted_total", &m.tree_splits_accepted);
    write_counter(&mut out, "qostream_tree_splits_tie_broken_total", &m.tree_splits_tie_broken);
    write_counter(
        &mut out,
        "qostream_tree_splits_hoeffding_rejected_total",
        &m.tree_splits_hoeffding_rejected,
    );
    write_counter(&mut out, "qostream_tree_splits_no_merit_total", &m.tree_splits_no_merit);
    write_counter(
        &mut out,
        "qostream_tree_splits_branch_too_small_total",
        &m.tree_splits_branch_too_small,
    );
    write_counter(&mut out, "qostream_qo_inserts_total", &m.qo_inserts);
    write_summary(&mut out, "qostream_qo_slots_occupied", &m.qo_slots_occupied);
    write_counter(&mut out, "qostream_backend_batches_total", &m.backend_batches);
    write_summary(&mut out, "qostream_backend_batch_size", &m.backend_batch_size);
    write_summary(&mut out, "qostream_backend_latency_ns", &m.backend_latency_ns);
    write_counter(&mut out, "qostream_forest_warnings_total", &m.forest_warnings);
    write_counter(&mut out, "qostream_forest_drifts_total", &m.forest_drifts);
    write_counter(&mut out, "qostream_forest_bg_promotions_total", &m.forest_bg_promotions);
    write_summary(&mut out, "qostream_serve_learn_ns", &m.serve_learn_ns);
    write_summary(&mut out, "qostream_serve_predict_ns", &m.serve_predict_ns);
    write_summary(&mut out, "qostream_serve_delta_publish_bytes", &m.serve_delta_publish_bytes);
    write_summary_ns_as_seconds(
        &mut out,
        "qostream_snapshot_publish_seconds",
        &m.snapshot_publish_ns,
    );
    write_format_counters(
        &mut out,
        "qostream_snapshot_bytes",
        &m.snapshot_bytes_json,
        &m.snapshot_bytes_binary,
    );
    write_gauge(
        &mut out,
        "qostream_serve_snapshot_failures_consecutive",
        &m.serve_snapshot_failures_consecutive,
    );
    write_gauge(&mut out, "qostream_model_mem_bytes", &m.model_mem_bytes);
    write_gauge(&mut out, "qostream_repl_lag_versions", &m.repl_lag_versions);
    write_gauge(&mut out, "qostream_repl_lag_learns", &m.repl_lag_learns);
    write_counter(&mut out, "qostream_repl_deltas_applied_total", &m.repl_deltas_applied);
    write_counter(&mut out, "qostream_repl_full_resyncs_total", &m.repl_full_resyncs);
    write_counter(
        &mut out,
        "qostream_tree_split_attempts_total",
        // the ring's total is the attempts counter; expose it as one
        &trace_total_counter(&m.split_trace),
    );
    out
}

fn trace_total_counter(ring: &TraceRing) -> Counter {
    let c = Counter::new();
    c.add(ring.total());
    c
}

/// Prometheus text exposition of the global registry (the serve
/// protocol's `metrics` command).
pub fn exposition() -> String {
    exposition_of(global())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::check;

    #[test]
    fn bucket_index_is_monotone_and_bounds_hold() {
        // every value lands in a bucket whose bounds contain it, and the
        // index is monotone in the value
        let probes = [
            0u64,
            1,
            2,
            3,
            4,
            7,
            8,
            1023,
            1024,
            u32::MAX as u64,
            u64::MAX / 2,
            u64::MAX,
        ];
        let mut last = 0usize;
        for &v in &probes {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS);
            assert!(i >= last, "index not monotone at {v}");
            last = i;
            let lo = if i == 0 { 0 } else { bucket_upper_bound(i - 1).saturating_add(1) };
            assert!(v >= lo && v <= bucket_upper_bound(i), "v={v} outside bucket {i}");
        }
    }

    #[test]
    fn prop_merge_equals_pooled_recording() {
        check("histogram-merge-pooled", 0x0B5E, 50, |rng| {
            let (a, b, pooled) = (Histogram::new(), Histogram::new(), Histogram::new());
            for _ in 0..rng.below(200) {
                let v = rng.below(1 << rng.below(40));
                a.record(v);
                pooled.record(v);
            }
            for _ in 0..rng.below(200) {
                let v = rng.below(1 << rng.below(40));
                b.record(v);
                pooled.record(v);
            }
            let merged = a.snapshot().merge(&b.snapshot());
            if merged != pooled.snapshot() {
                return Err("merge != pooled".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantile_bounds() {
        // the quantile estimate never under-reports the true quantile
        // and over-reports by strictly less than 2x (one bucket width)
        check("histogram-quantile-bounds", 0x0B5F, 50, |rng| {
            let h = Histogram::new();
            let n = 1 + rng.below(300) as usize;
            let mut values: Vec<u64> = (0..n).map(|_| rng.below(1 << rng.below(32))).collect();
            for &v in &values {
                h.record(v);
            }
            values.sort_unstable();
            let s = h.snapshot();
            for q in [0.5, 0.9, 0.99] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let truth = values[rank - 1];
                let est = s.quantile(q);
                if est < truth {
                    return Err(format!("q{q}: est {est} < true {truth}"));
                }
                if truth > 0 && est >= truth.saturating_mul(2) {
                    return Err(format!("q{q}: est {est} >= 2x true {truth}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantiles_on_empty_and_single_sample() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        h.record(100);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.sum, 100);
        // 100 lives in [64, 127]
        assert_eq!(s.quantile(0.5), 127);
        assert_eq!(s.quantile(0.99), 127);
        assert!((s.mean() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn trace_ring_bounds_and_counts() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.record(SplitEvent {
                outcome: if i % 2 == 0 {
                    SplitOutcome::Accepted
                } else {
                    SplitOutcome::HoeffdingRejected
                },
                merit_gap: i as f64,
                slots_evaluated: i,
                elapsed_ns: i * 100,
            });
        }
        assert_eq!(ring.total(), 10);
        let events = ring.events();
        assert_eq!(events.len(), 4, "ring must stay bounded");
        // oldest-first: the survivors are attempts 6..=9
        assert_eq!(events[0].slots_evaluated, 6);
        assert_eq!(events[3].slots_evaluated, 9);
        assert!(events[0].outcome.split());
        assert!(!events[1].outcome.split());
    }

    #[test]
    fn outcome_labels_are_stable() {
        // the wire format of trace_splits depends on these strings
        assert_eq!(SplitOutcome::Accepted.label(), "accepted");
        assert_eq!(SplitOutcome::TieBroken.label(), "tie_broken");
        assert_eq!(SplitOutcome::HoeffdingRejected.label(), "hoeffding_rejected");
        assert_eq!(SplitOutcome::NoMerit.label(), "no_merit");
        assert_eq!(SplitOutcome::BranchTooSmall.label(), "branch_too_small");
    }

    #[test]
    fn exposition_golden() {
        // a local registry with known values renders the exact text the
        // `metrics` command promises (naming scheme + summary shape)
        let m = Metrics::new();
        m.tree_learns.add(42);
        m.tree_route_depth.record(3);
        m.tree_route_depth.record(3);
        m.count_split_outcome(SplitOutcome::Accepted);
        m.count_split_outcome(SplitOutcome::TieBroken);
        m.count_split_outcome(SplitOutcome::HoeffdingRejected);
        m.model_mem_bytes.set(4096);
        let text = exposition_of(&m);
        for needle in [
            "# TYPE qostream_tree_learns_total counter\nqostream_tree_learns_total 42\n",
            "# TYPE qostream_tree_route_depth summary\n\
             qostream_tree_route_depth{quantile=\"0.5\"} 3\n\
             qostream_tree_route_depth{quantile=\"0.9\"} 3\n\
             qostream_tree_route_depth{quantile=\"0.99\"} 3\n\
             qostream_tree_route_depth_sum 6\nqostream_tree_route_depth_count 2\n",
            "qostream_tree_splits_accepted_total 1\n",
            "qostream_tree_splits_tie_broken_total 1\n",
            "qostream_tree_splits_hoeffding_rejected_total 1\n",
            "# TYPE qostream_model_mem_bytes gauge\nqostream_model_mem_bytes 4096\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // the acceptance criterion: >= 15 distinct series families
        let families = text.matches("# TYPE ").count();
        assert!(families >= 15, "only {families} series families:\n{text}");
        // every family follows the naming scheme
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            assert!(name.starts_with("qostream_"), "bad metric name {name}");
        }
    }

    #[test]
    fn snapshot_publish_and_bytes_families_render() {
        // the snapshot-cost instruments: a ns histogram exposed in
        // seconds, and one byte counter family split by format label
        let m = Metrics::new();
        m.snapshot_publish_ns.record(2_000_000_000); // 2s → bucket upper bound < 4s
        m.snapshot_bytes_json.add(1000);
        m.snapshot_bytes_binary.add(400);
        let text = exposition_of(&m);
        assert!(text.contains("# TYPE qostream_snapshot_publish_seconds summary\n"));
        assert!(text.contains("qostream_snapshot_publish_seconds_count 1\n"));
        assert!(text.contains("# TYPE qostream_snapshot_bytes counter\n"));
        assert!(text.contains("qostream_snapshot_bytes{format=\"json\"} 1000\n"));
        assert!(text.contains("qostream_snapshot_bytes{format=\"binary\"} 400\n"));
        // the quantile is the bucket's upper bound in seconds: within
        // [2, 4) for a 2s sample (log2 buckets over-report < 2x)
        let q50 = text
            .lines()
            .find(|l| l.starts_with("qostream_snapshot_publish_seconds{quantile=\"0.5\"}"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap();
        assert!((2.0..4.0).contains(&q50), "q50 = {q50}");
    }

    #[test]
    fn disabled_registry_yields_no_global_handle() {
        // m() is the gate: when disabled it returns None and recording
        // sites skip all work. The lock keeps the overhead bench (which
        // also flips the global switch) from interleaving.
        let _toggling = toggle_lock();
        disable();
        assert!(m().is_none());
        enable();
        assert!(m().is_some());
        // leave it enabled: instrumentation is side-effect-free for
        // model behavior, and other tests may be recording concurrently
    }
}
