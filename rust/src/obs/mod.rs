//! `obs/` — a dependency-free metrics + tracing layer.
//!
//! The paper's central claim is a *cost profile* — O(1) monitoring per
//! instance and sub-linear split evaluation (PAPER.md Sec. 3–4) — and the
//! serving layer's north star is operating that profile under real
//! traffic. This module makes both observable from a running process
//! with `std` only (no external crates, matching the vendor-shim policy):
//!
//! * **Counters / gauges** — single relaxed `AtomicU64`s.
//! * **Histograms** — log2-bucketed `AtomicU64` arrays with an exact
//!   merge (bucketwise add: merging two recordings is *identical* to
//!   having recorded into one histogram, property-tested below) and
//!   p50/p90/p99 readout. A quantile answer is the inclusive upper bound
//!   of its bucket, so it over-reports by strictly less than 2× and
//!   never under-reports.
//! * **Trace rings** — bounded rings of recent events behind one
//!   generic [`TraceRing`]: the split-decision ring (every split
//!   attempt's outcome — accepted / tie-broken / Hoeffding-rejected /
//!   no-merit / branch-too-small — merit gap, slots evaluated, elapsed
//!   ns) and the replication-apply ring (version, learns covered,
//!   publish→apply freshness span). Both event kinds are rare (split
//!   attempts ride the grace period; applies ride the poll interval),
//!   so a mutexed ring is fine; the hot learn path never touches them.
//!   The `trace_splits` / `trace_repl` protocol commands dump them
//!   **newest first** via [`TraceRing::recent`] (asserted in tests);
//!   [`TraceRing::events`] keeps the oldest-first in-process view.
//! * **Windowed metrics** ([`window`]) — time-rotated rings of
//!   counters/histograms giving 1m/5m rates and rolling-window
//!   quantiles beside the lifetime totals, reusing the same exact
//!   bucketwise merge.
//! * **Registry snapshots** ([`snapshot`]) — a mergeable, JSON-codable
//!   capture of the whole registry. The fleet aggregator
//!   (`serve/fleet.rs`) scrapes these via the `metrics_raw` command and
//!   merges them **exactly** (bucketwise histogram addition) into one
//!   fleet-wide exposition; the single-process exposition below renders
//!   through the very same capture→render path, so the two can't drift.
//!
//! The full metric-family catalog — name, type, labels, window, where
//! each is recorded — lives in `docs/OBSERVABILITY.md`, generated from
//! the same [`CATALOG`] table that drives the `# HELP` lines (a unit
//! test asserts doc and code agree).
//!
//! ## Overhead contract
//!
//! The registry is **disabled by default**. Every recording site goes
//! through [`m()`], which is one relaxed load + branch when disabled —
//! the instrumented binary runs the uninstrumented hot path. When
//! enabled (servers enable on start), recording is 1–3 uncontended
//! relaxed RMWs. `bench_suite::serve_bench::obs_overhead_scenario`
//! measures enabled-vs-disabled learns/sec and the CI smoke gate asserts
//! the ratio stays ≥ 0.95 (within 5%).
//!
//! ## Metric naming scheme
//!
//! `qostream_<component>_<name>[_total|_bytes|_ns]` where component is
//! one of `tree`, `qo`, `backend`, `forest`, `serve`, `repl`, `model`,
//! `govern`.
//! Counters end in `_total`; byte and nanosecond distributions carry
//! their unit as the suffix.
//!
//! ## Exposition format
//!
//! [`exposition()`] renders Prometheus text exposition: `# HELP` +
//! `# TYPE` per family (help text from [`CATALOG`]), counters and
//! gauges as single samples, histograms as Prometheus *summaries*
//! (`{quantile="0.5|0.9|0.99"}` samples plus `_sum`/`_count`), windowed
//! families as gauges with a `window="1m|5m"` label. The serve protocol
//! exposes it via the `metrics` command (and the rings via
//! `trace_splits` / `trace_repl`) on leaders and followers alike.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

pub mod snapshot;
pub mod window;

pub use snapshot::RegistrySnapshot;
pub use window::{WindowedCounter, WindowedHistogram};

/// Global on/off switch. Off (the default) means every recording site is
/// a relaxed load + branch — effectively free.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the global registry recording?
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global registry on (servers call this on start).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn the global registry off (recording sites become no-ops).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Serializes enable/disable *experiments*: the overhead bench and the
/// gate's own tests flip the process-global switch back and forth, and
/// concurrent flippers (cargo runs tests in parallel threads) would
/// corrupt each other's measurements. Hold this while toggling.
/// Recording sites and plain [`enable()`] callers (servers) never take it.
pub fn toggle_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The gated accessor every instrumentation site uses:
/// `if let Some(m) = obs::m() { m.tree_learns.inc(); }`.
/// Returns `None` when the registry is disabled, so the instrumented
/// path compiles down to a load + branch around the recording code.
#[inline(always)]
pub fn m() -> Option<&'static Metrics> {
    if enabled() {
        Some(global())
    } else {
        None
    }
}

/// The global registry, independent of the enabled gate (readout paths —
/// exposition, stats — always see it).
pub fn global() -> &'static Metrics {
    static METRICS: Metrics = Metrics::new();
    &METRICS
}

/// A monotone counter. Recording is one relaxed `fetch_add`.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline(always)]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline(always)]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// A last-write-wins gauge.
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { v: AtomicU64::new(0) }
    }

    #[inline(always)]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// log2 buckets: index 0 holds the value 0, index `i ≥ 1` holds
/// `[2^(i-1), 2^i - 1]`, and index 64 holds everything from `2^63` up.
pub const N_BUCKETS: usize = 65;

/// Bucket index of a recorded value.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound of a bucket (the quantile representative).
pub fn bucket_upper_bound(i: usize) -> u64 {
    match i {
        0 => 0,
        1..=63 => (1u64 << i) - 1,
        _ => u64::MAX,
    }
}

/// A lock-free log2-bucketed histogram of `u64` samples (latencies in
/// ns, sizes in bytes, depths, batch sizes...).
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [ZERO; N_BUCKETS], sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    /// Record one sample: three relaxed `fetch_add`s.
    #[inline(always)]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy (not a cross-field atomic snapshot; under
    /// concurrent recording the fields may be a few samples apart, which
    /// is fine for monitoring readout).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; N_BUCKETS];
        for (c, b) in counts.iter_mut().zip(&self.buckets) {
            *c = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Plain-data copy of a [`Histogram`], mergeable and queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: [u64; N_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot { counts: [0; N_BUCKETS], sum: 0, count: 0 }
    }

    /// Exact merge: bucketwise addition. `a.merge(&b)` is identical to
    /// the snapshot of one histogram that recorded both sample sets
    /// (bucketing is a pure function of the value).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut out = self.clone();
        for (c, o) in out.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        out.sum += other.sum;
        out.count += other.count;
        out
    }

    /// Saturating bucketwise subtraction: the samples recorded *since*
    /// `earlier` was taken of the same histogram. The bench isolates one
    /// run's samples from the process-global registry with a
    /// before/after diff (`after.minus(&before)`), immune to whatever
    /// other tests recorded earlier in the process.
    pub fn minus(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut out = self.clone();
        for (c, e) in out.counts.iter_mut().zip(&earlier.counts) {
            *c = c.saturating_sub(*e);
        }
        out.sum = out.sum.saturating_sub(earlier.sum);
        out.count = out.count.saturating_sub(earlier.count);
        out
    }

    /// The q-quantile (`0 < q <= 1`) as the inclusive upper bound of the
    /// bucket holding the ⌈q·count⌉-th smallest sample; 0 when empty.
    /// Over-reports by < 2× (the bucket's width), never under-reports.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(N_BUCKETS - 1)
    }

    /// Mean of the recorded samples (exact — the sum is tracked exactly).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// How a split attempt resolved (mirrors the decision branches of
/// `tree::HoeffdingTreeRegressor`'s Hoeffding test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitOutcome {
    /// Merit ratio cleared the Hoeffding bound: split materialized.
    Accepted,
    /// Bound not cleared but ε shrank under the tie threshold: split
    /// materialized as a tie-break.
    TieBroken,
    /// Candidates too close for the current ε: leaf keeps observing.
    HoeffdingRejected,
    /// Best candidate had no positive merit.
    NoMerit,
    /// Best candidate would create an under-populated branch.
    BranchTooSmall,
}

impl SplitOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            SplitOutcome::Accepted => "accepted",
            SplitOutcome::TieBroken => "tie_broken",
            SplitOutcome::HoeffdingRejected => "hoeffding_rejected",
            SplitOutcome::NoMerit => "no_merit",
            SplitOutcome::BranchTooSmall => "branch_too_small",
        }
    }

    /// Did this outcome materialize a split?
    pub fn split(&self) -> bool {
        matches!(self, SplitOutcome::Accepted | SplitOutcome::TieBroken)
    }
}

/// One recorded split attempt.
#[derive(Clone, Copy, Debug)]
pub struct SplitEvent {
    pub outcome: SplitOutcome,
    /// `best.merit - second.merit` (0 when there was no runner-up).
    pub merit_gap: f64,
    /// Stored elements across the leaf's observers at decision time —
    /// the paper's "slots" cost axis for the evaluated query.
    pub slots_evaluated: u64,
    /// Wall-clock ns from gathering suggestions to the decision.
    pub elapsed_ns: u64,
}

/// One applied replication version on a follower (the `trace_repl`
/// ring): which version landed, the cumulative acked learns it covers,
/// and the wall-clock publish→apply freshness span.
#[derive(Clone, Copy, Debug)]
pub struct ReplEvent {
    /// The version the apply landed on.
    pub version: u64,
    /// Cumulative leader learns covered by that version (0 when the
    /// leader predates the freshness stamps).
    pub learns: u64,
    /// Publish→apply wall-clock span in ns (clamped at 0 under clock
    /// skew — the stamps are wall-clock across two hosts).
    pub span_ns: u64,
    /// Applied via a full resync rather than a delta chain.
    pub full: bool,
}

/// Bounded ring of recent events plus a total counter, generic over the
/// event payload: [`SplitEvent`] for the split-decision ring,
/// [`ReplEvent`] for the replication-apply ring. Mutexed: both event
/// kinds are rare (split attempts fire once per `grace_period` learns,
/// applies once per poll), so this is far off the hot path.
pub struct TraceRing<T = SplitEvent> {
    capacity: usize,
    inner: Mutex<TraceInner<T>>,
}

struct TraceInner<T> {
    events: VecDeque<T>,
    total: u64,
}

impl<T: Copy> TraceRing<T> {
    pub const fn new(capacity: usize) -> TraceRing<T> {
        TraceRing {
            capacity,
            inner: Mutex::new(TraceInner { events: VecDeque::new(), total: 0 }),
        }
    }

    pub fn record(&self, event: T) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.total += 1;
        if inner.events.len() >= self.capacity {
            inner.events.pop_front();
        }
        inner.events.push_back(event);
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> Vec<T> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.events.iter().copied().collect()
    }

    /// Up to `limit` of the most recent events, **newest first** — the
    /// wire shape of `trace_splits` / `trace_repl` (a dashboard wants
    /// the latest decisions at the top; ordering asserted in tests).
    pub fn recent(&self, limit: usize) -> Vec<T> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.events.iter().rev().take(limit).copied().collect()
    }

    /// Attempts ever recorded (including evicted ones).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).total
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Steady-state resident bytes of this ring: the struct itself plus
    /// a full `capacity`-deep event buffer. The `VecDeque` starts empty
    /// and its growth doubles, so the true heap size crosses this bound
    /// only transiently during a doubling — the same accounting-grade
    /// slack every other `mem_bytes()` in the crate accepts
    /// (`MEM_RATIO` in `docs/INVARIANTS.md`).
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<TraceRing<T>>() + self.capacity * std::mem::size_of::<T>()
    }
}

/// Every metric the system records, by name. One static instance backs
/// the process ([`global()`]); tests build their own.
pub struct Metrics {
    // tree
    pub tree_learns: Counter,
    pub tree_route_depth: Histogram,
    pub tree_splits_accepted: Counter,
    pub tree_splits_tie_broken: Counter,
    pub tree_splits_hoeffding_rejected: Counter,
    pub tree_splits_no_merit: Counter,
    pub tree_splits_branch_too_small: Counter,
    // observer
    pub qo_inserts: Counter,
    pub qo_slots_occupied: Histogram,
    // split backend
    pub backend_batches: Counter,
    pub backend_batch_size: Histogram,
    pub backend_latency_ns: Histogram,
    // forest
    pub forest_warnings: Counter,
    pub forest_drifts: Counter,
    pub forest_bg_promotions: Counter,
    // serve
    pub serve_learn_ns: Histogram,
    pub serve_predict_ns: Histogram,
    /// Learns acked, time-windowed (1m/5m rates in the exposition).
    pub serve_learn_window: WindowedCounter,
    /// Predictions served, time-windowed.
    pub serve_predict_window: WindowedCounter,
    /// Predict latency over the trailing windows (windowed p50/p99).
    pub serve_predict_ns_window: WindowedHistogram,
    pub serve_delta_publish_bytes: Histogram,
    pub serve_snapshot_failures_consecutive: Gauge,
    /// Wall-clock of one snapshot publication (structural clone + `Arc`
    /// swap + staging). Recorded in nanoseconds; exposed as the
    /// `qostream_snapshot_publish_seconds` summary.
    pub snapshot_publish_ns: Histogram,
    /// Canonical-JSON bytes of materialized checkpoint documents
    /// (`qostream_snapshot_bytes{format="json"}`).
    pub snapshot_bytes_json: Counter,
    /// Binary-envelope bytes of encoded checkpoint/delta payloads
    /// (`qostream_snapshot_bytes{format="binary"}`).
    pub snapshot_bytes_binary: Counter,
    // model
    pub model_mem_bytes: Gauge,
    // governance (crate::govern): escalation-step totals and the
    // configured budget (0 = unbounded)
    pub govern_compactions: Counter,
    pub govern_evictions: Counter,
    pub govern_prunes: Counter,
    pub mem_budget_bytes: Gauge,
    /// Unix seconds this process's server/follower role started
    /// (`qostream_process_start_seconds`) — rate math and restart
    /// detection from the scrape alone.
    pub process_start_seconds: Gauge,
    // replication (follower side)
    pub repl_lag_versions: Gauge,
    pub repl_lag_learns: Gauge,
    pub repl_deltas_applied: Counter,
    pub repl_full_resyncs: Counter,
    /// Live publish→apply span of each applied version, in ns (exposed
    /// as the `qostream_repl_freshness_seconds` summary).
    pub repl_freshness_ns: Histogram,
    /// The freshness spans over the trailing windows.
    pub repl_freshness_ns_window: WindowedHistogram,
    // trace rings
    pub split_trace: TraceRing,
    pub repl_trace: TraceRing<ReplEvent>,
}

impl Metrics {
    pub const fn new() -> Metrics {
        Metrics {
            tree_learns: Counter::new(),
            tree_route_depth: Histogram::new(),
            tree_splits_accepted: Counter::new(),
            tree_splits_tie_broken: Counter::new(),
            tree_splits_hoeffding_rejected: Counter::new(),
            tree_splits_no_merit: Counter::new(),
            tree_splits_branch_too_small: Counter::new(),
            qo_inserts: Counter::new(),
            qo_slots_occupied: Histogram::new(),
            backend_batches: Counter::new(),
            backend_batch_size: Histogram::new(),
            backend_latency_ns: Histogram::new(),
            forest_warnings: Counter::new(),
            forest_drifts: Counter::new(),
            forest_bg_promotions: Counter::new(),
            serve_learn_ns: Histogram::new(),
            serve_predict_ns: Histogram::new(),
            serve_learn_window: WindowedCounter::new(),
            serve_predict_window: WindowedCounter::new(),
            serve_predict_ns_window: WindowedHistogram::new(),
            serve_delta_publish_bytes: Histogram::new(),
            serve_snapshot_failures_consecutive: Gauge::new(),
            snapshot_publish_ns: Histogram::new(),
            snapshot_bytes_json: Counter::new(),
            snapshot_bytes_binary: Counter::new(),
            model_mem_bytes: Gauge::new(),
            govern_compactions: Counter::new(),
            govern_evictions: Counter::new(),
            govern_prunes: Counter::new(),
            mem_budget_bytes: Gauge::new(),
            process_start_seconds: Gauge::new(),
            repl_lag_versions: Gauge::new(),
            repl_lag_learns: Gauge::new(),
            repl_deltas_applied: Counter::new(),
            repl_full_resyncs: Counter::new(),
            repl_freshness_ns: Histogram::new(),
            repl_freshness_ns_window: WindowedHistogram::new(),
            split_trace: TraceRing::new(256),
            repl_trace: TraceRing::new(256),
        }
    }

    /// Resident bytes of the whole registry. Every instrument except
    /// the trace rings is a fixed inline block of atomics (counters,
    /// gauges, histograms, and the windowed rings of [`window`] all
    /// store `[AtomicU64; _]` arrays in place), so `size_of::<Metrics>`
    /// covers them exactly; only the two rings add heap, charged at
    /// their steady-state bound ([`TraceRing::mem_bytes`]). The PR 9
    /// windowed instruments and rings were previously missing from all
    /// accounting — a pinning test below keeps this sum honest.
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Metrics>()
            + self.split_trace.capacity() * std::mem::size_of::<SplitEvent>()
            + self.repl_trace.capacity() * std::mem::size_of::<ReplEvent>()
    }

    /// Route a split outcome to its per-outcome counter.
    pub fn count_split_outcome(&self, outcome: SplitOutcome) {
        match outcome {
            SplitOutcome::Accepted => self.tree_splits_accepted.inc(),
            SplitOutcome::TieBroken => self.tree_splits_tie_broken.inc(),
            SplitOutcome::HoeffdingRejected => self.tree_splits_hoeffding_rejected.inc(),
            SplitOutcome::NoMerit => self.tree_splits_no_merit.inc(),
            SplitOutcome::BranchTooSmall => self.tree_splits_branch_too_small.inc(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

/// One metric family's catalog entry: the single source of truth behind
/// the `# HELP` lines, the `# TYPE` kinds, and the family table in
/// `docs/OBSERVABILITY.md` (a test asserts code and doc agree).
pub struct MetricDesc {
    /// Full exposition family name (`qostream_…`).
    pub name: &'static str,
    /// Prometheus type emitted on the `# TYPE` line.
    pub kind: &'static str,
    /// One-line help text emitted on the `# HELP` line.
    pub help: &'static str,
}

/// Every metric family the exposition can emit, in exposition order.
pub const CATALOG: &[MetricDesc] = &[
    MetricDesc {
        name: "qostream_tree_learns_total",
        kind: "counter",
        help: "Instances learned across all trees",
    },
    MetricDesc {
        name: "qostream_tree_route_depth",
        kind: "summary",
        help: "Leaf depth reached when routing a learned instance",
    },
    MetricDesc {
        name: "qostream_tree_splits_accepted_total",
        kind: "counter",
        help: "Split attempts accepted by the Hoeffding bound",
    },
    MetricDesc {
        name: "qostream_tree_splits_tie_broken_total",
        kind: "counter",
        help: "Split attempts materialized via the tie-break threshold",
    },
    MetricDesc {
        name: "qostream_tree_splits_hoeffding_rejected_total",
        kind: "counter",
        help: "Split attempts rejected by the Hoeffding bound",
    },
    MetricDesc {
        name: "qostream_tree_splits_no_merit_total",
        kind: "counter",
        help: "Split attempts whose best candidate had no positive merit",
    },
    MetricDesc {
        name: "qostream_tree_splits_branch_too_small_total",
        kind: "counter",
        help: "Split attempts rejected for an under-populated branch",
    },
    MetricDesc {
        name: "qostream_qo_inserts_total",
        kind: "counter",
        help: "Values inserted into quantization-observer slot tables",
    },
    MetricDesc {
        name: "qostream_qo_slots_occupied",
        kind: "summary",
        help: "Occupied slots per quantization observer at query time",
    },
    MetricDesc {
        name: "qostream_backend_batches_total",
        kind: "counter",
        help: "Split-candidate batches flushed through the split backend",
    },
    MetricDesc {
        name: "qostream_backend_batch_size",
        kind: "summary",
        help: "Leaves evaluated per split-backend batch",
    },
    MetricDesc {
        name: "qostream_backend_latency_ns",
        kind: "summary",
        help: "Wall-clock ns per split-backend batch",
    },
    MetricDesc {
        name: "qostream_forest_warnings_total",
        kind: "counter",
        help: "ADWIN warning signals across forest members",
    },
    MetricDesc {
        name: "qostream_forest_drifts_total",
        kind: "counter",
        help: "ADWIN drift signals across forest members",
    },
    MetricDesc {
        name: "qostream_forest_bg_promotions_total",
        kind: "counter",
        help: "Background trees promoted to foreground on drift",
    },
    MetricDesc {
        name: "qostream_serve_learn_ns",
        kind: "summary",
        help: "Wall-clock ns per acked learn request",
    },
    MetricDesc {
        name: "qostream_serve_predict_ns",
        kind: "summary",
        help: "Wall-clock ns per served prediction",
    },
    MetricDesc {
        name: "qostream_serve_learn_rate",
        kind: "gauge",
        help: "Learns per second over the trailing window",
    },
    MetricDesc {
        name: "qostream_serve_predict_rate",
        kind: "gauge",
        help: "Predictions per second over the trailing window",
    },
    MetricDesc {
        name: "qostream_serve_predict_ns_window",
        kind: "gauge",
        help: "Predict latency quantiles (ns) over the trailing window",
    },
    MetricDesc {
        name: "qostream_serve_delta_publish_bytes",
        kind: "summary",
        help: "Compact-text bytes of each published delta",
    },
    MetricDesc {
        name: "qostream_snapshot_publish_seconds",
        kind: "summary",
        help: "Wall-clock seconds per snapshot publication (clone + swap + stage)",
    },
    MetricDesc {
        name: "qostream_snapshot_bytes",
        kind: "counter",
        help: "Bytes of materialized checkpoint payloads by encoding",
    },
    MetricDesc {
        name: "qostream_serve_snapshot_failures_consecutive",
        kind: "gauge",
        help: "Consecutive snapshot publication failures (0 = healthy)",
    },
    MetricDesc {
        name: "qostream_model_mem_bytes",
        kind: "gauge",
        help: "Resident bytes of the served model",
    },
    MetricDesc {
        name: "qostream_govern_compactions_total",
        kind: "counter",
        help: "QO slot tables compacted by the memory governor",
    },
    MetricDesc {
        name: "qostream_govern_evictions_total",
        kind: "counter",
        help: "Cold leaves whose observers the memory governor evicted",
    },
    MetricDesc {
        name: "qostream_govern_prunes_total",
        kind: "counter",
        help: "Ensemble members pruned by the memory governor",
    },
    MetricDesc {
        name: "qostream_model_mem_budget_bytes",
        kind: "gauge",
        help: "Configured model memory budget (0 = unbounded)",
    },
    MetricDesc {
        name: "qostream_process_start_seconds",
        kind: "gauge",
        help: "Unix seconds the serving role started (restart detection)",
    },
    MetricDesc {
        name: "qostream_repl_lag_versions",
        kind: "gauge",
        help: "Versions this follower trails the leader head",
    },
    MetricDesc {
        name: "qostream_repl_lag_learns",
        kind: "gauge",
        help: "Learns this follower trails the leader head",
    },
    MetricDesc {
        name: "qostream_repl_deltas_applied_total",
        kind: "counter",
        help: "Delta versions applied by this follower",
    },
    MetricDesc {
        name: "qostream_repl_full_resyncs_total",
        kind: "counter",
        help: "Full resyncs this follower fell back to",
    },
    MetricDesc {
        name: "qostream_repl_freshness_seconds",
        kind: "summary",
        help: "Live publish-to-apply span of each applied version",
    },
    MetricDesc {
        name: "qostream_repl_freshness_seconds_window",
        kind: "gauge",
        help: "Freshness span quantiles (seconds) over the trailing window",
    },
    MetricDesc {
        name: "qostream_tree_split_attempts_total",
        kind: "counter",
        help: "Split attempts ever recorded by the trace ring",
    },
];

/// Catalog lookup by family name (the renderer's `# HELP` source).
pub fn describe(name: &str) -> Option<&'static MetricDesc> {
    CATALOG.iter().find(|d| d.name == name)
}

/// Prometheus text exposition of one registry — rendered through the
/// same [`RegistrySnapshot`] capture→render path the fleet aggregator
/// merges, so single-process and fleet output cannot drift.
pub fn exposition_of(m: &Metrics) -> String {
    RegistrySnapshot::capture(m).exposition()
}

/// Prometheus text exposition of the global registry (the serve
/// protocol's `metrics` command).
pub fn exposition() -> String {
    exposition_of(global())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::check;

    #[test]
    fn bucket_index_is_monotone_and_bounds_hold() {
        // every value lands in a bucket whose bounds contain it, and the
        // index is monotone in the value
        let probes = [
            0u64,
            1,
            2,
            3,
            4,
            7,
            8,
            1023,
            1024,
            u32::MAX as u64,
            u64::MAX / 2,
            u64::MAX,
        ];
        let mut last = 0usize;
        for &v in &probes {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS);
            assert!(i >= last, "index not monotone at {v}");
            last = i;
            let lo = if i == 0 { 0 } else { bucket_upper_bound(i - 1).saturating_add(1) };
            assert!(v >= lo && v <= bucket_upper_bound(i), "v={v} outside bucket {i}");
        }
    }

    #[test]
    fn prop_merge_equals_pooled_recording() {
        check("histogram-merge-pooled", 0x0B5E, 50, |rng| {
            let (a, b, pooled) = (Histogram::new(), Histogram::new(), Histogram::new());
            for _ in 0..rng.below(200) {
                let v = rng.below(1 << rng.below(40));
                a.record(v);
                pooled.record(v);
            }
            for _ in 0..rng.below(200) {
                let v = rng.below(1 << rng.below(40));
                b.record(v);
                pooled.record(v);
            }
            let merged = a.snapshot().merge(&b.snapshot());
            if merged != pooled.snapshot() {
                return Err("merge != pooled".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantile_bounds() {
        // the quantile estimate never under-reports the true quantile
        // and over-reports by strictly less than 2x (one bucket width)
        check("histogram-quantile-bounds", 0x0B5F, 50, |rng| {
            let h = Histogram::new();
            let n = 1 + rng.below(300) as usize;
            let mut values: Vec<u64> = (0..n).map(|_| rng.below(1 << rng.below(32))).collect();
            for &v in &values {
                h.record(v);
            }
            values.sort_unstable();
            let s = h.snapshot();
            for q in [0.5, 0.9, 0.99] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let truth = values[rank - 1];
                let est = s.quantile(q);
                if est < truth {
                    return Err(format!("q{q}: est {est} < true {truth}"));
                }
                if truth > 0 && est >= truth.saturating_mul(2) {
                    return Err(format!("q{q}: est {est} >= 2x true {truth}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantiles_on_empty_and_single_sample() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        h.record(100);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.sum, 100);
        // 100 lives in [64, 127]
        assert_eq!(s.quantile(0.5), 127);
        assert_eq!(s.quantile(0.99), 127);
        assert!((s.mean() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn trace_ring_bounds_and_counts() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.record(SplitEvent {
                outcome: if i % 2 == 0 {
                    SplitOutcome::Accepted
                } else {
                    SplitOutcome::HoeffdingRejected
                },
                merit_gap: i as f64,
                slots_evaluated: i,
                elapsed_ns: i * 100,
            });
        }
        assert_eq!(ring.total(), 10);
        let events = ring.events();
        assert_eq!(events.len(), 4, "ring must stay bounded");
        // oldest-first: the survivors are attempts 6..=9
        assert_eq!(events[0].slots_evaluated, 6);
        assert_eq!(events[3].slots_evaluated, 9);
        assert!(events[0].outcome.split());
        assert!(!events[1].outcome.split());
    }

    #[test]
    fn trace_ring_recent_is_newest_first_and_capped() {
        // the wire shape of trace_splits/trace_repl: newest first, and
        // `limit` never exceeds what the ring holds
        let ring: TraceRing<ReplEvent> = TraceRing::new(4);
        for i in 1..=10u64 {
            ring.record(ReplEvent { version: i, learns: i * 5, span_ns: i, full: false });
        }
        let recent = ring.recent(3);
        assert_eq!(recent.len(), 3);
        assert_eq!(recent[0].version, 10, "newest first");
        assert_eq!(recent[2].version, 8);
        // a limit past the ring's occupancy clamps to the survivors
        let all = ring.recent(1000);
        assert_eq!(all.len(), 4);
        assert_eq!(all[0].version, 10);
        assert_eq!(all[3].version, 7);
        // recent(k) is events() reversed and truncated
        let mut from_events = ring.events();
        from_events.reverse();
        assert_eq!(
            all.iter().map(|e| e.version).collect::<Vec<_>>(),
            from_events.iter().map(|e| e.version).collect::<Vec<_>>()
        );
    }

    #[test]
    fn histogram_minus_isolates_a_run() {
        // the bench pattern: snapshot before, record, snapshot after,
        // diff — the diff is exactly the run's samples
        let h = Histogram::new();
        h.record(100);
        h.record(2000);
        let before = h.snapshot();
        h.record(100);
        h.record(300_000);
        let run = h.snapshot().minus(&before);
        assert_eq!(run.count, 2);
        assert_eq!(run.sum, 300_100);
        let mut expect = HistogramSnapshot::empty();
        expect.counts[bucket_index(100)] += 1;
        expect.counts[bucket_index(300_000)] += 1;
        expect.sum = 300_100;
        expect.count = 2;
        assert_eq!(run, expect);
        // diffing against a later snapshot saturates instead of wrapping
        let inverted = before.minus(&h.snapshot());
        assert_eq!(inverted.count, 0);
        assert_eq!(inverted.sum, 0);
    }

    #[test]
    fn registry_mem_accounting_pins_every_instrument() {
        use std::mem::size_of;
        // the windowed instruments are fixed inline blocks: nothing on
        // the heap, so their accounting is exactly their struct size —
        // and that size must actually contain their rings
        assert_eq!(WindowedCounter::new().mem_bytes(), size_of::<WindowedCounter>());
        assert!(
            WindowedCounter::new().mem_bytes() >= window::N_TIME_BUCKETS * 2 * 8,
            "a windowed counter holds an (epoch, count) pair per time bucket"
        );
        assert_eq!(WindowedHistogram::new().mem_bytes(), size_of::<WindowedHistogram>());
        assert!(
            WindowedHistogram::new().mem_bytes()
                >= window::N_TIME_BUCKETS * (N_BUCKETS + 3) * 8,
            "a windowed histogram holds a full bucket array per time bucket"
        );
        // trace rings charge struct + steady-state buffer, independent
        // of current occupancy (the bound a budget must plan for)
        let ring: TraceRing = TraceRing::new(256);
        assert_eq!(
            ring.mem_bytes(),
            size_of::<TraceRing>() + 256 * size_of::<SplitEvent>()
        );
        let occupied: TraceRing = TraceRing::new(256);
        occupied.record(SplitEvent {
            outcome: SplitOutcome::Accepted,
            merit_gap: 0.0,
            slots_evaluated: 1,
            elapsed_ns: 1,
        });
        assert_eq!(occupied.mem_bytes(), ring.mem_bytes());
        // the registry total is the inline block plus both rings' heap —
        // the PR 9 instruments can no longer go missing from the sum
        let m = Metrics::new();
        assert_eq!(
            m.mem_bytes(),
            size_of::<Metrics>()
                + m.split_trace.capacity() * size_of::<SplitEvent>()
                + m.repl_trace.capacity() * size_of::<ReplEvent>()
        );
        assert!(
            m.mem_bytes()
                > size_of::<WindowedCounter>() * 2 + size_of::<WindowedHistogram>() * 2,
            "the registry total must contain its windowed instruments"
        );
    }

    #[test]
    fn outcome_labels_are_stable() {
        // the wire format of trace_splits depends on these strings
        assert_eq!(SplitOutcome::Accepted.label(), "accepted");
        assert_eq!(SplitOutcome::TieBroken.label(), "tie_broken");
        assert_eq!(SplitOutcome::HoeffdingRejected.label(), "hoeffding_rejected");
        assert_eq!(SplitOutcome::NoMerit.label(), "no_merit");
        assert_eq!(SplitOutcome::BranchTooSmall.label(), "branch_too_small");
    }

    #[test]
    fn exposition_golden() {
        // a local registry with known values renders the exact text the
        // `metrics` command promises (naming scheme + summary shape)
        let m = Metrics::new();
        m.tree_learns.add(42);
        m.tree_route_depth.record(3);
        m.tree_route_depth.record(3);
        m.count_split_outcome(SplitOutcome::Accepted);
        m.count_split_outcome(SplitOutcome::TieBroken);
        m.count_split_outcome(SplitOutcome::HoeffdingRejected);
        m.model_mem_bytes.set(4096);
        let text = exposition_of(&m);
        for needle in [
            "# TYPE qostream_tree_learns_total counter\nqostream_tree_learns_total 42\n",
            "# TYPE qostream_tree_route_depth summary\n\
             qostream_tree_route_depth{quantile=\"0.5\"} 3\n\
             qostream_tree_route_depth{quantile=\"0.9\"} 3\n\
             qostream_tree_route_depth{quantile=\"0.99\"} 3\n\
             qostream_tree_route_depth_sum 6\nqostream_tree_route_depth_count 2\n",
            "qostream_tree_splits_accepted_total 1\n",
            "qostream_tree_splits_tie_broken_total 1\n",
            "qostream_tree_splits_hoeffding_rejected_total 1\n",
            "# TYPE qostream_model_mem_bytes gauge\nqostream_model_mem_bytes 4096\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // the acceptance criterion: >= 15 distinct series families
        let families = text.matches("# TYPE ").count();
        assert!(families >= 15, "only {families} series families:\n{text}");
        // every family follows the naming scheme
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            assert!(name.starts_with("qostream_"), "bad metric name {name}");
        }
    }

    #[test]
    fn snapshot_publish_and_bytes_families_render() {
        // the snapshot-cost instruments: a ns histogram exposed in
        // seconds, and one byte counter family split by format label
        let m = Metrics::new();
        m.snapshot_publish_ns.record(2_000_000_000); // 2s → bucket upper bound < 4s
        m.snapshot_bytes_json.add(1000);
        m.snapshot_bytes_binary.add(400);
        let text = exposition_of(&m);
        assert!(text.contains("# TYPE qostream_snapshot_publish_seconds summary\n"));
        assert!(text.contains("qostream_snapshot_publish_seconds_count 1\n"));
        assert!(text.contains("# TYPE qostream_snapshot_bytes counter\n"));
        assert!(text.contains("qostream_snapshot_bytes{format=\"json\"} 1000\n"));
        assert!(text.contains("qostream_snapshot_bytes{format=\"binary\"} 400\n"));
        // the quantile is the bucket's upper bound in seconds: within
        // [2, 4) for a 2s sample (log2 buckets over-report < 2x)
        let q50 = text
            .lines()
            .find(|l| l.starts_with("qostream_snapshot_publish_seconds{quantile=\"0.5\"}"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap();
        assert!((2.0..4.0).contains(&q50), "q50 = {q50}");
    }

    #[test]
    fn disabled_registry_yields_no_global_handle() {
        // m() is the gate: when disabled it returns None and recording
        // sites skip all work. The lock keeps the overhead bench (which
        // also flips the global switch) from interleaving.
        let _toggling = toggle_lock();
        disable();
        assert!(m().is_none());
        enable();
        assert!(m().is_some());
        // leave it enabled: instrumentation is side-effect-free for
        // model behavior, and other tests may be recording concurrently
    }
}
