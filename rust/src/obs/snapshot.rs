//! Registry snapshots: a mergeable, JSON-codable capture of the whole
//! metrics registry, and the **single renderer** behind every
//! Prometheus exposition this crate emits.
//!
//! Why this exists: the fleet aggregator (`serve/fleet.rs`) must merge
//! N nodes' metrics **exactly**. Quantiles rendered to text cannot be
//! merged (a p99 of p99s is not a fleet p99), but the underlying log2
//! histograms can — bucketwise addition is identical to having recorded
//! every node's samples into one histogram
//! ([`HistogramSnapshot::merge`], property-tested). So nodes ship their
//! raw bucket counts over the wire (the `metrics_raw` protocol
//! command), the aggregator merges [`RegistrySnapshot`]s, and renders
//! the merged result with the same code path a single process uses:
//! [`crate::obs::exposition_of`] is literally
//! `RegistrySnapshot::capture(m).exposition()`. One renderer — the
//! fleet view and the node view cannot drift.
//!
//! Merge semantics per family kind:
//!
//! * **counters / summaries / windowed summaries** — exact sums
//!   (bucketwise for histograms).
//! * **gauges** — summed: the merged view reads as a fleet total
//!   (`model_mem_bytes` = fleet RAM). Per-node gauge values are served
//!   beside the merged families with `node`/`role` labels by the
//!   aggregator, so nothing is lost.
//! * **rates** — windowed event *counts* travel and sum; the rate is
//!   derived at render time, so merged rates are fleet-wide
//!   events/second, exactly.
//!
//! The wire format (`qostream-metrics-snapshot/1`) encodes histograms
//! sparsely (only occupied buckets) with `u64`s as decimal strings
//! ([`crate::persist::codec::ju64`]) so counts survive JSON exactly.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::persist::codec::{ju64, jusize, pu64, pusize};

use super::window::{self, WINDOWS};
use super::{HistogramSnapshot, Metrics, N_BUCKETS};

/// Wire-format identifier for encoded snapshots.
pub const SCHEMA: &str = "qostream-metrics-snapshot/1";

/// How a histogram family's samples are scaled at render time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Samples render as their raw `u64` values.
    Unit,
    /// Nanosecond samples render as seconds (Prometheus duration
    /// convention): quantiles and `_sum` divide by 1e9.
    NsAsSeconds,
}

impl Scale {
    fn tag(self) -> &'static str {
        match self {
            Scale::Unit => "unit",
            Scale::NsAsSeconds => "ns_s",
        }
    }

    fn from_tag(tag: &str) -> Result<Scale> {
        match tag {
            "unit" => Ok(Scale::Unit),
            "ns_s" => Ok(Scale::NsAsSeconds),
            other => Err(anyhow!("unknown scale tag {other:?}")),
        }
    }
}

/// One family's captured data.
#[derive(Clone, Debug, PartialEq)]
pub enum FamilyData {
    /// Samples as `(label-block, value)`; the label block is either
    /// empty or a literal `{key="value"}` suffix.
    Counter(Vec<(String, u64)>),
    Gauge(Vec<(String, u64)>),
    Summary { scale: Scale, hist: HistogramSnapshot },
    /// Per-window histograms, as `(window-label, hist)`.
    WindowedSummary { scale: Scale, windows: Vec<(String, HistogramSnapshot)> },
    /// Per-window event counts, as `(window-label, window-secs, count)`.
    WindowedRate { windows: Vec<(String, u64, u64)> },
}

impl FamilyData {
    /// The Prometheus type emitted on the `# TYPE` line (windowed
    /// families render as gauges with a `window` label).
    pub fn prom_kind(&self) -> &'static str {
        match self {
            FamilyData::Counter(_) => "counter",
            FamilyData::Gauge(_) => "gauge",
            FamilyData::Summary { .. } => "summary",
            FamilyData::WindowedSummary { .. } | FamilyData::WindowedRate { .. } => "gauge",
        }
    }

    fn wire_kind(&self) -> &'static str {
        match self {
            FamilyData::Counter(_) => "counter",
            FamilyData::Gauge(_) => "gauge",
            FamilyData::Summary { .. } => "summary",
            FamilyData::WindowedSummary { .. } => "wsummary",
            FamilyData::WindowedRate { .. } => "rate",
        }
    }
}

/// One named metric family.
#[derive(Clone, Debug, PartialEq)]
pub struct Family {
    pub name: String,
    pub data: FamilyData,
}

impl Family {
    fn counter(name: &str, v: u64) -> Family {
        Family { name: name.to_string(), data: FamilyData::Counter(vec![(String::new(), v)]) }
    }

    fn gauge(name: &str, v: u64) -> Family {
        Family { name: name.to_string(), data: FamilyData::Gauge(vec![(String::new(), v)]) }
    }

    fn summary(name: &str, scale: Scale, hist: HistogramSnapshot) -> Family {
        Family { name: name.to_string(), data: FamilyData::Summary { scale, hist } }
    }
}

/// A point-in-time capture of every family in a [`Metrics`] registry.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistrySnapshot {
    pub families: Vec<Family>,
}

impl RegistrySnapshot {
    /// Capture a registry now.
    pub fn capture(m: &Metrics) -> RegistrySnapshot {
        RegistrySnapshot::capture_at(m, window::now_unix_secs())
    }

    /// Capture a registry with an explicit unix-seconds instant for the
    /// windowed families (deterministic in tests).
    pub fn capture_at(m: &Metrics, now_secs: u64) -> RegistrySnapshot {
        let wsummary = |name: &str, scale: Scale, h: &super::WindowedHistogram| Family {
            name: name.to_string(),
            data: FamilyData::WindowedSummary {
                scale,
                windows: WINDOWS
                    .iter()
                    .map(|(label, secs)| {
                        (label.to_string(), h.snapshot_window_at(*secs, now_secs))
                    })
                    .collect(),
            },
        };
        let wrate = |name: &str, c: &super::WindowedCounter| Family {
            name: name.to_string(),
            data: FamilyData::WindowedRate {
                windows: WINDOWS
                    .iter()
                    .map(|(label, secs)| {
                        (label.to_string(), *secs, c.sum_window_at(*secs, now_secs))
                    })
                    .collect(),
            },
        };
        let families = vec![
            Family::counter("qostream_tree_learns_total", m.tree_learns.get()),
            Family::summary(
                "qostream_tree_route_depth",
                Scale::Unit,
                m.tree_route_depth.snapshot(),
            ),
            Family::counter("qostream_tree_splits_accepted_total", m.tree_splits_accepted.get()),
            Family::counter(
                "qostream_tree_splits_tie_broken_total",
                m.tree_splits_tie_broken.get(),
            ),
            Family::counter(
                "qostream_tree_splits_hoeffding_rejected_total",
                m.tree_splits_hoeffding_rejected.get(),
            ),
            Family::counter("qostream_tree_splits_no_merit_total", m.tree_splits_no_merit.get()),
            Family::counter(
                "qostream_tree_splits_branch_too_small_total",
                m.tree_splits_branch_too_small.get(),
            ),
            Family::counter("qostream_qo_inserts_total", m.qo_inserts.get()),
            Family::summary(
                "qostream_qo_slots_occupied",
                Scale::Unit,
                m.qo_slots_occupied.snapshot(),
            ),
            Family::counter("qostream_backend_batches_total", m.backend_batches.get()),
            Family::summary(
                "qostream_backend_batch_size",
                Scale::Unit,
                m.backend_batch_size.snapshot(),
            ),
            Family::summary(
                "qostream_backend_latency_ns",
                Scale::Unit,
                m.backend_latency_ns.snapshot(),
            ),
            Family::counter("qostream_forest_warnings_total", m.forest_warnings.get()),
            Family::counter("qostream_forest_drifts_total", m.forest_drifts.get()),
            Family::counter("qostream_forest_bg_promotions_total", m.forest_bg_promotions.get()),
            Family::summary("qostream_serve_learn_ns", Scale::Unit, m.serve_learn_ns.snapshot()),
            Family::summary(
                "qostream_serve_predict_ns",
                Scale::Unit,
                m.serve_predict_ns.snapshot(),
            ),
            wrate("qostream_serve_learn_rate", &m.serve_learn_window),
            wrate("qostream_serve_predict_rate", &m.serve_predict_window),
            wsummary("qostream_serve_predict_ns_window", Scale::Unit, &m.serve_predict_ns_window),
            Family::summary(
                "qostream_serve_delta_publish_bytes",
                Scale::Unit,
                m.serve_delta_publish_bytes.snapshot(),
            ),
            Family::summary(
                "qostream_snapshot_publish_seconds",
                Scale::NsAsSeconds,
                m.snapshot_publish_ns.snapshot(),
            ),
            Family {
                name: "qostream_snapshot_bytes".to_string(),
                data: FamilyData::Counter(vec![
                    ("{format=\"json\"}".to_string(), m.snapshot_bytes_json.get()),
                    ("{format=\"binary\"}".to_string(), m.snapshot_bytes_binary.get()),
                ]),
            },
            Family::gauge(
                "qostream_serve_snapshot_failures_consecutive",
                m.serve_snapshot_failures_consecutive.get(),
            ),
            Family::gauge("qostream_model_mem_bytes", m.model_mem_bytes.get()),
            Family::counter("qostream_govern_compactions_total", m.govern_compactions.get()),
            Family::counter("qostream_govern_evictions_total", m.govern_evictions.get()),
            Family::counter("qostream_govern_prunes_total", m.govern_prunes.get()),
            Family::gauge("qostream_model_mem_budget_bytes", m.mem_budget_bytes.get()),
            Family::gauge("qostream_process_start_seconds", m.process_start_seconds.get()),
            Family::gauge("qostream_repl_lag_versions", m.repl_lag_versions.get()),
            Family::gauge("qostream_repl_lag_learns", m.repl_lag_learns.get()),
            Family::counter("qostream_repl_deltas_applied_total", m.repl_deltas_applied.get()),
            Family::counter("qostream_repl_full_resyncs_total", m.repl_full_resyncs.get()),
            Family::summary(
                "qostream_repl_freshness_seconds",
                Scale::NsAsSeconds,
                m.repl_freshness_ns.snapshot(),
            ),
            wsummary(
                "qostream_repl_freshness_seconds_window",
                Scale::NsAsSeconds,
                &m.repl_freshness_ns_window,
            ),
            Family::counter("qostream_tree_split_attempts_total", m.split_trace.total()),
        ];
        RegistrySnapshot { families }
    }

    /// Exact merge of two captures (fleet aggregation): counters and
    /// histograms sum bucketwise, gauges sum to fleet totals, windowed
    /// rates sum their event counts. Errors when the two snapshots do
    /// not carry the same family sequence (version skew across nodes).
    pub fn merge(&self, other: &RegistrySnapshot) -> Result<RegistrySnapshot> {
        if self.families.len() != other.families.len() {
            return Err(anyhow!(
                "family count mismatch: {} vs {}",
                self.families.len(),
                other.families.len()
            ));
        }
        let mut families = Vec::with_capacity(self.families.len());
        for (a, b) in self.families.iter().zip(&other.families) {
            if a.name != b.name {
                return Err(anyhow!("family mismatch: {:?} vs {:?}", a.name, b.name));
            }
            let data = match (&a.data, &b.data) {
                (FamilyData::Counter(x), FamilyData::Counter(y)) => {
                    FamilyData::Counter(merge_samples(x, y))
                }
                (FamilyData::Gauge(x), FamilyData::Gauge(y)) => {
                    FamilyData::Gauge(merge_samples(x, y))
                }
                (
                    FamilyData::Summary { scale, hist },
                    FamilyData::Summary { scale: s2, hist: h2 },
                ) if scale == s2 => FamilyData::Summary { scale: *scale, hist: hist.merge(h2) },
                (
                    FamilyData::WindowedSummary { scale, windows },
                    FamilyData::WindowedSummary { scale: s2, windows: w2 },
                ) if scale == s2 => {
                    let mut out = windows.clone();
                    for (label, hist) in w2 {
                        match out.iter_mut().find(|(l, _)| l == label) {
                            Some((_, h)) => *h = h.merge(hist),
                            None => out.push((label.clone(), hist.clone())),
                        }
                    }
                    FamilyData::WindowedSummary { scale: *scale, windows: out }
                }
                (
                    FamilyData::WindowedRate { windows },
                    FamilyData::WindowedRate { windows: w2 },
                ) => {
                    let mut out = windows.clone();
                    for (label, secs, count) in w2 {
                        match out.iter_mut().find(|(l, _, _)| l == label) {
                            Some((_, s, c)) if *s == *secs => *c += count,
                            Some(_) => {
                                return Err(anyhow!("window {label:?} spans differ in {:?}", a.name))
                            }
                            None => out.push((label.clone(), *secs, *count)),
                        }
                    }
                    FamilyData::WindowedRate { windows: out }
                }
                _ => return Err(anyhow!("family kind mismatch in {:?}", a.name)),
            };
            families.push(Family { name: a.name.clone(), data });
        }
        Ok(RegistrySnapshot { families })
    }

    /// Render this capture as Prometheus text exposition (`# HELP` +
    /// `# TYPE` per family, help text from [`super::CATALOG`]).
    pub fn exposition(&self) -> String {
        let mut out = String::with_capacity(8192);
        for f in &self.families {
            if let Some(desc) = super::describe(&f.name) {
                out.push_str(&format!("# HELP {} {}\n", f.name, desc.help));
            }
            out.push_str(&format!("# TYPE {} {}\n", f.name, f.data.prom_kind()));
            match &f.data {
                FamilyData::Counter(samples) | FamilyData::Gauge(samples) => {
                    for (labels, v) in samples {
                        out.push_str(&format!("{}{labels} {v}\n", f.name));
                    }
                }
                FamilyData::Summary { scale, hist } => {
                    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                        let v = hist.quantile(q);
                        match scale {
                            Scale::Unit => out
                                .push_str(&format!("{}{{quantile=\"{label}\"}} {v}\n", f.name)),
                            Scale::NsAsSeconds => out.push_str(&format!(
                                "{}{{quantile=\"{label}\"}} {}\n",
                                f.name,
                                v as f64 / 1e9
                            )),
                        }
                    }
                    match scale {
                        Scale::Unit => out.push_str(&format!(
                            "{n}_sum {}\n{n}_count {}\n",
                            hist.sum,
                            hist.count,
                            n = f.name
                        )),
                        Scale::NsAsSeconds => out.push_str(&format!(
                            "{n}_sum {}\n{n}_count {}\n",
                            hist.sum as f64 / 1e9,
                            hist.count,
                            n = f.name
                        )),
                    }
                }
                FamilyData::WindowedSummary { scale, windows } => {
                    for (wlabel, hist) in windows {
                        for (q, qlabel) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                            let v = hist.quantile(q);
                            match scale {
                                Scale::Unit => out.push_str(&format!(
                                    "{}{{window=\"{wlabel}\",quantile=\"{qlabel}\"}} {v}\n",
                                    f.name
                                )),
                                Scale::NsAsSeconds => out.push_str(&format!(
                                    "{}{{window=\"{wlabel}\",quantile=\"{qlabel}\"}} {}\n",
                                    f.name,
                                    v as f64 / 1e9
                                )),
                            }
                        }
                    }
                }
                FamilyData::WindowedRate { windows } => {
                    for (wlabel, secs, count) in windows {
                        let rate =
                            if *secs == 0 { 0.0 } else { *count as f64 / *secs as f64 };
                        out.push_str(&format!("{}{{window=\"{wlabel}\"}} {rate}\n", f.name));
                    }
                }
            }
        }
        out
    }

    /// The summed value of a counter family (across its label samples).
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.families.iter().find(|f| f.name == name).and_then(|f| match &f.data {
            FamilyData::Counter(samples) => Some(samples.iter().map(|(_, v)| v).sum()),
            _ => None,
        })
    }

    /// The summed value of a gauge family.
    pub fn gauge_value(&self, name: &str) -> Option<u64> {
        self.families.iter().find(|f| f.name == name).and_then(|f| match &f.data {
            FamilyData::Gauge(samples) => Some(samples.iter().map(|(_, v)| v).sum()),
            _ => None,
        })
    }

    /// The histogram behind a summary family.
    pub fn summary_hist(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.families.iter().find(|f| f.name == name).and_then(|f| match &f.data {
            FamilyData::Summary { hist, .. } => Some(hist),
            _ => None,
        })
    }

    /// The derived events/second of a rate family for one window label.
    pub fn rate(&self, name: &str, window: &str) -> Option<f64> {
        self.families.iter().find(|f| f.name == name).and_then(|f| match &f.data {
            FamilyData::WindowedRate { windows } => windows
                .iter()
                .find(|(l, _, _)| l == window)
                .map(|(_, secs, count)| {
                    if *secs == 0 {
                        0.0
                    } else {
                        *count as f64 / *secs as f64
                    }
                }),
            _ => None,
        })
    }

    /// Encode for the `metrics_raw` wire command.
    pub fn to_json(&self) -> Json {
        let mut families = Json::Arr(Vec::new());
        for f in &self.families {
            let mut o = Json::obj();
            o.set("name", f.name.as_str()).set("kind", f.data.wire_kind());
            match &f.data {
                FamilyData::Counter(samples) | FamilyData::Gauge(samples) => {
                    let mut arr = Json::Arr(Vec::new());
                    for (labels, v) in samples {
                        let mut pair = Json::Arr(Vec::new());
                        pair.push(labels.as_str());
                        pair.push(ju64(*v));
                        arr.push(pair);
                    }
                    o.set("samples", arr);
                }
                FamilyData::Summary { scale, hist } => {
                    o.set("scale", scale.tag()).set("hist", hist_to_json(hist));
                }
                FamilyData::WindowedSummary { scale, windows } => {
                    let mut arr = Json::Arr(Vec::new());
                    for (label, hist) in windows {
                        let mut pair = Json::Arr(Vec::new());
                        pair.push(label.as_str());
                        pair.push(hist_to_json(hist));
                        arr.push(pair);
                    }
                    o.set("scale", scale.tag()).set("windows", arr);
                }
                FamilyData::WindowedRate { windows } => {
                    let mut arr = Json::Arr(Vec::new());
                    for (label, secs, count) in windows {
                        let mut triple = Json::Arr(Vec::new());
                        triple.push(label.as_str());
                        triple.push(ju64(*secs));
                        triple.push(ju64(*count));
                        arr.push(triple);
                    }
                    o.set("windows", arr);
                }
            }
            families.push(o);
        }
        let mut out = Json::obj();
        out.set("schema", SCHEMA).set("families", families);
        out
    }

    /// Decode a `metrics_raw` payload.
    pub fn from_json(j: &Json) -> Result<RegistrySnapshot> {
        let schema = j.get("schema").and_then(Json::as_str);
        if schema != Some(SCHEMA) {
            return Err(anyhow!("unsupported metrics snapshot schema {schema:?}"));
        }
        let fams = j
            .get("families")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("snapshot missing families array"))?;
        let mut families = Vec::with_capacity(fams.len());
        for f in fams {
            let name = f
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("family missing name"))?
                .to_string();
            let kind = f
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("family {name:?} missing kind"))?;
            let data = match kind {
                "counter" | "gauge" => {
                    let raw = f
                        .get("samples")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("family {name:?} missing samples"))?;
                    let mut samples = Vec::with_capacity(raw.len());
                    for pair in raw {
                        let pair =
                            pair.as_arr().ok_or_else(|| anyhow!("{name:?}: bad sample"))?;
                        let labels = pair
                            .first()
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name:?}: bad sample labels"))?;
                        let v = pu64(
                            pair.get(1).ok_or_else(|| anyhow!("{name:?}: bad sample value"))?,
                            "sample",
                        )?;
                        samples.push((labels.to_string(), v));
                    }
                    if kind == "counter" {
                        FamilyData::Counter(samples)
                    } else {
                        FamilyData::Gauge(samples)
                    }
                }
                "summary" => FamilyData::Summary {
                    scale: scale_of(f, &name)?,
                    hist: hist_from_json(
                        f.get("hist").ok_or_else(|| anyhow!("{name:?} missing hist"))?,
                    )?,
                },
                "wsummary" => {
                    let raw = f
                        .get("windows")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("family {name:?} missing windows"))?;
                    let mut windows = Vec::with_capacity(raw.len());
                    for pair in raw {
                        let pair =
                            pair.as_arr().ok_or_else(|| anyhow!("{name:?}: bad window"))?;
                        let label = pair
                            .first()
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name:?}: bad window label"))?;
                        let hist = hist_from_json(
                            pair.get(1).ok_or_else(|| anyhow!("{name:?}: bad window hist"))?,
                        )?;
                        windows.push((label.to_string(), hist));
                    }
                    FamilyData::WindowedSummary { scale: scale_of(f, &name)?, windows }
                }
                "rate" => {
                    let raw = f
                        .get("windows")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("family {name:?} missing windows"))?;
                    let mut windows = Vec::with_capacity(raw.len());
                    for triple in raw {
                        let triple =
                            triple.as_arr().ok_or_else(|| anyhow!("{name:?}: bad window"))?;
                        let label = triple
                            .first()
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name:?}: bad window label"))?;
                        let secs = pu64(
                            triple.get(1).ok_or_else(|| anyhow!("{name:?}: bad window secs"))?,
                            "secs",
                        )?;
                        let count = pu64(
                            triple.get(2).ok_or_else(|| anyhow!("{name:?}: bad window count"))?,
                            "count",
                        )?;
                        windows.push((label.to_string(), secs, count));
                    }
                    FamilyData::WindowedRate { windows }
                }
                other => return Err(anyhow!("family {name:?}: unknown kind {other:?}")),
            };
            families.push(Family { name, data });
        }
        Ok(RegistrySnapshot { families })
    }
}

fn scale_of(f: &Json, name: &str) -> Result<Scale> {
    Scale::from_tag(
        f.get("scale")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("family {name:?} missing scale"))?,
    )
}

fn merge_samples(a: &[(String, u64)], b: &[(String, u64)]) -> Vec<(String, u64)> {
    let mut out = a.to_vec();
    for (labels, v) in b {
        match out.iter_mut().find(|(l, _)| l == labels) {
            Some((_, existing)) => *existing += v,
            None => out.push((labels.clone(), *v)),
        }
    }
    out
}

/// Sparse histogram encoding: only occupied buckets travel, `u64`s as
/// decimal strings for exactness.
fn hist_to_json(h: &HistogramSnapshot) -> Json {
    let mut buckets = Json::Arr(Vec::new());
    for (i, c) in h.counts.iter().enumerate() {
        if *c != 0 {
            let mut pair = Json::Arr(Vec::new());
            pair.push(jusize(i));
            pair.push(ju64(*c));
            buckets.push(pair);
        }
    }
    let mut o = Json::obj();
    o.set("c", buckets).set("sum", ju64(h.sum)).set("count", ju64(h.count));
    o
}

fn hist_from_json(j: &Json) -> Result<HistogramSnapshot> {
    let mut out = HistogramSnapshot::empty();
    let buckets =
        j.get("c").and_then(Json::as_arr).ok_or_else(|| anyhow!("hist missing buckets"))?;
    for pair in buckets {
        let pair = pair.as_arr().ok_or_else(|| anyhow!("bad hist bucket"))?;
        let i = pusize(pair.first().ok_or_else(|| anyhow!("bad hist bucket index"))?, "bucket")?;
        if i >= N_BUCKETS {
            return Err(anyhow!("hist bucket index {i} out of range"));
        }
        out.counts[i] =
            pu64(pair.get(1).ok_or_else(|| anyhow!("bad hist bucket count"))?, "bucket count")?;
    }
    out.sum = pu64(j.get("sum").ok_or_else(|| anyhow!("hist missing sum"))?, "sum")?;
    out.count = pu64(j.get("count").ok_or_else(|| anyhow!("hist missing count"))?, "count")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::{SplitEvent, SplitOutcome};
    use super::*;
    use crate::common::proptest::check;

    const T0: u64 = 1_700_000_000;

    fn split_event(i: u64) -> SplitEvent {
        SplitEvent {
            outcome: SplitOutcome::Accepted,
            merit_gap: 0.5,
            slots_evaluated: i,
            elapsed_ns: i * 10,
        }
    }

    fn populate(m: &Metrics, seed: u64) {
        m.tree_learns.add(100 + seed);
        for i in 0..20 {
            m.tree_route_depth.record(i % 7);
            m.serve_predict_ns.record(1000 * (seed + i));
            m.repl_freshness_ns.record(i * 1_000_000);
            m.serve_predict_ns_window.record_at(1000 * (seed + i), T0 - (i % 50));
            m.repl_freshness_ns_window.record_at(i * 1_000_000, T0 - (i % 200));
            m.serve_learn_window.add_at(1, T0 - (i % 100));
        }
        m.snapshot_bytes_json.add(10 * seed);
        m.snapshot_bytes_binary.add(3 * seed);
        m.split_trace.record(split_event(seed));
    }

    #[test]
    fn capture_roundtrips_through_json_exactly() {
        let m = Metrics::new();
        populate(&m, 3);
        m.model_mem_bytes.set(1 << 20);
        let snap = RegistrySnapshot::capture_at(&m, T0);
        let decoded = RegistrySnapshot::from_json(&Json::parse(&snap.to_json().to_compact())
            .expect("wire text parses"))
        .expect("decodes");
        assert_eq!(snap, decoded);
    }

    #[test]
    fn merged_capture_equals_pooled_recording() {
        // the fleet-aggregation contract: merging two nodes' snapshots
        // is bit-exact equal to one registry that recorded everything
        let (a, b, pooled) = (Metrics::new(), Metrics::new(), Metrics::new());
        populate(&a, 1);
        populate(&b, 9);
        populate(&pooled, 1);
        populate(&pooled, 9);
        a.model_mem_bytes.set(500);
        b.model_mem_bytes.set(700);
        pooled.model_mem_bytes.set(1200); // gauges merge as fleet sums
        let merged = RegistrySnapshot::capture_at(&a, T0)
            .merge(&RegistrySnapshot::capture_at(&b, T0))
            .expect("same family sequence");
        assert_eq!(merged, RegistrySnapshot::capture_at(&pooled, T0));
        // and the rendered fleet exposition is the pooled one, verbatim
        assert_eq!(merged.exposition(), RegistrySnapshot::capture_at(&pooled, T0).exposition());
    }

    #[test]
    fn prop_merge_matches_pooled_over_random_recordings() {
        check("registry-merge-pooled", 0x0F1E, 25, |rng| {
            let (a, b, pooled) = (Metrics::new(), Metrics::new(), Metrics::new());
            for (node, which) in [(&a, 0u64), (&b, 1)] {
                for _ in 0..rng.below(100) {
                    let v = rng.below(1 << rng.below(40));
                    node.repl_freshness_ns.record(v);
                    pooled.repl_freshness_ns.record(v);
                    let at = T0 - rng.below(300);
                    node.serve_predict_ns_window.record_at(v, at);
                    pooled.serve_predict_ns_window.record_at(v, at);
                    node.serve_learn_window.add_at(1 + which, at);
                    pooled.serve_learn_window.add_at(1 + which, at);
                }
            }
            let merged = RegistrySnapshot::capture_at(&a, T0)
                .merge(&RegistrySnapshot::capture_at(&b, T0))
                .map_err(|e| e.to_string())?;
            if merged != RegistrySnapshot::capture_at(&pooled, T0) {
                return Err("merged != pooled".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_rejects_mismatched_family_sequences() {
        let m = Metrics::new();
        let a = RegistrySnapshot::capture_at(&m, T0);
        let mut b = RegistrySnapshot::capture_at(&m, T0);
        b.families[0].name = "qostream_other".to_string();
        assert!(a.merge(&b).is_err());
        let mut c = RegistrySnapshot::capture_at(&m, T0);
        c.families.pop();
        assert!(a.merge(&c).is_err());
    }

    #[test]
    fn exposition_carries_help_for_every_family() {
        let m = Metrics::new();
        populate(&m, 2);
        let text = RegistrySnapshot::capture_at(&m, T0).exposition();
        let mut families = 0usize;
        let mut prev: Option<&str> = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                families += 1;
                let (name, kind) = {
                    let mut it = rest.split_whitespace();
                    (it.next().expect("name"), it.next().expect("kind"))
                };
                // every TYPE is immediately preceded by its HELP line
                let help =
                    prev.and_then(|p| p.strip_prefix("# HELP ")).expect("HELP precedes TYPE");
                assert!(help.starts_with(name), "HELP/TYPE name mismatch at {name}");
                // and the catalog agrees on the kind
                let desc = super::super::describe(name)
                    .unwrap_or_else(|| panic!("{name} missing from CATALOG"));
                assert_eq!(desc.kind, kind, "catalog kind drift for {name}");
            }
            prev = Some(line);
        }
        // bidirectional: every catalog entry actually renders
        assert_eq!(families, super::super::CATALOG.len(), "families vs catalog:\n{text}");
    }

    #[test]
    fn windowed_families_render_with_window_labels() {
        let m = Metrics::new();
        for _ in 0..30 {
            m.serve_learn_window.add_at(2, T0);
            m.serve_predict_ns_window.record_at(50_000, T0);
            m.repl_freshness_ns_window.record_at(30_000_000, T0); // 30ms
        }
        let text = RegistrySnapshot::capture_at(&m, T0).exposition();
        // 60 learns over the 1m window = 1 learn/sec
        assert!(
            text.contains("qostream_serve_learn_rate{window=\"1m\"} 1\n"),
            "missing 1m learn rate:\n{text}"
        );
        assert!(text.contains("qostream_serve_learn_rate{window=\"5m\"} 0.2\n"), "{text}");
        assert!(
            text.contains("qostream_serve_predict_ns_window{window=\"1m\",quantile=\"0.99\"}"),
            "{text}"
        );
        // the freshness window renders in seconds: 30ms lands in the
        // (2^24..2^25] ns bucket, upper bound ~0.0335s
        let line = text
            .lines()
            .find(|l| {
                l.starts_with("qostream_repl_freshness_seconds_window{window=\"1m\",quantile=\"0.5\"}")
            })
            .expect("windowed freshness line");
        let v: f64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!((0.03..0.07).contains(&v), "windowed freshness p50 {v}");
    }

    #[test]
    fn docs_catalog_stays_in_sync_with_code() {
        // docs/OBSERVABILITY.md documents every family; a new metric
        // without a doc row (or a doc row for a removed metric) fails here
        let doc = include_str!("../../../docs/OBSERVABILITY.md");
        for desc in super::super::CATALOG {
            assert!(
                doc.contains(&format!("`{}`", desc.name)),
                "docs/OBSERVABILITY.md missing a row for {}",
                desc.name
            );
        }
    }
}
