//! E-BST — the Extended Binary Search Tree attribute observer
//! (Ikonomovska et al. 2011), the baseline the paper compares against —
//! and TE-BST, its input-truncating variant (paper Sec. 5.2).
//!
//! Each node is keyed by an observed feature value and stores the robust
//! target statistics of every observation with `x ≤ key` that *passed
//! through* the node on insertion (which covers the node's entire left
//! subtree). An in-order traversal accumulating ancestor statistics then
//! yields, at each node, the full left-hand statistics for the candidate
//! split `x ≤ key`; the right side is the Chan subtraction from the total.
//!
//! Nodes live in an arena (`Vec`) with u32 child indices: cache-friendlier
//! than boxed pointers and immune to recursion-depth issues — both the
//! insertion and the traversal are iterative, so adversarially sorted
//! input (a degenerate O(n)-deep tree) cannot overflow the stack.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::criterion::SplitCriterion;
use crate::persist::codec::{
    field, jf64, jusize, parr, pf64, pusize, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

use super::{AttributeObserver, SplitSuggestion};

const NONE: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct Node {
    key: f64,
    /// Statistics of all y whose x ≤ key among observations routed
    /// through this node.
    stats_le: VarStats,
    left: u32,
    right: u32,
}

/// The classical E-BST observer.
#[derive(Clone, Debug, Default)]
pub struct EBst {
    arena: Vec<Node>,
    root: u32,
    total: VarStats,
}

impl EBst {
    pub fn new() -> EBst {
        EBst { arena: Vec::new(), root: NONE, total: VarStats::new() }
    }

    fn insert(&mut self, key: f64, y: f64, w: f64) {
        if self.root == NONE {
            self.root = self.push_node(key, y, w);
            return;
        }
        let mut idx = self.root;
        loop {
            let node = &mut self.arena[idx as usize];
            if key <= node.key {
                // x ≤ node.key: this observation belongs to the node's
                // ≤-region statistics
                node.stats_le.update(y, w);
                if key == node.key {
                    return;
                }
                if node.left == NONE {
                    let new = self.push_node(key, y, w);
                    self.arena[idx as usize].left = new;
                    return;
                }
                idx = node.left;
            } else {
                if node.right == NONE {
                    let new = self.push_node(key, y, w);
                    self.arena[idx as usize].right = new;
                    return;
                }
                idx = node.right;
            }
        }
    }

    fn push_node(&mut self, key: f64, y: f64, w: f64) -> u32 {
        self.arena.push(Node {
            key,
            stats_le: VarStats::from_one(y, w),
            left: NONE,
            right: NONE,
        });
        (self.arena.len() - 1) as u32
    }

    /// Iterative in-order traversal; calls `visit(key, left_stats)` for
    /// every candidate threshold with the statistics of `x ≤ key`.
    fn for_each_candidate(&self, mut visit: impl FnMut(f64, VarStats)) {
        if self.root == NONE {
            return;
        }
        // (node, ancestor-left statistics, children-expanded?)
        let mut stack: Vec<(u32, VarStats, bool)> = vec![(self.root, VarStats::new(), false)];
        while let Some((idx, acc, expanded)) = stack.pop() {
            let node = &self.arena[idx as usize];
            if !expanded {
                stack.push((idx, acc, true));
                if node.left != NONE {
                    stack.push((node.left, acc, false));
                }
            } else {
                let left_stats = acc + node.stats_le;
                visit(node.key, left_stats);
                if node.right != NONE {
                    stack.push((node.right, left_stats, false));
                }
            }
        }
    }

    /// Decode an observer written by [`AttributeObserver::to_json`]. The
    /// arena is restored in its original insertion order, so continued
    /// insertion produces the identical tree shape.
    pub fn from_json(j: &Json) -> Result<EBst> {
        let nodes = parr(field(j, "nodes")?, "nodes")?;
        let mut arena = Vec::with_capacity(nodes.len());
        for item in nodes {
            let entry = parr(item, "nodes")?;
            if entry.len() != 4 {
                return Err(anyhow!("ebst node: expected [key, stats, left, right]"));
            }
            let left = pusize(&entry[2], "node.left")?;
            let right = pusize(&entry[3], "node.right")?;
            if left > u32::MAX as usize || right > u32::MAX as usize {
                return Err(anyhow!("ebst node: child index overflows u32"));
            }
            arena.push(Node {
                key: pf64(&entry[0], "node.key")?,
                stats_le: varstats_from(&entry[1], "node.stats")?,
                left: left as u32,
                right: right as u32,
            });
        }
        let root = pusize(field(j, "root")?, "root")?;
        if root > u32::MAX as usize {
            return Err(anyhow!("ebst: root index overflows u32"));
        }
        let n = arena.len();
        if root as u32 != NONE && root >= n {
            return Err(anyhow!("ebst: root index out of range"));
        }
        // live arenas only ever append children after their parent, so
        // child indices strictly increase along every path; enforcing it
        // here makes a cyclic (corrupt) checkpoint fail at load instead
        // of looping the iterative insert/traversal forever
        for (idx, node) in arena.iter().enumerate() {
            for child in [node.left, node.right] {
                if child != NONE && (child as usize >= n || child as usize <= idx) {
                    return Err(anyhow!("ebst: child index out of order"));
                }
            }
        }
        Ok(EBst {
            arena,
            root: root as u32,
            total: varstats_from(field(j, "total")?, "total")?,
        })
    }

    fn best_split_impl(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        let mut best: Option<SplitSuggestion> = None;
        let total = self.total;
        self.for_each_candidate(|key, left| {
            // the maximal key covers the whole sample: not a valid binary
            // partition (empty right side)
            if left.n >= total.n {
                return;
            }
            let right = total - left;
            let merit = criterion.merit(&total, &left, &right);
            if best.map(|b| merit > b.merit).unwrap_or(true) {
                best = Some(SplitSuggestion { threshold: key, merit, left, right });
            }
        });
        best
    }
}

impl AttributeObserver for EBst {
    fn observe(&mut self, x: f64, y: f64, w: f64) {
        if w <= 0.0 || !x.is_finite() || !y.is_finite() {
            return;
        }
        self.total.update(y, w);
        self.insert(x, y, w);
    }

    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        self.best_split_impl(criterion)
    }

    fn n_elements(&self) -> usize {
        self.arena.len()
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<EBst>() + self.arena.capacity() * std::mem::size_of::<Node>()
    }

    fn name(&self) -> String {
        "E-BST".to_string()
    }

    fn total(&self) -> VarStats {
        self.total
    }

    fn reset(&mut self) {
        self.arena.clear();
        self.root = NONE;
        self.total = VarStats::new();
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("type", "ebst")
            .set("root", jusize(self.root as usize))
            .set("total", varstats_to_json(&self.total))
            .set(
                "nodes",
                Json::Arr(
                    self.arena
                        .iter()
                        .map(|n| {
                            Json::Arr(vec![
                                jf64(n.key),
                                varstats_to_json(&n.stats_le),
                                jusize(n.left as usize),
                                jusize(n.right as usize),
                            ])
                        })
                        .collect(),
                ),
            );
        o
    }

    fn clone_box(&self) -> Box<dyn AttributeObserver> {
        Box::new(self.clone())
    }
}

/// TE-BST: E-BST over feature values truncated to `decimals` decimal
/// places before insertion (paper Sec. 5.2 uses 3).
#[derive(Clone, Debug)]
pub struct TruncatedEBst {
    inner: EBst,
    factor: f64,
    decimals: u32,
}

impl TruncatedEBst {
    pub fn new(decimals: u32) -> TruncatedEBst {
        TruncatedEBst { inner: EBst::new(), factor: 10f64.powi(decimals as i32), decimals }
    }

    /// Truncation toward zero, as "truncate to d decimal places" implies.
    #[inline]
    pub fn truncate(&self, x: f64) -> f64 {
        (x * self.factor).trunc() / self.factor
    }

    /// Decode an observer written by [`AttributeObserver::to_json`].
    pub fn from_json(j: &Json) -> Result<TruncatedEBst> {
        let decimals = pusize(field(j, "decimals")?, "decimals")?;
        if decimals > 300 {
            return Err(anyhow!("tebst: {decimals} decimal places is not representable"));
        }
        Ok(TruncatedEBst {
            inner: EBst::from_json(field(j, "inner")?)?,
            factor: 10f64.powi(decimals as i32),
            decimals: decimals as u32,
        })
    }
}

impl AttributeObserver for TruncatedEBst {
    fn observe(&mut self, x: f64, y: f64, w: f64) {
        if !x.is_finite() {
            return;
        }
        self.inner.observe(self.truncate(x), y, w);
    }

    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        self.inner.best_split(criterion)
    }

    fn n_elements(&self) -> usize {
        self.inner.n_elements()
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<TruncatedEBst>() - std::mem::size_of::<EBst>()
            + self.inner.mem_bytes()
    }

    fn name(&self) -> String {
        format!("TE-BST_{}", self.decimals)
    }

    fn total(&self) -> VarStats {
        self.inner.total()
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("type", "tebst")
            .set("decimals", jusize(self.decimals as usize))
            .set("inner", self.inner.to_json());
        o
    }

    fn clone_box(&self) -> Box<dyn AttributeObserver> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::{check, expect_close};
    use crate::common::Rng;
    use crate::criterion::VarianceReduction;
    use crate::observer::ExhaustiveObserver;

    #[test]
    fn node_count_equals_distinct_values() {
        let mut bst = EBst::new();
        for x in [1.0, 2.0, 1.0, 3.0, 2.0, 1.0] {
            bst.observe(x, x, 1.0);
        }
        assert_eq!(bst.n_elements(), 3);
        assert_eq!(bst.total().n, 6.0);
    }

    #[test]
    fn matches_exhaustive_on_random_data() {
        // E-BST candidates are the observed values (threshold = key), the
        // exhaustive oracle uses midpoints; merits at the argmax must agree
        // because both partition identically between the same neighbours.
        let mut bst = EBst::new();
        let mut ex = ExhaustiveObserver::new();
        let mut rng = Rng::new(21);
        for _ in 0..2000 {
            let x = rng.normal(0.0, 1.0);
            let y = (x * 3.0).sin() + rng.normal(0.0, 0.05);
            bst.observe(x, y, 1.0);
            ex.observe(x, y, 1.0);
        }
        let sb = bst.best_split(&VarianceReduction).unwrap();
        let se = ex.best_split(&VarianceReduction).unwrap();
        assert!((sb.merit - se.merit).abs() < 1e-9, "{} vs {}", sb.merit, se.merit);
        assert!((sb.left.n - se.left.n).abs() < 1e-9);
    }

    #[test]
    fn sorted_insertion_does_not_overflow() {
        // degenerate O(n)-deep tree: iterative insert/traverse must survive
        let mut bst = EBst::new();
        for i in 0..30_000 {
            bst.observe(i as f64, (i % 7) as f64, 1.0);
        }
        assert_eq!(bst.n_elements(), 30_000);
        assert!(bst.best_split(&VarianceReduction).is_some());
    }

    #[test]
    fn rightmost_key_not_proposed() {
        let mut bst = EBst::new();
        for (x, y) in [(1.0, 0.0), (2.0, 1.0), (3.0, 5.0)] {
            bst.observe(x, y, 1.0);
        }
        let s = bst.best_split(&VarianceReduction).unwrap();
        assert!(s.threshold < 3.0);
        assert!(s.right.n > 0.0);
    }

    #[test]
    fn truncation_collapses_nearby_values() {
        let mut te = TruncatedEBst::new(3);
        te.observe(0.12345, 1.0, 1.0);
        te.observe(0.12349, 2.0, 1.0);
        te.observe(0.12441, 3.0, 1.0);
        assert_eq!(te.n_elements(), 2); // 0.123 and 0.124
    }

    #[test]
    fn truncate_toward_zero() {
        let te = TruncatedEBst::new(3);
        assert_eq!(te.truncate(1.23456), 1.234);
        assert_eq!(te.truncate(-1.23456), -1.234);
    }

    #[test]
    fn tebst_fewer_elements_than_ebst() {
        let mut bst = EBst::new();
        let mut te = TruncatedEBst::new(3);
        let mut rng = Rng::new(23);
        for _ in 0..50_000 {
            let x = rng.normal(0.0, 0.1);
            bst.observe(x, x, 1.0);
            te.observe(x, x, 1.0);
        }
        assert!(te.n_elements() < bst.n_elements());
    }

    #[test]
    fn json_roundtrip_preserves_shape_and_future_inserts() {
        let mut bst = EBst::new();
        let mut rng = Rng::new(71);
        for _ in 0..600 {
            let x = rng.normal(0.0, 2.0);
            bst.observe(x, x.sin(), 1.0);
        }
        let text = bst.to_json().to_compact();
        let mut back = EBst::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_elements(), bst.n_elements());
        let sa = bst.best_split(&VarianceReduction).unwrap();
        let sb = back.best_split(&VarianceReduction).unwrap();
        assert_eq!(sa.threshold.to_bits(), sb.threshold.to_bits());
        assert_eq!(sa.merit.to_bits(), sb.merit.to_bits());
        // continued insertion stays structurally identical
        for _ in 0..300 {
            let x = rng.normal(0.0, 2.0);
            bst.observe(x, x.sin(), 1.0);
            back.observe(x, x.sin(), 1.0);
        }
        assert_eq!(back.n_elements(), bst.n_elements());
        let sa = bst.best_split(&VarianceReduction).unwrap();
        let sb = back.best_split(&VarianceReduction).unwrap();
        assert_eq!(sa.threshold.to_bits(), sb.threshold.to_bits());
        assert_eq!(sa.merit.to_bits(), sb.merit.to_bits());
    }

    #[test]
    fn json_decode_rejects_corrupt_indices() {
        let mut bst = EBst::new();
        bst.observe(1.0, 1.0, 1.0);
        let mut j = bst.to_json();
        j.set("root", crate::persist::codec::jusize(99));
        assert!(EBst::from_json(&j).is_err());
    }

    #[test]
    fn json_decode_rejects_cycles() {
        use crate::persist::codec::jusize;
        // node 0's left child pointing back at node 0 would loop the
        // iterative insert forever; decode must reject it
        let mut bst = EBst::new();
        bst.observe(2.0, 1.0, 1.0);
        bst.observe(1.0, 0.5, 1.0);
        let doc = bst.to_json();
        let nodes = doc.get("nodes").unwrap().as_arr().unwrap();
        let first = nodes[0].as_arr().unwrap();
        let patched = Json::Arr(vec![
            first[0].clone(),
            first[1].clone(),
            jusize(0), // left → itself
            first[3].clone(),
        ]);
        let mut rest: Vec<Json> = nodes.to_vec();
        rest[0] = patched;
        let mut doc = doc;
        doc.set("nodes", Json::Arr(rest));
        assert!(EBst::from_json(&doc).is_err(), "cyclic arena must be rejected");
    }

    #[test]
    fn tebst_json_roundtrip_keeps_truncation() {
        let mut te = TruncatedEBst::new(3);
        te.observe(0.12345, 1.0, 1.0);
        te.observe(0.12441, 3.0, 1.0);
        let back =
            TruncatedEBst::from_json(&Json::parse(&te.to_json().to_compact()).unwrap())
                .unwrap();
        assert_eq!(back.n_elements(), 2);
        assert_eq!(back.name(), "TE-BST_3");
        assert_eq!(back.truncate(1.23456), 1.234);
    }

    #[test]
    fn prop_partition_sums_to_total() {
        check("ebst-partition-total", 0xC0, 40, |rng| {
            let mut bst = EBst::new();
            let n = 50 + rng.below(500);
            for _ in 0..n {
                bst.observe(rng.normal(0.0, 3.0), rng.normal(0.0, 1.0), 1.0);
            }
            if let Some(s) = bst.best_split(&VarianceReduction) {
                let sum = s.left + s.right;
                expect_close("n", sum.n, bst.total().n, 1e-9, 1e-9)?;
                expect_close("mean", sum.mean, bst.total().mean, 1e-7, 1e-7)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ebst_merit_geq_qo_merit() {
        // paper Sec. 6.1: exhaustive methods upper-bound QO's merit
        use crate::observer::{QuantizationObserver, RadiusPolicy};
        check("ebst>=qo", 0xC1, 25, |rng| {
            let mut bst = EBst::new();
            let mut qo = QuantizationObserver::new(RadiusPolicy::Fixed(0.25));
            let n = 500 + rng.below(1500);
            for _ in 0..n {
                let x = rng.normal(0.0, 1.0);
                let y = x.powi(3) + rng.normal(0.0, 0.2);
                bst.observe(x, y, 1.0);
                qo.observe(x, y, 1.0);
            }
            let mb = bst.best_split(&VarianceReduction).map(|s| s.merit).unwrap_or(0.0);
            let mq = qo.best_split(&VarianceReduction).map(|s| s.merit).unwrap_or(0.0);
            if mb + 1e-9 >= mq {
                Ok(())
            } else {
                Err(format!("E-BST merit {mb} < QO merit {mq}"))
            }
        });
    }
}
