//! Attribute Observers (AOs): the structures an online regression tree
//! keeps per numerical feature per leaf to monitor the stream and answer
//! split-candidate queries.
//!
//! * [`QuantizationObserver`] — the paper's contribution (Sec. 4): O(1)
//!   hashed insertion, O(|H| log |H|) query, |H| ≪ n memory.
//! * [`EBst`] — the classical Extended Binary Search Tree baseline
//!   (Ikonomovska et al. 2011): O(log n) insertion, O(n) memory/query.
//! * [`TruncatedEBst`] — E-BST over inputs truncated to `d` decimal places
//!   (the paper's TE-BST baseline).
//! * [`ExhaustiveObserver`] — stores the raw sample and evaluates every
//!   boundary; the test oracle.
//!
//! All observers use the robust [`VarStats`] estimators
//! (the paper replaces the naive Σy² statistics in *all*
//! compared AOs, Sec. 3).

pub mod ebst;
pub mod exhaustive;
pub mod multi_target;
pub mod qo;
pub mod radius;

pub use ebst::{EBst, TruncatedEBst};
pub use exhaustive::ExhaustiveObserver;
pub use multi_target::MultiTargetQuantizationObserver;
pub use qo::QuantizationObserver;
pub use radius::RadiusPolicy;

use crate::criterion::SplitCriterion;
use crate::stats::VarStats;

/// A proposed binary split `x ≤ threshold` with its merit and the target
/// statistics of the two branches.
#[derive(Clone, Copy, Debug)]
pub struct SplitSuggestion {
    pub threshold: f64,
    pub merit: f64,
    pub left: VarStats,
    pub right: VarStats,
}

/// The interface the tree (and the bench harness) programs against.
pub trait AttributeObserver: Send {
    /// Monitor one observation of the feature with target `y`, weight `w`.
    fn observe(&mut self, x: f64, y: f64, w: f64);

    /// Best split candidate under `criterion`, or `None` if fewer than two
    /// distinct partitions have been observed.
    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion>;

    /// Number of stored elements (paper's memory metric: BST nodes or hash
    /// slots — all elements store the same statistics, Sec. 5.3).
    fn n_elements(&self) -> usize;

    /// Observer name for reports.
    fn name(&self) -> String;

    /// Total target statistics seen by this observer.
    fn total(&self) -> VarStats;

    /// Forget everything (leaf reuse after a split).
    fn reset(&mut self);

    /// Downcast hook for batched split backends
    /// ([`crate::runtime::backend`]): Quantization Observers expose
    /// themselves so a backend can pack their slot tables; every other
    /// observer stays opaque and is answered per-observer.
    fn as_qo(&self) -> Option<&QuantizationObserver> {
        None
    }
}

/// Factory for building one observer per feature (tree leaves need
/// independently-owned instances).
pub trait ObserverFactory: Send + Sync {
    fn build(&self) -> Box<dyn AttributeObserver>;
    fn name(&self) -> String;
}

/// Blanket factory from a closure.
pub struct FnObserverFactory<F: Fn() -> Box<dyn AttributeObserver> + Send + Sync> {
    pub f: F,
    pub label: String,
}

impl<F: Fn() -> Box<dyn AttributeObserver> + Send + Sync> ObserverFactory
    for FnObserverFactory<F>
{
    fn build(&self) -> Box<dyn AttributeObserver> {
        (self.f)()
    }
    fn name(&self) -> String {
        self.label.clone()
    }
}

/// Convenience constructor for boxed closure factories.
pub fn factory<F>(label: &str, f: F) -> Box<dyn ObserverFactory>
where
    F: Fn() -> Box<dyn AttributeObserver> + Send + Sync + 'static,
{
    Box::new(FnObserverFactory { f, label: label.to_string() })
}

/// A factory view over shared configuration: ensembles hold one
/// `Arc<dyn ObserverFactory>` and hand every member tree (and every
/// background tree spawned later) its own boxed [`ArcFactory`] clone.
pub struct ArcFactory(std::sync::Arc<dyn ObserverFactory>);

impl ArcFactory {
    pub fn new(shared: std::sync::Arc<dyn ObserverFactory>) -> ArcFactory {
        ArcFactory(shared)
    }
}

impl ObserverFactory for ArcFactory {
    fn build(&self) -> Box<dyn AttributeObserver> {
        self.0.build()
    }

    fn name(&self) -> String {
        self.0.name()
    }
}

/// The paper's five compared observer configurations (Sec. 5.2).
pub fn paper_lineup() -> Vec<Box<dyn ObserverFactory>> {
    vec![
        factory("E-BST", || Box::new(EBst::new())),
        factory("TE-BST", || Box::new(TruncatedEBst::new(3))),
        factory("QO_0.01", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::Fixed(0.01)))
        }),
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        }),
        factory("QO_s3", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(3.0)))
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criterion::VarianceReduction;

    #[test]
    fn paper_lineup_names() {
        let names: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
        assert_eq!(names, vec!["E-BST", "TE-BST", "QO_0.01", "QO_s2", "QO_s3"]);
    }

    #[test]
    fn arc_factory_forwards_to_shared() {
        let shared: std::sync::Arc<dyn ObserverFactory> =
            std::sync::Arc::from(factory("E-BST", || Box::new(EBst::new())));
        let a = ArcFactory::new(shared.clone());
        let b = ArcFactory::new(shared);
        assert_eq!(a.name(), "E-BST");
        let mut oa = a.build();
        let ob = b.build();
        oa.observe(1.0, 2.0, 1.0);
        assert_eq!(oa.n_elements(), 1);
        assert_eq!(ob.n_elements(), 0, "builds must stay independent");
    }

    #[test]
    fn factories_build_independent_observers() {
        let lineup = paper_lineup();
        let mut a = lineup[0].build();
        let b = lineup[0].build();
        a.observe(1.0, 2.0, 1.0);
        assert_eq!(a.n_elements(), 1);
        assert_eq!(b.n_elements(), 0);
    }

    #[test]
    fn all_observers_agree_on_step_function() {
        // y = -1 for x <= 0, +1 for x > 0: every AO must find a split
        // near 0 with merit close to the full variance.
        let crit = VarianceReduction;
        for fac in paper_lineup() {
            let mut ao = fac.build();
            let mut rng = crate::common::Rng::new(99);
            for _ in 0..2000 {
                let x = rng.uniform(-1.0, 1.0);
                let y = if x <= 0.0 { -1.0 } else { 1.0 };
                ao.observe(x, y, 1.0);
            }
            let s = ao.best_split(&crit).unwrap_or_else(|| panic!("{} no split", fac.name()));
            assert!(
                s.threshold.abs() < 0.05,
                "{}: threshold {}",
                fac.name(),
                s.threshold
            );
            let total = ao.total();
            assert!(
                s.merit > 0.9 * total.variance(),
                "{}: merit {} vs var {}",
                fac.name(),
                s.merit,
                total.variance()
            );
        }
    }
}
