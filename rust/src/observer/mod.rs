//! Attribute Observers (AOs): the structures an online regression tree
//! keeps per numerical feature per leaf to monitor the stream and answer
//! split-candidate queries.
//!
//! * [`QuantizationObserver`] — the paper's contribution (Sec. 4): O(1)
//!   hashed insertion, O(|H| log |H|) query, |H| ≪ n memory.
//! * [`EBst`] — the classical Extended Binary Search Tree baseline
//!   (Ikonomovska et al. 2011): O(log n) insertion, O(n) memory/query.
//! * [`TruncatedEBst`] — E-BST over inputs truncated to `d` decimal places
//!   (the paper's TE-BST baseline).
//! * [`ExhaustiveObserver`] — stores the raw sample and evaluates every
//!   boundary; the test oracle.
//!
//! All observers use the robust [`VarStats`] estimators
//! (the paper replaces the naive Σy² statistics in *all*
//! compared AOs, Sec. 3).

pub mod ebst;
pub mod exhaustive;
pub mod multi_target;
pub mod qo;
pub mod radius;

pub use ebst::{EBst, TruncatedEBst};
pub use exhaustive::ExhaustiveObserver;
pub use multi_target::MultiTargetQuantizationObserver;
pub use qo::QuantizationObserver;
pub use radius::RadiusPolicy;

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::criterion::SplitCriterion;
use crate::persist::codec::{field, pstr};
use crate::stats::VarStats;

/// A proposed binary split `x ≤ threshold` with its merit and the target
/// statistics of the two branches.
#[derive(Clone, Copy, Debug)]
pub struct SplitSuggestion {
    pub threshold: f64,
    pub merit: f64,
    pub left: VarStats,
    pub right: VarStats,
}

/// The interface the tree (and the bench harness) programs against.
///
/// `Send + Sync` because whole models — leaves, observers and all — are
/// shared immutably across serving threads as `Arc` snapshots
/// ([`crate::serve`]); every built-in observer is plain data, so the
/// bound is free.
pub trait AttributeObserver: Send + Sync {
    /// Monitor one observation of the feature with target `y`, weight `w`.
    fn observe(&mut self, x: f64, y: f64, w: f64);

    /// Best split candidate under `criterion`, or `None` if fewer than two
    /// distinct partitions have been observed.
    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion>;

    /// Number of stored elements (paper's memory metric: BST nodes or hash
    /// slots — all elements store the same statistics, Sec. 5.3).
    fn n_elements(&self) -> usize;

    /// Observer name for reports.
    fn name(&self) -> String;

    /// Resident heap footprint in bytes (capacity-based, so it reflects
    /// allocations, not just live elements). The default `0` keeps custom
    /// observers compiling; built-in observers override it so
    /// [`crate::obs`]'s `model_mem_bytes` gauge and the `stats` response
    /// can report real model size.
    fn mem_bytes(&self) -> usize {
        0
    }

    /// Total target statistics seen by this observer.
    fn total(&self) -> VarStats;

    /// Forget everything (leaf reuse after a split).
    fn reset(&mut self);

    /// Downcast hook for batched split backends
    /// ([`crate::runtime::backend`]): Quantization Observers expose
    /// themselves so a backend can pack their slot tables; every other
    /// observer stays opaque and is answered per-observer.
    fn as_qo(&self) -> Option<&QuantizationObserver> {
        None
    }

    /// Mutable counterpart of [`AttributeObserver::as_qo`], used by the
    /// memory-governance pass ([`crate::govern`]) to compact QO slot
    /// tables in place. Non-QO observers stay opaque (and ungoverned —
    /// their memory is bounded only by eviction).
    fn as_qo_mut(&mut self) -> Option<&mut QuantizationObserver> {
        None
    }

    /// Serialize the observer's complete state for checkpointing
    /// ([`crate::persist`]); [`observer_from_json`] decodes the tagged
    /// layout. The default returns `Json::Null`, which the model codec
    /// rejects at save time — custom observer implementations opt in by
    /// overriding this (and teaching [`observer_from_json`] their tag).
    fn to_json(&self) -> Json {
        Json::Null
    }

    /// Clone this observer into a fresh box. Structural-sharing snapshots
    /// ([`crate::serve`]) keep leaves behind `Arc` and copy-on-write the
    /// touched ones — which deep-clones the leaf's observers through this
    /// hook. Built-in observers are plain data, so their impls are a
    /// one-line `Box::new(self.clone())`.
    fn clone_box(&self) -> Box<dyn AttributeObserver>;
}

/// Boxed observers clone through [`AttributeObserver::clone_box`], which
/// is what lets [`crate::tree::leaf::LeafState`] derive `Clone` for the
/// copy-on-write snapshot path.
impl Clone for Box<dyn AttributeObserver> {
    fn clone(&self) -> Box<dyn AttributeObserver> {
        self.clone_box()
    }
}

/// Decode any built-in observer from its [`AttributeObserver::to_json`]
/// encoding (dispatch on the `"type"` tag).
pub fn observer_from_json(j: &Json) -> Result<Box<dyn AttributeObserver>> {
    match pstr(field(j, "type")?, "type")? {
        "qo" => Ok(Box::new(QuantizationObserver::from_json(j)?)),
        "ebst" => Ok(Box::new(EBst::from_json(j)?)),
        "tebst" => Ok(Box::new(TruncatedEBst::from_json(j)?)),
        "exhaustive" => Ok(Box::new(ExhaustiveObserver::from_json(j)?)),
        other => Err(anyhow!("unknown observer type {other:?}")),
    }
}

/// Factory for building one observer per feature (tree leaves need
/// independently-owned instances).
pub trait ObserverFactory: Send + Sync {
    fn build(&self) -> Box<dyn AttributeObserver>;
    fn name(&self) -> String;
}

/// Blanket factory from a closure.
pub struct FnObserverFactory<F: Fn() -> Box<dyn AttributeObserver> + Send + Sync> {
    pub f: F,
    pub label: String,
}

impl<F: Fn() -> Box<dyn AttributeObserver> + Send + Sync> ObserverFactory
    for FnObserverFactory<F>
{
    fn build(&self) -> Box<dyn AttributeObserver> {
        (self.f)()
    }
    fn name(&self) -> String {
        self.label.clone()
    }
}

/// Convenience constructor for boxed closure factories.
pub fn factory<F>(label: &str, f: F) -> Box<dyn ObserverFactory>
where
    F: Fn() -> Box<dyn AttributeObserver> + Send + Sync + 'static,
{
    Box::new(FnObserverFactory { f, label: label.to_string() })
}

/// A factory view over shared configuration: ensembles hold one
/// `Arc<dyn ObserverFactory>` and hand every member tree (and every
/// background tree spawned later) its own boxed [`ArcFactory`] clone.
pub struct ArcFactory(std::sync::Arc<dyn ObserverFactory>);

impl ArcFactory {
    pub fn new(shared: std::sync::Arc<dyn ObserverFactory>) -> ArcFactory {
        ArcFactory(shared)
    }
}

impl ObserverFactory for ArcFactory {
    fn build(&self) -> Box<dyn AttributeObserver> {
        self.0.build()
    }

    fn name(&self) -> String {
        self.0.name()
    }
}

/// A *serializable* description of an observer configuration — the part a
/// checkpoint must carry so a restored tree can build observers for leaves
/// it grows **after** loading ([`crate::persist`]). Every factory the repo
/// ships maps to a spec through its label ([`ObserverSpec::from_label`]);
/// custom closure factories with other labels are not checkpointable.
///
/// Limitation: the label does not carry a custom `StdFraction` warmup, so
/// a restored factory uses the default (100). Observers that already
/// exist in the tree are unaffected — their full radius state travels in
/// the checkpoint — only leaves created after the restore see it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObserverSpec {
    EBst,
    TruncatedEBst(u32),
    Exhaustive,
    Qo(RadiusPolicy),
}

impl ObserverSpec {
    /// Parse a factory label (`"E-BST"`, `"TE-BST_3"`, `"Exhaustive"`,
    /// `"QO_0.01"`, `"QO_s2"`) back into a spec. The bare `"TE-BST"` of
    /// [`paper_lineup`] maps to the paper's 3-decimal configuration.
    pub fn from_label(label: &str) -> Option<ObserverSpec> {
        match label {
            "E-BST" => Some(ObserverSpec::EBst),
            "TE-BST" => Some(ObserverSpec::TruncatedEBst(3)),
            "Exhaustive" => Some(ObserverSpec::Exhaustive),
            _ => {
                if let Some(d) = label.strip_prefix("TE-BST_") {
                    return d.parse().ok().map(ObserverSpec::TruncatedEBst);
                }
                if let Some(k) = label.strip_prefix("QO_s") {
                    return k
                        .parse::<f64>()
                        .ok()
                        .filter(|k| *k > 0.0)
                        .map(|k| ObserverSpec::Qo(RadiusPolicy::std_fraction(k)));
                }
                if let Some(r) = label.strip_prefix("QO_") {
                    return r
                        .parse::<f64>()
                        .ok()
                        .filter(|r| *r > 0.0)
                        .map(|r| ObserverSpec::Qo(RadiusPolicy::Fixed(r)));
                }
                None
            }
        }
    }

    /// The label this spec round-trips through (equals the name of the
    /// factory [`ObserverSpec::to_factory`] builds).
    pub fn label(&self) -> String {
        match self {
            ObserverSpec::EBst => "E-BST".to_string(),
            ObserverSpec::TruncatedEBst(d) => format!("TE-BST_{d}"),
            ObserverSpec::Exhaustive => "Exhaustive".to_string(),
            ObserverSpec::Qo(policy) => policy.label(),
        }
    }

    /// Build the factory this spec describes.
    pub fn to_factory(&self) -> Box<dyn ObserverFactory> {
        match *self {
            ObserverSpec::EBst => factory("E-BST", || Box::new(EBst::new())),
            ObserverSpec::TruncatedEBst(d) => {
                factory(&format!("TE-BST_{d}"), move || Box::new(TruncatedEBst::new(d)))
            }
            ObserverSpec::Exhaustive => {
                factory("Exhaustive", || Box::new(ExhaustiveObserver::new()))
            }
            ObserverSpec::Qo(policy) => {
                factory(&policy.label(), move || {
                    Box::new(QuantizationObserver::new(policy))
                })
            }
        }
    }
}

/// The paper's five compared observer configurations (Sec. 5.2).
pub fn paper_lineup() -> Vec<Box<dyn ObserverFactory>> {
    vec![
        factory("E-BST", || Box::new(EBst::new())),
        factory("TE-BST", || Box::new(TruncatedEBst::new(3))),
        factory("QO_0.01", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::Fixed(0.01)))
        }),
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        }),
        factory("QO_s3", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(3.0)))
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criterion::VarianceReduction;

    #[test]
    fn paper_lineup_names() {
        let names: Vec<String> = paper_lineup().iter().map(|f| f.name()).collect();
        assert_eq!(names, vec!["E-BST", "TE-BST", "QO_0.01", "QO_s2", "QO_s3"]);
    }

    #[test]
    fn arc_factory_forwards_to_shared() {
        let shared: std::sync::Arc<dyn ObserverFactory> =
            std::sync::Arc::from(factory("E-BST", || Box::new(EBst::new())));
        let a = ArcFactory::new(shared.clone());
        let b = ArcFactory::new(shared);
        assert_eq!(a.name(), "E-BST");
        let mut oa = a.build();
        let ob = b.build();
        oa.observe(1.0, 2.0, 1.0);
        assert_eq!(oa.n_elements(), 1);
        assert_eq!(ob.n_elements(), 0, "builds must stay independent");
    }

    #[test]
    fn factories_build_independent_observers() {
        let lineup = paper_lineup();
        let mut a = lineup[0].build();
        let b = lineup[0].build();
        a.observe(1.0, 2.0, 1.0);
        assert_eq!(a.n_elements(), 1);
        assert_eq!(b.n_elements(), 0);
    }

    #[test]
    fn observer_spec_roundtrips_every_paper_label() {
        for fac in paper_lineup() {
            let label = fac.name();
            let spec = ObserverSpec::from_label(&label)
                .unwrap_or_else(|| panic!("unparseable label {label:?}"));
            // the spec's own label is the canonical fixpoint (the bare
            // "TE-BST" paper label canonicalizes to "TE-BST_3")
            assert_eq!(ObserverSpec::from_label(&spec.label()), Some(spec));
            let rebuilt = spec.to_factory();
            assert_eq!(rebuilt.name(), spec.label());
            // the rebuilt factory produces a working observer of that kind
            let mut ao = rebuilt.build();
            ao.observe(1.0, 2.0, 1.0);
            assert_eq!(ao.total().n, 1.0);
        }
        assert_eq!(ObserverSpec::from_label("TE-BST"), Some(ObserverSpec::TruncatedEBst(3)));
        assert_eq!(ObserverSpec::from_label("Exhaustive"), Some(ObserverSpec::Exhaustive));
        assert_eq!(ObserverSpec::from_label("nope"), None);
        assert_eq!(ObserverSpec::from_label("QO_-1"), None);
        assert_eq!(ObserverSpec::from_label("QO_snope"), None);
    }

    #[test]
    fn observer_from_json_rejects_unknown_tags() {
        let mut j = Json::obj();
        j.set("type", "martian");
        assert!(observer_from_json(&j).is_err());
        assert!(observer_from_json(&Json::Null).is_err());
    }

    #[test]
    fn all_observers_agree_on_step_function() {
        // y = -1 for x <= 0, +1 for x > 0: every AO must find a split
        // near 0 with merit close to the full variance.
        let crit = VarianceReduction;
        for fac in paper_lineup() {
            let mut ao = fac.build();
            let mut rng = crate::common::Rng::new(99);
            for _ in 0..2000 {
                let x = rng.uniform(-1.0, 1.0);
                let y = if x <= 0.0 { -1.0 } else { 1.0 };
                ao.observe(x, y, 1.0);
            }
            let s = ao.best_split(&crit).unwrap_or_else(|| panic!("{} no split", fac.name()));
            assert!(
                s.threshold.abs() < 0.05,
                "{}: threshold {}",
                fac.name(),
                s.threshold
            );
            let total = ao.total();
            assert!(
                s.merit > 0.9 * total.variance(),
                "{}: merit {} vs var {}",
                fac.name(),
                s.merit,
                total.variance()
            );
        }
    }
}
