//! Quantization-radius policies for the QO (paper Sec. 5.2).
//!
//! * `Fixed(r)` — the cold-start choice (the paper's QO_0.01);
//! * `StdFraction { k, warmup }` — the dynamical choice: r = σ̂ / k, where
//!   σ̂ is the running standard deviation of the *feature*. The paper notes
//!   the full-sample σ is not available online, so the radius is frozen
//!   from the running estimate once `warmup` observations have been
//!   buffered (the buffered points are then re-inserted through the hash).

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::persist::codec::{
    field, jf64, jusize, parr, pf64, pusize, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

/// How the QO picks its quantization radius.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RadiusPolicy {
    /// Constant radius from the start.
    Fixed(f64),
    /// r = σ̂(feature) / k, frozen after `warmup` observations.
    StdFraction { k: f64, warmup: usize },
}

impl RadiusPolicy {
    /// The paper's dynamical variants with the default warmup (100).
    pub fn std_fraction(k: f64) -> RadiusPolicy {
        RadiusPolicy::StdFraction { k, warmup: 100 }
    }

    /// Human-readable label matching the paper's notation.
    pub fn label(&self) -> String {
        match self {
            RadiusPolicy::Fixed(r) => format!("QO_{r}"),
            RadiusPolicy::StdFraction { k, .. } => format!("QO_s{k}"),
        }
    }

    /// Checkpoint encoding ([`crate::persist`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            RadiusPolicy::Fixed(r) => {
                o.set("fixed", jf64(*r));
            }
            RadiusPolicy::StdFraction { k, warmup } => {
                let mut inner = Json::obj();
                inner.set("k", jf64(*k)).set("warmup", jusize(*warmup));
                o.set("std", inner);
            }
        }
        o
    }

    /// Decode a policy written by [`RadiusPolicy::to_json`].
    pub fn from_json(j: &Json) -> Result<RadiusPolicy> {
        if let Some(r) = j.get("fixed") {
            return Ok(RadiusPolicy::Fixed(pf64(r, "fixed")?));
        }
        if let Some(inner) = j.get("std") {
            return Ok(RadiusPolicy::StdFraction {
                k: pf64(field(inner, "k")?, "k")?,
                warmup: pusize(field(inner, "warmup")?, "warmup")?,
            });
        }
        Err(anyhow!("radius policy: expected \"fixed\" or \"std\""))
    }
}

/// Runtime state of the radius decision.
#[derive(Clone, Debug)]
pub enum RadiusState {
    /// Radius decided; quantization active.
    Frozen(f64),
    /// Still warming up: buffering raw observations and tracking feature
    /// dispersion.
    Warming { k: f64, warmup: usize, feature_stats: VarStats, buffer: Vec<(f64, f64, f64)> },
}

impl RadiusState {
    pub fn new(policy: RadiusPolicy) -> RadiusState {
        match policy {
            RadiusPolicy::Fixed(r) => {
                assert!(r > 0.0, "radius must be positive");
                RadiusState::Frozen(r)
            }
            RadiusPolicy::StdFraction { k, warmup } => {
                assert!(k > 0.0 && warmup >= 2);
                RadiusState::Warming {
                    k,
                    warmup,
                    feature_stats: VarStats::new(),
                    buffer: Vec::with_capacity(warmup),
                }
            }
        }
    }

    /// Feed one observation. Returns `Some(radius, buffered)` at the
    /// freeze transition: the caller must then insert the returned buffer
    /// through the hash. Afterwards (and for `Fixed`), returns `None` and
    /// the caller should hash the observation directly via [`Self::radius`].
    pub fn on_observe(&mut self, x: f64, y: f64, w: f64) -> Option<(f64, Vec<(f64, f64, f64)>)> {
        match self {
            RadiusState::Frozen(_) => None,
            RadiusState::Warming { k, warmup, feature_stats, buffer } => {
                feature_stats.update(x, w);
                buffer.push((x, y, w));
                if buffer.len() >= *warmup {
                    let std = feature_stats.std();
                    // Degenerate feature (all equal so far): fall back to a
                    // small absolute radius, mirroring the paper's fixed
                    // cold-start value.
                    let radius = if std > 0.0 { std / *k } else { 0.01 };
                    let drained = std::mem::take(buffer);
                    *self = RadiusState::Frozen(radius);
                    Some((radius, drained))
                } else {
                    None
                }
            }
        }
    }

    /// Current radius if frozen.
    pub fn radius(&self) -> Option<f64> {
        match self {
            RadiusState::Frozen(r) => Some(*r),
            RadiusState::Warming { .. } => None,
        }
    }

    /// Observations currently buffered (warming phase).
    pub fn buffered(&self) -> usize {
        match self {
            RadiusState::Frozen(_) => 0,
            RadiusState::Warming { buffer, .. } => buffer.len(),
        }
    }

    /// Checkpoint encoding ([`crate::persist`]): the frozen radius, or the
    /// complete warming snapshot (dispersion stats + raw buffer) so a
    /// restored observer freezes at exactly the same radius the live one
    /// would have.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            RadiusState::Frozen(r) => {
                o.set("frozen", jf64(*r));
            }
            RadiusState::Warming { k, warmup, feature_stats, buffer } => {
                let mut inner = Json::obj();
                inner
                    .set("k", jf64(*k))
                    .set("warmup", jusize(*warmup))
                    .set("feature_stats", varstats_to_json(feature_stats))
                    .set(
                        "buffer",
                        Json::Arr(
                            buffer
                                .iter()
                                .map(|&(x, y, w)| {
                                    Json::Arr(vec![jf64(x), jf64(y), jf64(w)])
                                })
                                .collect(),
                        ),
                    );
                o.set("warming", inner);
            }
        }
        o
    }

    /// Decode a state written by [`RadiusState::to_json`].
    pub fn from_json(j: &Json) -> Result<RadiusState> {
        if let Some(r) = j.get("frozen") {
            let r = pf64(r, "frozen")?;
            if !(r.is_finite() && r > 0.0) {
                return Err(anyhow!("frozen radius must be positive, got {r}"));
            }
            return Ok(RadiusState::Frozen(r));
        }
        if let Some(inner) = j.get("warming") {
            let mut buffer = Vec::new();
            for item in parr(field(inner, "buffer")?, "buffer")? {
                let triple = parr(item, "buffer")?;
                if triple.len() != 3 {
                    return Err(anyhow!("warming buffer: expected [x, y, w]"));
                }
                buffer.push((
                    pf64(&triple[0], "buffer.x")?,
                    pf64(&triple[1], "buffer.y")?,
                    pf64(&triple[2], "buffer.w")?,
                ));
            }
            return Ok(RadiusState::Warming {
                k: pf64(field(inner, "k")?, "k")?,
                warmup: pusize(field(inner, "warmup")?, "warmup")?,
                feature_stats: varstats_from(
                    field(inner, "feature_stats")?,
                    "feature_stats",
                )?,
                buffer,
            });
        }
        Err(anyhow!("radius state: expected \"frozen\" or \"warming\""))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_immediately_frozen() {
        let mut st = RadiusState::new(RadiusPolicy::Fixed(0.25));
        assert_eq!(st.radius(), Some(0.25));
        assert!(st.on_observe(1.0, 2.0, 1.0).is_none());
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_radius_rejected() {
        RadiusState::new(RadiusPolicy::Fixed(0.0));
    }

    #[test]
    fn std_fraction_freezes_after_warmup() {
        let mut st = RadiusState::new(RadiusPolicy::StdFraction { k: 2.0, warmup: 10 });
        let mut rng = crate::common::Rng::new(1);
        let mut frozen = None;
        for i in 0..10 {
            let x = rng.normal(0.0, 4.0);
            let out = st.on_observe(x, 0.0, 1.0);
            if i < 9 {
                assert!(out.is_none());
                assert_eq!(st.buffered(), i + 1);
            } else {
                frozen = out;
            }
        }
        let (radius, buffer) = frozen.expect("should freeze at warmup");
        assert_eq!(buffer.len(), 10);
        // σ of N(0,4) sample / 2 — loose check that it's in a sane band
        assert!(radius > 0.5 && radius < 5.0, "radius={radius}");
        assert_eq!(st.radius(), Some(radius));
    }

    #[test]
    fn degenerate_feature_falls_back() {
        let mut st = RadiusState::new(RadiusPolicy::StdFraction { k: 3.0, warmup: 5 });
        let mut out = None;
        for _ in 0..5 {
            out = st.on_observe(7.0, 1.0, 1.0).or(out);
        }
        let (radius, _) = out.unwrap();
        assert_eq!(radius, 0.01);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(RadiusPolicy::Fixed(0.01).label(), "QO_0.01");
        assert_eq!(RadiusPolicy::std_fraction(2.0).label(), "QO_s2");
    }

    #[test]
    fn json_roundtrip_mid_warmup_freezes_identically() {
        use crate::common::json::Json;
        let mut live = RadiusState::new(RadiusPolicy::StdFraction { k: 2.0, warmup: 20 });
        let mut rng = crate::common::Rng::new(31);
        let points: Vec<(f64, f64)> =
            (0..20).map(|_| (rng.normal(0.0, 3.0), rng.f64())).collect();
        for &(x, y) in &points[..10] {
            assert!(live.on_observe(x, y, 1.0).is_none());
        }
        // snapshot mid-warmup, then feed both copies the same tail
        let text = live.to_json().to_compact();
        let mut restored = RadiusState::from_json(&Json::parse(&text).unwrap()).unwrap();
        let (mut frozen_live, mut frozen_restored) = (None, None);
        for &(x, y) in &points[10..] {
            frozen_live = live.on_observe(x, y, 1.0).or(frozen_live);
            frozen_restored = restored.on_observe(x, y, 1.0).or(frozen_restored);
        }
        let (ra, ba) = frozen_live.expect("live must freeze");
        let (rb, bb) = frozen_restored.expect("restored must freeze");
        assert_eq!(ra.to_bits(), rb.to_bits());
        assert_eq!(ba.len(), bb.len());
        for (p, q) in ba.iter().zip(&bb) {
            assert_eq!(p.0.to_bits(), q.0.to_bits());
        }

        // frozen states round-trip too
        let frozen = RadiusState::Frozen(0.125);
        let back = RadiusState::from_json(
            &Json::parse(&frozen.to_json().to_compact()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.radius(), Some(0.125));
    }

    #[test]
    fn policy_json_roundtrip() {
        use crate::common::json::Json;
        for policy in [
            RadiusPolicy::Fixed(0.01),
            RadiusPolicy::StdFraction { k: 3.0, warmup: 50 },
        ] {
            let back = RadiusPolicy::from_json(
                &Json::parse(&policy.to_json().to_compact()).unwrap(),
            )
            .unwrap();
            assert_eq!(back, policy);
        }
    }
}
