//! The Quantization Observer (QO) — the paper's contribution (Sec. 4).
//!
//! A single hash table `H` maps bucket code `h = ⌊x / r⌋` to a slot holding
//! the sum of the feature values and a robust target estimator
//! ([`VarStats`]). Insertion is O(1) amortized (paper Alg. 1); the split
//! query (paper Alg. 2) sorts the |H| occupied codes, prefix-merges the
//! target statistics left-to-right, recovers right-hand statistics by the
//! Chan subtraction, and proposes the midpoint of consecutive slot
//! *prototypes* (mean feature value per slot) as the candidate threshold.
//!
//! Dynamical radii (r = σ̂/k) warm up on a small buffered prefix of the
//! stream before freezing — see [`RadiusPolicy`].

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::common::fxhash::FxBuildHasher;
use crate::common::json::Json;

use crate::criterion::SplitCriterion;
use crate::persist::codec::{
    field, jf64, ji64, parr, pf64, pi64, pstr, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

use super::radius::{RadiusPolicy, RadiusState};
use super::{AttributeObserver, SplitSuggestion};

/// One hash slot: Σx (for the prototype) + robust target statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Slot {
    pub sum_x: f64,
    pub stats: VarStats,
}

impl Slot {
    #[inline]
    fn observe(&mut self, x: f64, y: f64, w: f64) {
        self.sum_x += w * x;
        self.stats.update(y, w);
    }

    /// Prototype feature value: the mean x of the slot's members.
    #[inline]
    pub fn prototype(&self) -> f64 {
        if self.stats.n > 0.0 {
            self.sum_x / self.stats.n
        } else {
            0.0
        }
    }
}

/// How the threshold between two consecutive occupied slots is chosen.
/// The paper (Sec. 4) uses prototype midpoints and notes that "other
/// strategies could also be employed" — the grid-boundary alternative is
/// provided for the ablation bench (it needs no Σx bookkeeping at all).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SplitPointStrategy {
    /// Midpoint of the two slots' mean feature values (paper Alg. 2).
    #[default]
    PrototypeMidpoint,
    /// The quantization-grid edge after the left slot: (code+1)·r.
    GridBoundary,
}

/// The Quantization Observer (paper Sec. 4).
#[derive(Clone, Debug)]
pub struct QuantizationObserver {
    policy: RadiusPolicy,
    state: RadiusState,
    slots: HashMap<i64, Slot, FxBuildHasher>,
    total: VarStats,
    strategy: SplitPointStrategy,
}

impl QuantizationObserver {
    pub fn new(policy: RadiusPolicy) -> QuantizationObserver {
        QuantizationObserver {
            policy,
            state: RadiusState::new(policy),
            slots: HashMap::default(),
            total: VarStats::new(),
            strategy: SplitPointStrategy::default(),
        }
    }

    /// Select a split-point strategy (default: prototype midpoints).
    pub fn with_strategy(mut self, strategy: SplitPointStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// The configured split-point strategy (batched backends replicate it).
    pub fn strategy(&self) -> SplitPointStrategy {
        self.strategy
    }

    /// Fixed-radius constructor (paper's QO_0.01 uses `r = 0.01`).
    pub fn with_radius(r: f64) -> QuantizationObserver {
        QuantizationObserver::new(RadiusPolicy::Fixed(r))
    }

    /// The quantization radius, once decided.
    pub fn radius(&self) -> Option<f64> {
        self.state.radius()
    }

    /// Bucket code for `x` under radius `r` (paper: h = ⌊x/r⌋), saturating
    /// at the i64 range so extreme `x/r` ratios cannot wrap.
    #[inline]
    pub fn code(x: f64, r: f64) -> i64 {
        let q = (x / r).floor();
        if q >= i64::MAX as f64 {
            i64::MAX
        } else if q <= i64::MIN as f64 {
            i64::MIN
        } else {
            q as i64
        }
    }

    #[inline]
    fn insert_hashed(&mut self, r: f64, x: f64, y: f64, w: f64) {
        self.slots.entry(Self::code(x, r)).or_default().observe(x, y, w);
        if let Some(m) = crate::obs::m() {
            m.qo_inserts.inc();
        }
    }

    /// Merge a pre-aggregated slot into the hash. Used by the bulk XLA
    /// ingest path and by the sharded coordinator when combining partial
    /// observers — correctness rests on the Chan merge (paper Eqs. 4–5).
    ///
    /// Panics if the radius is still warming (bulk merges only make sense
    /// once the quantization grid is fixed).
    pub fn absorb_slot(&mut self, code: i64, sum_x: f64, stats: VarStats) {
        assert!(
            self.state.radius().is_some(),
            "absorb_slot requires a frozen radius (use RadiusPolicy::Fixed)"
        );
        if stats.is_empty() {
            return;
        }
        let slot = self.slots.entry(code).or_default();
        slot.sum_x += sum_x;
        slot.stats += stats;
        self.total += stats;
    }

    /// Merge everything another observer has seen into this one. Both
    /// observers must use the same frozen radius (same grid), otherwise
    /// bucket codes are incompatible.
    pub fn merge_from(&mut self, other: &QuantizationObserver) {
        let (ra, rb) = (self.state.radius(), other.state.radius());
        match (ra, rb) {
            (Some(ra), Some(rb)) => {
                assert!(
                    (ra - rb).abs() <= 1e-12 * ra.abs().max(rb.abs()),
                    "radius mismatch: {ra} vs {rb}"
                );
            }
            _ => panic!("merge_from requires both radii frozen"),
        }
        for (&code, slot) in &other.slots {
            self.absorb_slot(code, slot.sum_x, slot.stats);
        }
    }

    /// Occupied slots sorted by bucket code — the query-side view, also
    /// used to feed the XLA split engine (`runtime::split_engine`).
    pub fn sorted_slots(&self) -> Vec<(i64, Slot)> {
        let mut items: Vec<(i64, Slot)> = self.slots.iter().map(|(&k, &s)| (k, s)).collect();
        items.sort_unstable_by_key(|&(k, _)| k);
        items
    }

    /// Compact the slot table down to at most `target_slots` occupied
    /// slots by merging adjacent (code-ordered) slot pairs — the memory
    /// governor's step (a) ([`crate::govern`]).
    ///
    /// The merge is *exact* in the paper's sense (Sec. 3): per-slot
    /// [`VarStats`] are mergeable, so a merged slot carries precisely the
    /// statistics both originals held and every surviving split boundary
    /// proposes the same left/right candidate stats the prefix-merge in
    /// [`AttributeObserver::best_split`] would have accumulated across
    /// the originals. What is lost is *resolution*: boundaries interior
    /// to a merged pair can no longer be proposed. The merged slot keeps
    /// the left slot's bucket code, so codes stay strictly increasing
    /// (`QO_SLOT_ORDER`) and `total` is untouched (`QO_TOTAL_DRIFT`).
    ///
    /// The table is rebuilt with exact capacity so [`mem_bytes`]
    /// actually shrinks. No-op while the radius is still warming (the
    /// buffer, not the hash, holds the state) or when already at or
    /// under the target. Returns the number of slots merged away.
    ///
    /// [`mem_bytes`]: AttributeObserver::mem_bytes
    pub fn compact(&mut self, target_slots: usize) -> usize {
        if self.state.radius().is_none() {
            return 0;
        }
        let target = target_slots.max(2);
        if self.slots.len() <= target {
            return 0;
        }
        let mut items = self.sorted_slots();
        let before = items.len();
        while items.len() > target {
            let mut merged: Vec<(i64, Slot)> = Vec::with_capacity(items.len().div_ceil(2));
            let mut it = items.into_iter();
            while let Some((code, mut slot)) = it.next() {
                if let Some((_, right)) = it.next() {
                    slot.sum_x += right.sum_x;
                    slot.stats += right.stats;
                }
                merged.push((code, slot));
            }
            items = merged;
        }
        self.slots = HashMap::with_capacity_and_hasher(items.len(), FxBuildHasher::default());
        self.slots.extend(items);
        before - self.slots.len()
    }

    /// Decode an observer written by [`AttributeObserver::to_json`]
    /// (checkpointing; see [`crate::persist`]). The restored observer is
    /// state-identical: same radius state (frozen or mid-warmup), same
    /// slot statistics, same totals and strategy.
    pub fn from_json(j: &Json) -> Result<QuantizationObserver> {
        let policy = RadiusPolicy::from_json(field(j, "policy")?)?;
        let state = RadiusState::from_json(field(j, "state")?)?;
        let strategy = match pstr(field(j, "strategy")?, "strategy")? {
            "prototype" => SplitPointStrategy::PrototypeMidpoint,
            "grid" => SplitPointStrategy::GridBoundary,
            other => return Err(anyhow!("unknown split-point strategy {other:?}")),
        };
        let mut slots: HashMap<i64, Slot, FxBuildHasher> = HashMap::default();
        for item in parr(field(j, "slots")?, "slots")? {
            let entry = parr(item, "slots")?;
            if entry.len() != 3 {
                return Err(anyhow!("slot: expected [code, sum_x, stats]"));
            }
            let code = pi64(&entry[0], "slot.code")?;
            let slot = Slot {
                sum_x: pf64(&entry[1], "slot.sum_x")?,
                stats: varstats_from(&entry[2], "slot.stats")?,
            };
            if slots.insert(code, slot).is_some() {
                return Err(anyhow!("duplicate slot code {code}"));
            }
        }
        Ok(QuantizationObserver {
            policy,
            state,
            slots,
            total: varstats_from(field(j, "total")?, "total")?,
            strategy,
        })
    }

    /// Split query over the warming buffer (before the radius freezes):
    /// exhaustive sweep over the few buffered raw points so trees can
    /// still attempt early splits.
    fn best_split_buffered(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        let buffer = match &self.state {
            RadiusState::Warming { buffer, .. } => buffer,
            RadiusState::Frozen(_) => return None,
        };
        let mut pts: Vec<(f64, f64, f64)> = buffer.clone();
        if pts.len() < 2 {
            return None;
        }
        pts.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut left = VarStats::new();
        let mut best: Option<SplitSuggestion> = None;
        for i in 0..pts.len() - 1 {
            let (x, y, w) = pts[i];
            left.update(y, w);
            let (x_next, _, _) = pts[i + 1];
            if x_next <= x {
                continue; // duplicate feature value: no boundary here
            }
            let right = self.total - left;
            let merit = criterion.merit(&self.total, &left, &right);
            if best.map(|b| merit > b.merit).unwrap_or(true) {
                best = Some(SplitSuggestion {
                    threshold: 0.5 * (x + x_next),
                    merit,
                    left,
                    right,
                });
            }
        }
        best
    }
}

impl AttributeObserver for QuantizationObserver {
    fn observe(&mut self, x: f64, y: f64, w: f64) {
        if w <= 0.0 || !x.is_finite() || !y.is_finite() {
            return;
        }
        self.total.update(y, w);
        match self.state.radius() {
            Some(r) => self.insert_hashed(r, x, y, w),
            None => {
                if let Some((r, buffered)) = self.state.on_observe(x, y, w) {
                    // radius froze: replay the warmup buffer into the hash
                    for (bx, by, bw) in buffered {
                        self.insert_hashed(r, bx, by, bw);
                    }
                }
            }
        }
    }

    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        if self.state.radius().is_none() {
            return self.best_split_buffered(criterion);
        }
        let slots = self.sorted_slots();
        if let Some(m) = crate::obs::m() {
            m.qo_slots_occupied.record(slots.len() as u64);
        }
        if slots.len() < 2 {
            return None;
        }
        // paper Alg. 2: prefix-merge left-to-right, subtract for the right
        let radius = self.state.radius().unwrap_or(1.0);
        let mut left = VarStats::new();
        let mut best: Option<SplitSuggestion> = None;
        for window in slots.windows(2) {
            let (code, slot) = window[0];
            let (_, next) = window[1];
            left += slot.stats;
            let right = self.total - left;
            let merit = criterion.merit(&self.total, &left, &right);
            if best.map(|b| merit > b.merit).unwrap_or(true) {
                let threshold = match self.strategy {
                    SplitPointStrategy::PrototypeMidpoint => {
                        0.5 * (slot.prototype() + next.prototype())
                    }
                    // saturating: `code` itself saturates at the i64 range
                    // for extreme x/r, so plain `code + 1` could overflow
                    // (a panic in debug builds)
                    SplitPointStrategy::GridBoundary => {
                        code.saturating_add(1) as f64 * radius
                    }
                };
                best = Some(SplitSuggestion { threshold, merit, left, right });
            }
        }
        best
    }

    fn n_elements(&self) -> usize {
        if self.slots.is_empty() {
            self.state.buffered()
        } else {
            self.slots.len()
        }
    }

    fn mem_bytes(&self) -> usize {
        // hash table: each bucket holds a (code, Slot) pair plus ~1 byte
        // of control metadata in the std SwissTable layout
        std::mem::size_of::<QuantizationObserver>()
            + self.slots.capacity() * (std::mem::size_of::<(i64, Slot)>() + 1)
            + self.state.buffered() * std::mem::size_of::<(f64, f64, f64)>()
    }

    fn name(&self) -> String {
        self.policy.label()
    }

    fn total(&self) -> VarStats {
        self.total
    }

    fn reset(&mut self) {
        self.state = RadiusState::new(self.policy);
        self.slots.clear();
        self.total = VarStats::new();
        // strategy is configuration, not state: kept across resets
    }

    fn as_qo(&self) -> Option<&QuantizationObserver> {
        Some(self)
    }

    fn as_qo_mut(&mut self) -> Option<&mut QuantizationObserver> {
        Some(self)
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("type", "qo")
            .set("policy", self.policy.to_json())
            .set("state", self.state.to_json())
            .set(
                "strategy",
                match self.strategy {
                    SplitPointStrategy::PrototypeMidpoint => "prototype",
                    SplitPointStrategy::GridBoundary => "grid",
                },
            )
            .set("total", varstats_to_json(&self.total))
            .set(
                "slots",
                Json::Arr(
                    // sorted by code: deterministic checkpoint text
                    self.sorted_slots()
                        .into_iter()
                        .map(|(code, slot)| {
                            Json::Arr(vec![
                                ji64(code),
                                jf64(slot.sum_x),
                                varstats_to_json(&slot.stats),
                            ])
                        })
                        .collect(),
                ),
            );
        o
    }

    fn clone_box(&self) -> Box<dyn AttributeObserver> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::{check, expect_close};
    use crate::common::Rng;
    use crate::criterion::VarianceReduction;
    use crate::observer::ExhaustiveObserver;

    #[test]
    fn code_floor_semantics() {
        assert_eq!(QuantizationObserver::code(0.0, 0.1), 0);
        assert_eq!(QuantizationObserver::code(0.09, 0.1), 0);
        assert_eq!(QuantizationObserver::code(0.10, 0.1), 1);
        assert_eq!(QuantizationObserver::code(-0.01, 0.1), -1);
        assert_eq!(QuantizationObserver::code(-0.1, 0.1), -1);
        assert_eq!(QuantizationObserver::code(1e300, 1e-300), i64::MAX);
        assert_eq!(QuantizationObserver::code(-1e300, 1e-300), i64::MIN);
    }

    #[test]
    fn slot_count_bounded_by_range_over_radius() {
        let mut qo = QuantizationObserver::with_radius(0.1);
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            qo.observe(rng.uniform(-1.0, 1.0), rng.f64(), 1.0);
        }
        // codes in [-10, 9] -> at most 20 slots, and n' << n
        assert!(qo.n_elements() <= 20, "{}", qo.n_elements());
        assert!(qo.n_elements() >= 15);
    }

    #[test]
    fn total_matches_slot_merge() {
        let mut qo = QuantizationObserver::with_radius(0.05);
        let mut rng = Rng::new(6);
        for _ in 0..3000 {
            qo.observe(rng.normal(0.0, 1.0), rng.normal(2.0, 3.0), 1.0);
        }
        let merged = qo
            .sorted_slots()
            .into_iter()
            .fold(VarStats::new(), |acc, (_, s)| acc + s.stats);
        assert!((merged.n - qo.total().n).abs() < 1e-9);
        assert!((merged.mean - qo.total().mean).abs() < 1e-9);
        assert!((merged.m2 - qo.total().m2).abs() / qo.total().m2.max(1.0) < 1e-9);
    }

    #[test]
    fn finds_step_split() {
        let mut qo = QuantizationObserver::with_radius(0.02);
        let mut rng = Rng::new(7);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            let y = if x <= 0.3 { 0.0 } else { 5.0 };
            qo.observe(x, y, 1.0);
        }
        let s = qo.best_split(&VarianceReduction).unwrap();
        assert!((s.threshold - 0.3).abs() < 0.03, "threshold={}", s.threshold);
        assert!(s.left.mean < 0.5 && s.right.mean > 4.5);
    }

    #[test]
    fn dynamic_radius_freezes_and_splits() {
        let mut qo = QuantizationObserver::new(RadiusPolicy::std_fraction(2.0));
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            // still warming: buffered split queries must work
            let x = rng.normal(0.0, 1.0);
            qo.observe(x, if x <= 0.0 { -1.0 } else { 1.0 }, 1.0);
        }
        assert!(qo.radius().is_none());
        assert!(qo.best_split(&VarianceReduction).is_some());
        for _ in 0..5000 {
            let x = rng.normal(0.0, 1.0);
            qo.observe(x, if x <= 0.0 { -1.0 } else { 1.0 }, 1.0);
        }
        let r = qo.radius().expect("frozen after warmup");
        assert!(r > 0.2 && r < 1.0, "r={r}"); // ~sigma/2
        let s = qo.best_split(&VarianceReduction).unwrap();
        assert!(s.threshold.abs() < 0.3, "threshold={}", s.threshold);
    }

    #[test]
    fn smaller_radius_more_slots_better_merit() {
        let mut rng = Rng::new(9);
        let data: Vec<(f64, f64)> = (0..20_000)
            .map(|_| {
                let x = rng.uniform(-1.0, 1.0);
                (x, 3.0 * x + rng.normal(0.0, 0.1))
            })
            .collect();
        let mut merits = Vec::new();
        let mut elements = Vec::new();
        for r in [0.5, 0.1, 0.01] {
            let mut qo = QuantizationObserver::with_radius(r);
            for &(x, y) in &data {
                qo.observe(x, y, 1.0);
            }
            merits.push(qo.best_split(&VarianceReduction).unwrap().merit);
            elements.push(qo.n_elements());
        }
        assert!(elements[0] < elements[1] && elements[1] < elements[2], "{elements:?}");
        assert!(merits[0] <= merits[1] + 1e-9 && merits[1] <= merits[2] + 1e-9, "{merits:?}");
    }

    #[test]
    fn merit_close_to_exhaustive_oracle() {
        // paper Sec. 6.1: QO merit is slightly below but very close to the
        // exhaustive/E-BST merit
        let mut rng = Rng::new(10);
        let mut qo = QuantizationObserver::with_radius(0.01);
        let mut ex = ExhaustiveObserver::new();
        for _ in 0..5000 {
            let x = rng.normal(0.0, 1.0);
            let y = x * x + rng.normal(0.0, 0.1);
            qo.observe(x, y, 1.0);
            ex.observe(x, y, 1.0);
        }
        let mq = qo.best_split(&VarianceReduction).unwrap().merit;
        let me = ex.best_split(&VarianceReduction).unwrap().merit;
        assert!(mq <= me + 1e-9);
        assert!(mq > 0.9 * me, "qo={mq} exhaustive={me}");
    }

    #[test]
    fn reset_clears_everything() {
        let mut qo = QuantizationObserver::with_radius(0.1);
        qo.observe(1.0, 2.0, 1.0);
        qo.reset();
        assert_eq!(qo.n_elements(), 0);
        assert!(qo.total().is_empty());
        assert!(qo.best_split(&VarianceReduction).is_none());
    }

    #[test]
    fn ignores_non_finite_and_zero_weight() {
        let mut qo = QuantizationObserver::with_radius(0.1);
        qo.observe(f64::NAN, 1.0, 1.0);
        qo.observe(1.0, f64::INFINITY, 1.0);
        qo.observe(1.0, 1.0, 0.0);
        assert_eq!(qo.n_elements(), 0);
        assert!(qo.total().is_empty());
    }

    #[test]
    fn merge_from_equals_single_observer() {
        let mut rng = Rng::new(77);
        let data: Vec<(f64, f64)> =
            (0..4000).map(|_| (rng.normal(0.0, 2.0), rng.normal(1.0, 3.0))).collect();
        let mut whole = QuantizationObserver::with_radius(0.2);
        let mut a = QuantizationObserver::with_radius(0.2);
        let mut b = QuantizationObserver::with_radius(0.2);
        for (i, &(x, y)) in data.iter().enumerate() {
            whole.observe(x, y, 1.0);
            if i % 2 == 0 {
                a.observe(x, y, 1.0)
            } else {
                b.observe(x, y, 1.0)
            }
        }
        a.merge_from(&b);
        assert_eq!(a.n_elements(), whole.n_elements());
        let sa = a.best_split(&VarianceReduction).unwrap();
        let sw = whole.best_split(&VarianceReduction).unwrap();
        assert!((sa.threshold - sw.threshold).abs() < 1e-9);
        assert!((sa.merit - sw.merit).abs() < 1e-9);
        assert!((a.total().m2 - whole.total().m2).abs() / whole.total().m2 < 1e-9);
    }

    #[test]
    #[should_panic(expected = "radius mismatch")]
    fn merge_from_rejects_different_radius() {
        let mut a = QuantizationObserver::with_radius(0.1);
        let b = QuantizationObserver::with_radius(0.2);
        a.merge_from(&b);
    }

    #[test]
    fn absorb_slot_matches_observe() {
        let mut direct = QuantizationObserver::with_radius(0.5);
        direct.observe(0.7, 2.0, 1.0);
        direct.observe(0.9, 4.0, 1.0);
        let mut bulk = QuantizationObserver::with_radius(0.5);
        let mut stats = VarStats::new();
        stats.update(2.0, 1.0);
        stats.update(4.0, 1.0);
        bulk.absorb_slot(1, 1.6, stats);
        assert_eq!(bulk.n_elements(), direct.n_elements());
        assert!((bulk.total().mean - direct.total().mean).abs() < 1e-12);
        let (ka, sa) = bulk.sorted_slots()[0];
        let (kb, sb) = direct.sorted_slots()[0];
        assert_eq!(ka, kb);
        assert!((sa.sum_x - sb.sum_x).abs() < 1e-12);
    }

    #[test]
    fn grid_boundary_strategy_close_to_prototype_midpoint() {
        let mut rng = Rng::new(123);
        let data: Vec<(f64, f64)> = (0..20_000)
            .map(|_| {
                let x = rng.uniform(-1.0, 1.0);
                (x, if x <= 0.3 { 0.0 } else { 1.0 })
            })
            .collect();
        let mut proto = QuantizationObserver::with_radius(0.05);
        let mut grid = QuantizationObserver::with_radius(0.05)
            .with_strategy(SplitPointStrategy::GridBoundary);
        for &(x, y) in &data {
            proto.observe(x, y, 1.0);
            grid.observe(x, y, 1.0);
        }
        let sp = proto.best_split(&VarianceReduction).unwrap();
        let sg = grid.best_split(&VarianceReduction).unwrap();
        // same boundary slot => same merit; thresholds within one radius
        assert!((sp.merit - sg.merit).abs() < 1e-12);
        assert!((sp.threshold - sg.threshold).abs() <= 0.05 + 1e-12);
        // grid boundary is an exact multiple of r
        assert!((sg.threshold / 0.05 - (sg.threshold / 0.05).round()).abs() < 1e-9);
    }

    #[test]
    fn grid_boundary_saturated_slot_does_not_overflow() {
        // regression for the `code + 1` overflow: bucket codes saturate at
        // the i64 range for extreme x/r (see `code`), so the grid-boundary
        // threshold must use saturating arithmetic — in debug builds the
        // old `code + 1` could wrap and panic. Build slots at the very top
        // of the code range and query every boundary.
        let mut qo = QuantizationObserver::with_radius(0.5)
            .with_strategy(SplitPointStrategy::GridBoundary);
        let mut lo = VarStats::new();
        lo.update(0.0, 1.0);
        lo.update(0.2, 1.0);
        let mut hi = VarStats::new();
        hi.update(10.0, 1.0);
        hi.update(9.5, 1.0);
        qo.absorb_slot(i64::MAX - 1, 1.0, lo);
        qo.absorb_slot(i64::MAX, 2.0, hi);
        let s = qo.best_split(&VarianceReduction).expect("two slots must split");
        // the only boundary's left code is i64::MAX - 1: threshold is the
        // saturated grid edge i64::MAX · r
        assert!(s.threshold.is_finite(), "threshold={}", s.threshold);
        assert!((s.threshold - i64::MAX as f64 * 0.5).abs() <= 1.0);

        // the observe() route: x/r beyond the i64 range saturates codes at
        // both ends; the query must survive those slots too
        let mut extreme = QuantizationObserver::with_radius(1e-300)
            .with_strategy(SplitPointStrategy::GridBoundary);
        extreme.observe(-1e300, -1.0, 1.0); // code i64::MIN
        extreme.observe(0.0, 0.0, 1.0); // code 0
        extreme.observe(1e300, 1.0, 1.0); // code i64::MAX
        let s = extreme.best_split(&VarianceReduction).expect("three slots");
        assert!(s.threshold.is_finite(), "threshold={}", s.threshold);
    }

    #[test]
    fn json_roundtrip_is_state_identical() {
        let mut qo = QuantizationObserver::new(RadiusPolicy::std_fraction(2.0))
            .with_strategy(SplitPointStrategy::GridBoundary);
        let mut rng = Rng::new(17);
        for _ in 0..800 {
            let x = rng.normal(0.0, 1.5);
            qo.observe(x, x * x + rng.normal(0.0, 0.1), 1.0);
        }
        let text = qo.to_json().to_compact();
        let mut back =
            QuantizationObserver::from_json(&crate::common::json::Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.radius(), qo.radius());
        assert_eq!(back.n_elements(), qo.n_elements());
        assert_eq!(back.strategy(), qo.strategy());
        assert_eq!(back.total().mean.to_bits(), qo.total().mean.to_bits());
        let sa = qo.best_split(&VarianceReduction).unwrap();
        let sb = back.best_split(&VarianceReduction).unwrap();
        assert_eq!(sa.threshold.to_bits(), sb.threshold.to_bits());
        assert_eq!(sa.merit.to_bits(), sb.merit.to_bits());
        // continued observation stays identical
        for _ in 0..200 {
            let x = rng.normal(0.0, 1.5);
            let y = x * x;
            qo.observe(x, y, 1.0);
            back.observe(x, y, 1.0);
        }
        let sa = qo.best_split(&VarianceReduction).unwrap();
        let sb = back.best_split(&VarianceReduction).unwrap();
        assert_eq!(sa.threshold.to_bits(), sb.threshold.to_bits());
        assert_eq!(sa.merit.to_bits(), sb.merit.to_bits());
    }

    #[test]
    fn json_roundtrip_mid_warmup() {
        let mut qo = QuantizationObserver::new(RadiusPolicy::std_fraction(3.0));
        let mut rng = Rng::new(19);
        for _ in 0..40 {
            // fewer than the 100-observation warmup: still buffering
            qo.observe(rng.normal(0.0, 1.0), rng.f64(), 1.0);
        }
        assert!(qo.radius().is_none());
        let text = qo.to_json().to_compact();
        let mut back =
            QuantizationObserver::from_json(&crate::common::json::Json::parse(&text).unwrap())
                .unwrap();
        assert!(back.radius().is_none());
        assert_eq!(back.n_elements(), qo.n_elements());
        for _ in 0..100 {
            let x = rng.normal(0.0, 1.0);
            let y = x;
            qo.observe(x, y, 1.0);
            back.observe(x, y, 1.0);
        }
        // both froze at the identical dynamically chosen radius
        assert_eq!(qo.radius().unwrap().to_bits(), back.radius().unwrap().to_bits());
        assert_eq!(qo.n_elements(), back.n_elements());
    }

    #[test]
    fn compact_preserves_totals_order_and_boundary_stats() {
        let mut qo = QuantizationObserver::with_radius(0.01);
        let mut rng = Rng::new(21);
        for _ in 0..20_000 {
            let x = rng.uniform(-1.0, 1.0);
            qo.observe(x, if x <= 0.3 { 0.0 } else { 5.0 }, 1.0);
        }
        let original = qo.sorted_slots();
        assert!(original.len() > 64, "{}", original.len());
        let removed = qo.compact(64);
        let compacted = qo.sorted_slots();
        assert_eq!(removed, original.len() - compacted.len());
        assert!(compacted.len() <= 64 && compacted.len() > 32, "{}", compacted.len());
        // codes stay strictly increasing and are a subset of the originals
        for w in compacted.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        let codes: std::collections::HashSet<i64> = original.iter().map(|&(c, _)| c).collect();
        assert!(compacted.iter().all(|(c, _)| codes.contains(c)));
        // totals untouched; slot-stat sum still equals total (QO_TOTAL_DRIFT)
        let merged = compacted.iter().fold(VarStats::new(), |acc, &(_, s)| acc + s.stats);
        assert!((merged.n - qo.total().n).abs() < 1e-9);
        assert!((merged.m2 - qo.total().m2).abs() / qo.total().m2.max(1.0) < 1e-9);
        // each compacted slot's stats equal the VarStats merge of the
        // originals it covers (exactness: same fold best_split performs)
        let mut idx = 0;
        for (i, &(code, slot)) in compacted.iter().enumerate() {
            assert_eq!(code, original[idx].0);
            let end = if i + 1 < compacted.len() {
                original.iter().position(|&(c, _)| c == compacted[i + 1].0).unwrap()
            } else {
                original.len()
            };
            let (mut sum_x, mut stats) = (0.0, VarStats::new());
            for &(_, s) in &original[idx..end] {
                sum_x += s.sum_x;
                stats += s.stats;
            }
            assert_eq!(slot.sum_x.to_bits(), sum_x.to_bits());
            assert_eq!(slot.stats.n.to_bits(), stats.n.to_bits());
            assert_eq!(slot.stats.mean.to_bits(), stats.mean.to_bits());
            assert_eq!(slot.stats.m2.to_bits(), stats.m2.to_bits());
            idx = end;
        }
        // the split is still found near the step
        let s = qo.best_split(&VarianceReduction).unwrap();
        assert!((s.threshold - 0.3).abs() < 0.05, "threshold={}", s.threshold);
    }

    #[test]
    fn compact_shrinks_mem_and_is_idempotent() {
        let mut qo = QuantizationObserver::with_radius(0.005);
        let mut rng = Rng::new(23);
        for _ in 0..30_000 {
            qo.observe(rng.uniform(-1.0, 1.0), rng.f64(), 1.0);
        }
        let before = qo.mem_bytes();
        assert!(qo.compact(16) > 0);
        assert!(qo.n_elements() <= 16);
        assert!(qo.mem_bytes() < before, "{} !< {before}", qo.mem_bytes());
        // already at target: no further merging
        assert_eq!(qo.compact(16), 0);
        // target floor is 2 slots — a split query must stay possible
        qo.compact(0);
        assert!(qo.n_elements() >= 2);
        assert!(qo.best_split(&VarianceReduction).is_some());
    }

    #[test]
    fn compact_is_a_noop_while_warming() {
        let mut qo = QuantizationObserver::new(RadiusPolicy::std_fraction(2.0));
        let mut rng = Rng::new(29);
        for _ in 0..40 {
            qo.observe(rng.normal(0.0, 1.0), rng.f64(), 1.0);
        }
        assert!(qo.radius().is_none());
        assert_eq!(qo.compact(2), 0);
        assert_eq!(qo.n_elements(), 40, "warmup buffer must be untouched");
    }

    #[test]
    fn prop_left_right_partition_total() {
        check("qo-partition-total", 0xB0, 50, |rng| {
            let mut qo = QuantizationObserver::with_radius(0.05 + rng.f64() * 0.2);
            let n = 200 + rng.below(800);
            for _ in 0..n {
                qo.observe(rng.normal(0.0, 2.0), rng.normal(1.0, 1.0), 1.0);
            }
            if let Some(s) = qo.best_split(&VarianceReduction) {
                let sum = s.left + s.right;
                expect_close("n", sum.n, qo.total().n, 1e-9, 1e-9)?;
                expect_close("mean", sum.mean, qo.total().mean, 1e-7, 1e-7)?;
            }
            Ok(())
        });
    }
}
