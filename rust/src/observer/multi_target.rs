//! Multi-target Quantization Observer — the paper's Sec. 7 claim, made
//! concrete: "QO can also be easily extended to deal with multi-target
//! regression."
//!
//! Each hash slot keeps one robust [`VarStats`] *per target* (plus Σx for
//! the prototype). The split merit follows iSOUP-Tree (Osojnik et al.
//! 2018): the average of the per-target Variance Reductions, each
//! normalized by the target's total variance so differently-scaled
//! targets contribute equally.

use std::collections::HashMap;

use crate::common::fxhash::FxBuildHasher;
use crate::stats::VarStats;

/// A proposed multi-target split.
#[derive(Clone, Debug)]
pub struct MtSplitSuggestion {
    pub threshold: f64,
    /// Average normalized VR across targets.
    pub merit: f64,
    /// Per-target (left, right) statistics at the chosen boundary.
    pub left: Vec<VarStats>,
    pub right: Vec<VarStats>,
}

#[derive(Clone, Debug)]
struct MtSlot {
    sum_x: f64,
    n_x: f64,
    stats: Vec<VarStats>,
}

impl MtSlot {
    fn new(k: usize) -> MtSlot {
        MtSlot { sum_x: 0.0, n_x: 0.0, stats: vec![VarStats::new(); k] }
    }

    fn prototype(&self) -> f64 {
        if self.n_x > 0.0 {
            self.sum_x / self.n_x
        } else {
            0.0
        }
    }
}

/// Fixed-radius multi-target QO (paper Alg. 1/2 with vector targets).
#[derive(Clone, Debug)]
pub struct MultiTargetQuantizationObserver {
    radius: f64,
    n_targets: usize,
    slots: HashMap<i64, MtSlot, FxBuildHasher>,
    totals: Vec<VarStats>,
}

impl MultiTargetQuantizationObserver {
    pub fn new(radius: f64, n_targets: usize) -> MultiTargetQuantizationObserver {
        assert!(radius > 0.0 && n_targets > 0);
        MultiTargetQuantizationObserver {
            radius,
            n_targets,
            slots: HashMap::default(),
            totals: vec![VarStats::new(); n_targets],
        }
    }

    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    pub fn n_elements(&self) -> usize {
        self.slots.len()
    }

    /// Monitor one observation of the feature with the target vector `ys`.
    pub fn observe(&mut self, x: f64, ys: &[f64], w: f64) {
        assert_eq!(ys.len(), self.n_targets);
        if w <= 0.0 || !x.is_finite() || ys.iter().any(|y| !y.is_finite()) {
            return;
        }
        let code = super::qo::QuantizationObserver::code(x, self.radius);
        let k = self.n_targets;
        let slot = self.slots.entry(code).or_insert_with(|| MtSlot::new(k));
        slot.sum_x += w * x;
        slot.n_x += w;
        for (t, &y) in ys.iter().enumerate() {
            slot.stats[t].update(y, w);
            self.totals[t].update(y, w);
        }
    }

    /// Best split by average normalized VR (paper Alg. 2, vectorized over
    /// targets).
    pub fn best_split(&self) -> Option<MtSplitSuggestion> {
        if self.slots.len() < 2 {
            return None;
        }
        let mut items: Vec<(&i64, &MtSlot)> = self.slots.iter().collect();
        items.sort_unstable_by_key(|&(k, _)| *k);

        let total_vars: Vec<f64> = self.totals.iter().map(|t| t.variance()).collect();
        let mut left: Vec<VarStats> = vec![VarStats::new(); self.n_targets];
        let mut best: Option<MtSplitSuggestion> = None;
        for window in items.windows(2) {
            let (_, slot) = window[0];
            let (_, next) = window[1];
            for t in 0..self.n_targets {
                left[t] += slot.stats[t];
            }
            // average normalized VR across targets (iSOUP-style)
            let mut merit = 0.0;
            let mut right = Vec::with_capacity(self.n_targets);
            for t in 0..self.n_targets {
                let r = self.totals[t] - left[t];
                let vr = crate::criterion::SplitCriterion::merit(
                    &crate::criterion::VarianceReduction,
                    &self.totals[t],
                    &left[t],
                    &r,
                );
                merit += if total_vars[t] > 0.0 { vr / total_vars[t] } else { 0.0 };
                right.push(r);
            }
            merit /= self.n_targets as f64;
            if best.as_ref().map(|b| merit > b.merit).unwrap_or(true) {
                best = Some(MtSplitSuggestion {
                    threshold: 0.5 * (slot.prototype() + next.prototype()),
                    merit,
                    left: left.clone(),
                    right,
                });
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn both_targets_step_at_same_point() {
        let mut mt = MultiTargetQuantizationObserver::new(0.05, 2);
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            let ys = if x <= 0.2 { [0.0, 100.0] } else { [1.0, 50.0] };
            mt.observe(x, &ys, 1.0);
        }
        let s = mt.best_split().unwrap();
        assert!((s.threshold - 0.2).abs() < 0.05, "threshold={}", s.threshold);
        // both targets' variance fully explained -> merit ~ 1
        assert!(s.merit > 0.95, "merit={}", s.merit);
        assert!(s.left[0].mean < 0.1 && s.left[1].mean > 99.0);
    }

    #[test]
    fn normalization_balances_target_scales() {
        // target 0 steps at x=0 (scale 1); target 1 steps at x=0.5
        // (scale 1000). Without normalization target 1 would dominate;
        // with it, the merit at each boundary is the per-target average,
        // so the chosen split explains BOTH partially or the stronger
        // joint one. Here both steps have equal normalized VR = 0.5
        // contribution; slot layout decides; just check merit is ~0.5.
        let mut mt = MultiTargetQuantizationObserver::new(0.02, 2);
        let mut rng = Rng::new(2);
        for _ in 0..20_000 {
            let x = rng.uniform(-1.0, 1.0);
            let y0 = if x <= 0.0 { 0.0 } else { 1.0 };
            let y1 = if x <= 0.5 { 0.0 } else { 1000.0 };
            mt.observe(x, &[y0, y1], 1.0);
        }
        let s = mt.best_split().unwrap();
        // both candidate boundaries give avg normalized merit >= ~0.5;
        // the winner must be one of the two steps
        assert!(
            (s.threshold - 0.0).abs() < 0.05 || (s.threshold - 0.5).abs() < 0.05,
            "threshold={}",
            s.threshold
        );
        assert!(s.merit > 0.45, "merit={}", s.merit);
    }

    #[test]
    fn rejects_mismatched_target_arity() {
        let mut mt = MultiTargetQuantizationObserver::new(0.1, 2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            mt.observe(0.0, &[1.0], 1.0);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn single_target_matches_scalar_qo() {
        use crate::criterion::VarianceReduction;
        use crate::observer::{AttributeObserver, QuantizationObserver};
        let mut mt = MultiTargetQuantizationObserver::new(0.1, 1);
        let mut qo = QuantizationObserver::with_radius(0.1);
        let mut rng = Rng::new(3);
        for _ in 0..3000 {
            let x = rng.normal(0.0, 1.0);
            let y = x * x;
            mt.observe(x, &[y], 1.0);
            qo.observe(x, y, 1.0);
        }
        let sm = mt.best_split().unwrap();
        let sq = qo.best_split(&VarianceReduction).unwrap();
        assert!((sm.threshold - sq.threshold).abs() < 1e-9);
        // mt merit is normalized by total variance
        let expected = sq.merit / qo.total().variance();
        assert!((sm.merit - expected).abs() < 1e-9);
    }

    #[test]
    fn empty_and_single_slot_no_split() {
        let mut mt = MultiTargetQuantizationObserver::new(0.5, 3);
        assert!(mt.best_split().is_none());
        mt.observe(0.1, &[1.0, 2.0, 3.0], 1.0);
        mt.observe(0.2, &[1.0, 2.0, 3.0], 1.0); // same slot
        assert_eq!(mt.n_elements(), 1);
        assert!(mt.best_split().is_none());
    }
}
