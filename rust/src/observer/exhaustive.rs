//! Exhaustive (batch-style) attribute observer: stores the raw sample and
//! evaluates **every** boundary between distinct feature values.
//!
//! This is what a batch CART/FIMT split search would do with the full data
//! in memory; it is the oracle the approximate observers (QO, E-BST,
//! TE-BST) are tested against. O(n) memory, O(n log n) query.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::criterion::SplitCriterion;
use crate::persist::codec::{field, jf64, parr, pf64, varstats_from, varstats_to_json};
use crate::stats::VarStats;

use super::{AttributeObserver, SplitSuggestion};

#[derive(Clone, Debug, Default)]
pub struct ExhaustiveObserver {
    points: Vec<(f64, f64, f64)>,
    total: VarStats,
}

impl ExhaustiveObserver {
    pub fn new() -> ExhaustiveObserver {
        ExhaustiveObserver::default()
    }

    /// Decode an observer written by [`AttributeObserver::to_json`]. The
    /// raw sample is restored in arrival order.
    pub fn from_json(j: &Json) -> Result<ExhaustiveObserver> {
        let mut points = Vec::new();
        for item in parr(field(j, "points")?, "points")? {
            let triple = parr(item, "points")?;
            if triple.len() != 3 {
                return Err(anyhow!("exhaustive point: expected [x, y, w]"));
            }
            points.push((
                pf64(&triple[0], "point.x")?,
                pf64(&triple[1], "point.y")?,
                pf64(&triple[2], "point.w")?,
            ));
        }
        Ok(ExhaustiveObserver {
            points,
            total: varstats_from(field(j, "total")?, "total")?,
        })
    }

    /// Every candidate (threshold, merit), sorted by threshold — used by
    /// tests that compare full merit curves rather than just the argmax.
    pub fn all_candidates(&self, criterion: &dyn SplitCriterion) -> Vec<(f64, f64)> {
        let mut pts = self.points.clone();
        pts.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out = Vec::new();
        let mut left = VarStats::new();
        for i in 0..pts.len().saturating_sub(1) {
            let (x, y, w) = pts[i];
            left.update(y, w);
            let x_next = pts[i + 1].0;
            if x_next <= x {
                continue;
            }
            let right = self.total - left;
            out.push((0.5 * (x + x_next), criterion.merit(&self.total, &left, &right)));
        }
        out
    }
}

impl AttributeObserver for ExhaustiveObserver {
    fn observe(&mut self, x: f64, y: f64, w: f64) {
        if w <= 0.0 || !x.is_finite() || !y.is_finite() {
            return;
        }
        self.points.push((x, y, w));
        self.total.update(y, w);
    }

    fn best_split(&self, criterion: &dyn SplitCriterion) -> Option<SplitSuggestion> {
        let mut pts = self.points.clone();
        if pts.len() < 2 {
            return None;
        }
        pts.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut left = VarStats::new();
        let mut best: Option<SplitSuggestion> = None;
        for i in 0..pts.len() - 1 {
            let (x, y, w) = pts[i];
            left.update(y, w);
            let x_next = pts[i + 1].0;
            if x_next <= x {
                continue;
            }
            let right = self.total - left;
            let merit = criterion.merit(&self.total, &left, &right);
            if best.map(|b| merit > b.merit).unwrap_or(true) {
                best = Some(SplitSuggestion { threshold: 0.5 * (x + x_next), merit, left, right });
            }
        }
        best
    }

    fn n_elements(&self) -> usize {
        self.points.len()
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<ExhaustiveObserver>()
            + self.points.capacity() * std::mem::size_of::<(f64, f64, f64)>()
    }

    fn name(&self) -> String {
        "Exhaustive".to_string()
    }

    fn total(&self) -> VarStats {
        self.total
    }

    fn reset(&mut self) {
        self.points.clear();
        self.total = VarStats::new();
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("type", "exhaustive")
            .set("total", varstats_to_json(&self.total))
            .set(
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|&(x, y, w)| Json::Arr(vec![jf64(x), jf64(y), jf64(w)]))
                        .collect(),
                ),
            );
        o
    }

    fn clone_box(&self) -> Box<dyn AttributeObserver> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criterion::VarianceReduction;

    #[test]
    fn exact_split_on_step() {
        let mut ex = ExhaustiveObserver::new();
        for i in 0..100 {
            let x = i as f64 / 100.0;
            ex.observe(x, if x <= 0.42 { 0.0 } else { 1.0 }, 1.0);
        }
        let s = ex.best_split(&VarianceReduction).unwrap();
        assert!((s.threshold - 0.425).abs() < 1e-9, "{}", s.threshold);
        assert!((s.merit - ex.total().variance()).abs() < 1e-12);
    }

    #[test]
    fn no_split_with_constant_feature() {
        let mut ex = ExhaustiveObserver::new();
        for y in [1.0, 2.0, 3.0] {
            ex.observe(5.0, y, 1.0);
        }
        assert!(ex.best_split(&VarianceReduction).is_none());
    }

    #[test]
    fn json_roundtrip_preserves_sample_order() {
        let mut ex = ExhaustiveObserver::new();
        for (x, y) in [(3.0, 1.0), (1.0, -2.0), (2.0, 0.5), (1.0, 4.0)] {
            ex.observe(x, y, 1.0);
        }
        let back = ExhaustiveObserver::from_json(
            &Json::parse(&ex.to_json().to_compact()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.n_elements(), ex.n_elements());
        assert_eq!(back.points, ex.points);
        let sa = ex.best_split(&VarianceReduction).unwrap();
        let sb = back.best_split(&VarianceReduction).unwrap();
        assert_eq!(sa.threshold.to_bits(), sb.threshold.to_bits());
        assert_eq!(sa.merit.to_bits(), sb.merit.to_bits());
    }

    #[test]
    fn candidates_count_distinct_boundaries() {
        let mut ex = ExhaustiveObserver::new();
        for (x, y) in [(1.0, 0.0), (1.0, 1.0), (2.0, 2.0), (3.0, 0.5)] {
            ex.observe(x, y, 1.0);
        }
        let cands = ex.all_candidates(&VarianceReduction);
        assert_eq!(cands.len(), 2); // boundaries 1|2 and 2|3
        assert!((cands[0].0 - 1.5).abs() < 1e-12);
        assert!((cands[1].0 - 2.5).abs() < 1e-12);
    }
}
