//! Streaming CSV reader: replay a real dataset file as a [`Stream`].
//!
//! Minimal dialect: comma-separated, optional header, no embedded commas
//! in numeric data (quotes are tolerated and stripped). Non-numeric cells
//! become NaN and the row is skipped — regression streams must be fully
//! numeric.

use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use super::{Instance, Stream};

pub struct CsvStream {
    reader: BufReader<Box<dyn Read + Send>>,
    target_index: usize,
    n_features: usize,
    label: String,
    line_buf: String,
}

impl CsvStream {
    /// Open a CSV file; `target` names the target column (header required)
    /// or is a 0-based index when the file has no header.
    pub fn open(path: &Path, target: &str) -> anyhow::Result<CsvStream> {
        let file = File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let label = path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        Self::from_reader(Box::new(file), target, label)
    }

    /// Build from any reader (testing uses in-memory buffers).
    pub fn from_reader(
        raw: Box<dyn Read + Send>,
        target: &str,
        label: String,
    ) -> anyhow::Result<CsvStream> {
        let mut reader = BufReader::new(raw);
        let mut first = String::new();
        reader.read_line(&mut first)?;
        let cells = split_csv(first.trim_end());
        let all_numeric = cells.iter().all(|c| c.parse::<f64>().is_ok());
        let (target_index, n_cols, consumed_header) = if all_numeric {
            let idx: usize = target
                .parse()
                .map_err(|_| anyhow::anyhow!("no header: target must be a column index"))?;
            (idx, cells.len(), false)
        } else {
            let idx = cells
                .iter()
                .position(|c| c == target)
                .ok_or_else(|| anyhow::anyhow!("target column {target:?} not in header"))?;
            (idx, cells.len(), true)
        };
        anyhow::ensure!(target_index < n_cols, "target index out of range");
        let mut stream = CsvStream {
            reader,
            target_index,
            n_features: n_cols - 1,
            label,
            line_buf: if consumed_header { String::new() } else { first },
        };
        // when the first line was data, stash it for the first next() call
        if !consumed_header {
            // keep line_buf as pending row
        } else {
            stream.line_buf.clear();
        }
        Ok(stream)
    }

    fn parse_row(&self, line: &str) -> Option<Instance> {
        let cells = split_csv(line.trim_end());
        if cells.len() != self.n_features + 1 {
            return None;
        }
        let mut x = Vec::with_capacity(self.n_features);
        let mut y = f64::NAN;
        for (i, cell) in cells.iter().enumerate() {
            let v: f64 = cell.trim().parse().ok()?;
            if i == self.target_index {
                y = v;
            } else {
                x.push(v);
            }
        }
        if y.is_nan() {
            return None;
        }
        Some(Instance { x, y })
    }
}

fn split_csv(line: &str) -> Vec<String> {
    line.split(',').map(|c| c.trim().trim_matches('"').to_string()).collect()
}

impl Stream for CsvStream {
    fn next_instance(&mut self) -> Option<Instance> {
        loop {
            if !self.line_buf.is_empty() {
                let line = std::mem::take(&mut self.line_buf);
                if let Some(inst) = self.parse_row(&line) {
                    return Some(inst);
                }
                continue;
            }
            let mut line = String::new();
            match self.reader.read_line(&mut line) {
                Ok(0) | Err(_) => return None,
                Ok(_) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    if let Some(inst) = self.parse_row(&line) {
                        return Some(inst);
                    }
                    // malformed row: skip
                }
            }
        }
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn name(&self) -> String {
        format!("csv[{}]", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn stream_of(content: &str, target: &str) -> CsvStream {
        CsvStream::from_reader(Box::new(Cursor::new(content.to_string())), target, "mem".into())
            .unwrap()
    }

    #[test]
    fn header_and_target_by_name() {
        let mut s = stream_of("a,b,y\n1,2,3\n4,5,6\n", "y");
        assert_eq!(s.n_features(), 2);
        let i1 = s.next_instance().unwrap();
        assert_eq!(i1, Instance { x: vec![1.0, 2.0], y: 3.0 });
        let i2 = s.next_instance().unwrap();
        assert_eq!(i2.y, 6.0);
        assert!(s.next_instance().is_none());
    }

    #[test]
    fn target_in_middle_column() {
        let mut s = stream_of("a,y,b\n1,9,2\n", "y");
        assert_eq!(s.next_instance().unwrap(), Instance { x: vec![1.0, 2.0], y: 9.0 });
    }

    #[test]
    fn headerless_by_index() {
        let mut s = stream_of("1,2,3\n4,5,6\n", "2");
        // first row must not be lost
        assert_eq!(s.next_instance().unwrap(), Instance { x: vec![1.0, 2.0], y: 3.0 });
        assert_eq!(s.next_instance().unwrap().y, 6.0);
    }

    #[test]
    fn malformed_rows_skipped() {
        let mut s = stream_of("a,y\n1,2\nbad,row\n3,4\n\n", "y");
        assert_eq!(s.next_instance().unwrap().y, 2.0);
        assert_eq!(s.next_instance().unwrap().y, 4.0);
        assert!(s.next_instance().is_none());
    }

    #[test]
    fn missing_target_errors() {
        let res = CsvStream::from_reader(
            Box::new(Cursor::new("a,b\n1,2\n".to_string())),
            "nope",
            "mem".into(),
        );
        assert!(res.is_err());
    }
}
