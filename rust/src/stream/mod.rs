//! Data-stream substrate: the paper's Table 1 synthetic protocol, the
//! Friedman #1 benchmark generator, concept-drift wrappers and a CSV
//! reader.

pub mod csv;
pub mod drift;
pub mod friedman_gen;
pub mod synth;

pub use drift::{AbruptDrift, GradualDrift};
pub use friedman_gen::Friedman1;
pub use synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};

/// One labelled stream element.
#[derive(Clone, Debug, PartialEq)]
pub struct Instance {
    pub x: Vec<f64>,
    pub y: f64,
}

/// An unbounded (or file-bounded) supervised data stream.
pub trait Stream: Send {
    /// Produce the next instance, or `None` when exhausted.
    fn next_instance(&mut self) -> Option<Instance>;

    /// Number of input features.
    fn n_features(&self) -> usize;

    fn name(&self) -> String;

    /// Drain up to `n` instances into a vector (testing/bench helper).
    fn take_vec(&mut self, n: usize) -> Vec<Instance>
    where
        Self: Sized,
    {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.next_instance() {
                Some(inst) => out.push(inst),
                None => break,
            }
        }
        out
    }
}
