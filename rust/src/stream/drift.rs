//! Concept-drift wrappers: compose two streams into one whose concept
//! changes abruptly or gradually at a given position. Used by the
//! extension experiments (online trees are motivated by non-stationary
//! data, paper Sec. 1).

use crate::common::Rng;

use super::{Instance, Stream};

/// Switches from `before` to `after` at instance `position`.
pub struct AbruptDrift {
    before: Box<dyn Stream>,
    after: Box<dyn Stream>,
    position: usize,
    emitted: usize,
}

impl AbruptDrift {
    pub fn new(before: Box<dyn Stream>, after: Box<dyn Stream>, position: usize) -> AbruptDrift {
        assert_eq!(before.n_features(), after.n_features());
        AbruptDrift { before, after, position, emitted: 0 }
    }
}

impl Stream for AbruptDrift {
    fn next_instance(&mut self) -> Option<Instance> {
        let inst = if self.emitted < self.position {
            self.before.next_instance()
        } else {
            self.after.next_instance()
        };
        if inst.is_some() {
            self.emitted += 1;
        }
        inst
    }

    fn n_features(&self) -> usize {
        self.before.n_features()
    }

    fn name(&self) -> String {
        format!("abrupt[{}->{}@{}]", self.before.name(), self.after.name(), self.position)
    }
}

/// Sigmoid hand-over: at instance t the probability of sampling from the
/// new concept is `1 / (1 + e^{-4(t - position)/width})` (MOA convention).
pub struct GradualDrift {
    before: Box<dyn Stream>,
    after: Box<dyn Stream>,
    position: usize,
    width: usize,
    emitted: usize,
    rng: Rng,
}

impl GradualDrift {
    pub fn new(
        before: Box<dyn Stream>,
        after: Box<dyn Stream>,
        position: usize,
        width: usize,
        seed: u64,
    ) -> GradualDrift {
        assert_eq!(before.n_features(), after.n_features());
        assert!(width > 0);
        GradualDrift { before, after, position, width, emitted: 0, rng: Rng::new(seed) }
    }

    fn p_new(&self) -> f64 {
        let t = self.emitted as f64 - self.position as f64;
        1.0 / (1.0 + (-4.0 * t / self.width as f64).exp())
    }
}

impl Stream for GradualDrift {
    fn next_instance(&mut self) -> Option<Instance> {
        let p = self.p_new();
        let inst = if self.rng.bool(p) {
            self.after.next_instance()
        } else {
            self.before.next_instance()
        };
        if inst.is_some() {
            self.emitted += 1;
        }
        inst
    }

    fn n_features(&self) -> usize {
        self.before.n_features()
    }

    fn name(&self) -> String {
        format!(
            "gradual[{}->{}@{}+/-{}]",
            self.before.name(),
            self.after.name(),
            self.position,
            self.width
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};

    fn constant_stream(level: f64, seed: u64) -> Box<dyn Stream> {
        // a linear generator whose target we displace by reusing bias:
        // easier: uniform feature, y = level (achieved via zero coeffs +
        // clean_target offset). Use a tiny wrapper instead.
        struct Const {
            level: f64,
            inner: SyntheticRegression,
        }
        impl Stream for Const {
            fn next_instance(&mut self) -> Option<Instance> {
                let mut inst = self.inner.next_instance().unwrap();
                inst.y = self.level;
                Some(inst)
            }
            fn n_features(&self) -> usize {
                self.inner.n_features()
            }
            fn name(&self) -> String {
                format!("const{}", self.level)
            }
        }
        Box::new(Const {
            level,
            inner: SyntheticRegression::new(
                Distribution::Uniform { lo: -1.0, hi: 1.0 },
                TargetFn::Linear,
                NoiseSpec::NONE,
                1,
                seed,
            ),
        })
    }

    #[test]
    fn abrupt_switches_exactly_at_position() {
        let mut s = AbruptDrift::new(constant_stream(0.0, 1), constant_stream(9.0, 2), 5);
        let ys: Vec<f64> = s.take_vec(10).into_iter().map(|i| i.y).collect();
        assert_eq!(ys, vec![0.0, 0.0, 0.0, 0.0, 0.0, 9.0, 9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn gradual_mixes_around_position() {
        let mut s =
            GradualDrift::new(constant_stream(0.0, 3), constant_stream(1.0, 4), 500, 200, 7);
        let ys: Vec<f64> = s.take_vec(1000).into_iter().map(|i| i.y).collect();
        let early: f64 = ys[..100].iter().sum::<f64>() / 100.0;
        let late: f64 = ys[900..].iter().sum::<f64>() / 100.0;
        let mid: f64 = ys[450..550].iter().sum::<f64>() / 100.0;
        assert!(early < 0.05, "early={early}");
        assert!(late > 0.95, "late={late}");
        assert!(mid > 0.2 && mid < 0.8, "mid={mid}");
    }
}
