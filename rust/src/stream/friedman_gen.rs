//! The Friedman #1 synthetic regression benchmark (Friedman 1991), the
//! standard non-trivial workload for regression-tree evaluation:
//!
//! ```text
//! y = 10·sin(π·x1·x2) + 20·(x3 − 0.5)² + 10·x4 + 5·x5 + ε,  ε ~ N(0, σ)
//! ```
//!
//! with 10 features i.i.d. U[0, 1] (features 6–10 are pure noise). Used by
//! the end-to-end tree experiments.

use crate::common::Rng;

use super::{Instance, Stream};

#[derive(Clone, Debug)]
pub struct Friedman1 {
    rng: Rng,
    noise_sigma: f64,
}

impl Friedman1 {
    pub fn new(seed: u64, noise_sigma: f64) -> Friedman1 {
        Friedman1 { rng: Rng::new(seed), noise_sigma }
    }

    /// Noiseless target for a 10-feature input.
    pub fn clean_target(x: &[f64]) -> f64 {
        10.0 * (std::f64::consts::PI * x[0] * x[1]).sin()
            + 20.0 * (x[2] - 0.5) * (x[2] - 0.5)
            + 10.0 * x[3]
            + 5.0 * x[4]
    }
}

impl Stream for Friedman1 {
    fn next_instance(&mut self) -> Option<Instance> {
        let x: Vec<f64> = (0..10).map(|_| self.rng.f64()).collect();
        let mut y = Self::clean_target(&x);
        if self.noise_sigma > 0.0 {
            y += self.rng.normal(0.0, self.noise_sigma);
        }
        Some(Instance { x, y })
    }

    fn n_features(&self) -> usize {
        10
    }

    fn name(&self) -> String {
        format!("friedman1[sigma={}]", self.noise_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_features_in_unit_cube() {
        let mut f = Friedman1::new(1, 0.0);
        for _ in 0..100 {
            let inst = f.next_instance().unwrap();
            assert_eq!(inst.x.len(), 10);
            assert!(inst.x.iter().all(|v| (0.0..1.0).contains(v)));
        }
    }

    #[test]
    fn known_target_values() {
        // x1=x2=0.5: sin(pi/4)... compute directly
        let x = [0.5, 0.5, 0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let expected = 10.0 * (std::f64::consts::PI * 0.25).sin();
        assert!((Friedman1::clean_target(&x) - expected).abs() < 1e-12);
    }

    #[test]
    fn noiseless_is_deterministic_function_of_x() {
        let mut f = Friedman1::new(2, 0.0);
        let inst = f.next_instance().unwrap();
        assert_eq!(inst.y, Friedman1::clean_target(&inst.x));
    }

    #[test]
    fn irrelevant_features_do_not_matter() {
        let mut a = [0.1; 10];
        let mut b = [0.1; 10];
        a[7] = 0.9;
        b[7] = 0.2;
        assert_eq!(Friedman1::clean_target(&a), Friedman1::clean_target(&b));
    }
}
