//! The Friedman #1 synthetic regression benchmark (Friedman 1991), the
//! standard non-trivial workload for regression-tree evaluation:
//!
//! ```text
//! y = 10·sin(π·x1·x2) + 20·(x3 − 0.5)² + 10·x4 + 5·x5 + ε,  ε ~ N(0, σ)
//! ```
//!
//! with 10 features i.i.d. U[0, 1] (features 6–10 are pure noise). Used by
//! the end-to-end tree experiments.

use crate::common::Rng;

use super::{Instance, Stream};

#[derive(Clone, Debug)]
pub struct Friedman1 {
    rng: Rng,
    noise_sigma: f64,
    swapped: bool,
}

impl Friedman1 {
    pub fn new(seed: u64, noise_sigma: f64) -> Friedman1 {
        Friedman1 { rng: Rng::new(seed), noise_sigma, swapped: false }
    }

    /// The *swapped* concept: same U[0,1]^10 inputs, but the roles of the
    /// five informative features are reversed (x5..x1 instead of x1..x5).
    /// Composing `new` → `swapped` with [`super::AbruptDrift`] yields a
    /// genuine concept change over an unchanged input distribution — the
    /// drift workload of the forest experiments.
    pub fn swapped(seed: u64, noise_sigma: f64) -> Friedman1 {
        Friedman1 { rng: Rng::new(seed), noise_sigma, swapped: true }
    }

    /// Noiseless target for a 10-feature input.
    pub fn clean_target(x: &[f64]) -> f64 {
        10.0 * (std::f64::consts::PI * x[0] * x[1]).sin()
            + 20.0 * (x[2] - 0.5) * (x[2] - 0.5)
            + 10.0 * x[3]
            + 5.0 * x[4]
    }

    /// Noiseless target of the swapped concept.
    pub fn clean_target_swapped(x: &[f64]) -> f64 {
        10.0 * (std::f64::consts::PI * x[4] * x[3]).sin()
            + 20.0 * (x[2] - 0.5) * (x[2] - 0.5)
            + 10.0 * x[1]
            + 5.0 * x[0]
    }
}

impl Stream for Friedman1 {
    fn next_instance(&mut self) -> Option<Instance> {
        let x: Vec<f64> = (0..10).map(|_| self.rng.f64()).collect();
        let mut y = if self.swapped {
            Self::clean_target_swapped(&x)
        } else {
            Self::clean_target(&x)
        };
        if self.noise_sigma > 0.0 {
            y += self.rng.normal(0.0, self.noise_sigma);
        }
        Some(Instance { x, y })
    }

    fn n_features(&self) -> usize {
        10
    }

    fn name(&self) -> String {
        if self.swapped {
            format!("friedman1-swapped[sigma={}]", self.noise_sigma)
        } else {
            format!("friedman1[sigma={}]", self.noise_sigma)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_features_in_unit_cube() {
        let mut f = Friedman1::new(1, 0.0);
        for _ in 0..100 {
            let inst = f.next_instance().unwrap();
            assert_eq!(inst.x.len(), 10);
            assert!(inst.x.iter().all(|v| (0.0..1.0).contains(v)));
        }
    }

    #[test]
    fn known_target_values() {
        // x1=x2=0.5: sin(pi/4)... compute directly
        let x = [0.5, 0.5, 0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let expected = 10.0 * (std::f64::consts::PI * 0.25).sin();
        assert!((Friedman1::clean_target(&x) - expected).abs() < 1e-12);
    }

    #[test]
    fn noiseless_is_deterministic_function_of_x() {
        let mut f = Friedman1::new(2, 0.0);
        let inst = f.next_instance().unwrap();
        assert_eq!(inst.y, Friedman1::clean_target(&inst.x));
    }

    #[test]
    fn swapped_concept_differs_but_shares_inputs() {
        // same seed -> identical feature vectors, different targets
        let mut a = Friedman1::new(5, 0.0);
        let mut b = Friedman1::swapped(5, 0.0);
        let ia = a.next_instance().unwrap();
        let ib = b.next_instance().unwrap();
        assert_eq!(ia.x, ib.x);
        assert!((ia.y - ib.y).abs() > 1e-9, "concepts should differ almost surely");
        assert_eq!(ib.y, Friedman1::clean_target_swapped(&ib.x));
    }

    #[test]
    fn swapped_is_a_feature_permutation() {
        let x = [0.9, 0.1, 0.4, 0.7, 0.2, 0.0, 0.0, 0.0, 0.0, 0.0];
        let permuted = [0.2, 0.7, 0.4, 0.1, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert!(
            (Friedman1::clean_target_swapped(&x) - Friedman1::clean_target(&permuted)).abs()
                < 1e-12
        );
    }

    #[test]
    fn irrelevant_features_do_not_matter() {
        let mut a = [0.1; 10];
        let mut b = [0.1; 10];
        a[7] = 0.9;
        b[7] = 0.2;
        assert_eq!(Friedman1::clean_target(&a), Friedman1::clean_target(&b));
    }
}
