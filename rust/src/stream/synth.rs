//! The paper's synthetic data protocol (Table 1).
//!
//! Samples features from Uniform / Normal / Bimodal distributions, builds
//! a linear (`lin`) or cubic (`cub`) target from randomly drawn
//! coefficients, and optionally corrupts a fraction of the instances with
//! Gaussian noise whose scale tracks the feature dispersion (the paper
//! adds N(0, 0.1) noise, or N(0, 0.01) for the small-dispersion settings).

use crate::common::Rng;

use super::{Instance, Stream};

/// Feature sampling distribution (paper Table 1, bottom block).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Distribution {
    /// U[lo, hi]
    Uniform { lo: f64, hi: f64 },
    /// N(mu, sigma) — note the paper writes N(mean, std).
    Normal { mu: f64, sigma: f64 },
    /// Equal-probability mixture of two normals (the paper's "|"
    /// concatenation); the third paper setting is asymmetric.
    Bimodal { mu1: f64, sigma1: f64, mu2: f64, sigma2: f64 },
}

impl Distribution {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Distribution::Uniform { lo, hi } => rng.uniform(lo, hi),
            Distribution::Normal { mu, sigma } => rng.normal(mu, sigma),
            Distribution::Bimodal { mu1, sigma1, mu2, sigma2 } => {
                if rng.bool(0.5) {
                    rng.normal(mu1, sigma1)
                } else {
                    rng.normal(mu2, sigma2)
                }
            }
        }
    }

    /// Rough dispersion scale, used to pick the matching noise sigma
    /// (paper footnote a) and for radius sanity checks in tests.
    pub fn scale(&self) -> f64 {
        match *self {
            Distribution::Uniform { lo, hi } => (hi - lo) / (12f64).sqrt(),
            Distribution::Normal { sigma, .. } => sigma,
            Distribution::Bimodal { mu1, sigma1, mu2, sigma2 } => {
                // mixture std (equal weights)
                let mean = 0.5 * (mu1 + mu2);
                let var = 0.5 * (sigma1 * sigma1 + (mu1 - mean) * (mu1 - mean))
                    + 0.5 * (sigma2 * sigma2 + (mu2 - mean) * (mu2 - mean));
                var.sqrt()
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            Distribution::Uniform { lo, hi } => format!("U[{lo},{hi}]"),
            Distribution::Normal { mu, sigma } => format!("N({mu},{sigma})"),
            Distribution::Bimodal { mu1, sigma1, mu2, sigma2 } => {
                format!("N({mu1},{sigma1})|N({mu2},{sigma2})")
            }
        }
    }

    /// The nine Table 1 distributions.
    pub fn table1() -> Vec<Distribution> {
        vec![
            Distribution::Normal { mu: 0.0, sigma: 1.0 },
            Distribution::Normal { mu: 0.0, sigma: 0.1 },
            Distribution::Normal { mu: 0.0, sigma: 7.0 },
            Distribution::Uniform { lo: -1.0, hi: 1.0 },
            Distribution::Uniform { lo: -0.1, hi: 0.1 },
            Distribution::Uniform { lo: -7.0, hi: 7.0 },
            Distribution::Bimodal { mu1: -1.0, sigma1: 1.0, mu2: 1.0, sigma2: 1.0 },
            Distribution::Bimodal { mu1: -0.1, sigma1: 0.1, mu2: 0.1, sigma2: 0.1 },
            // the asymmetric setting (paper: N(-7,7) | N(7,0.1))
            Distribution::Bimodal { mu1: -7.0, sigma1: 7.0, mu2: 7.0, sigma2: 0.1 },
        ]
    }
}

/// Target function family (paper Table 1: lin / cub).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetFn {
    Linear,
    Cubic,
}

impl TargetFn {
    pub fn label(&self) -> &'static str {
        match self {
            TargetFn::Linear => "lin",
            TargetFn::Cubic => "cub",
        }
    }
}

/// Noise configuration (paper Table 1: 0% or 10% of instances, sigma
/// matched to the feature dispersion).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NoiseSpec {
    /// Fraction of noisy instances (0.0 or 0.1 in the paper).
    pub fraction: f64,
    /// Std of the additive Gaussian target noise.
    pub sigma: f64,
}

impl NoiseSpec {
    pub const NONE: NoiseSpec = NoiseSpec { fraction: 0.0, sigma: 0.0 };

    /// Paper footnote a: N(0, 0.1), or N(0, 0.01) when the generating
    /// distribution's dispersion is itself small.
    pub fn for_distribution(dist: &Distribution, fraction: f64) -> NoiseSpec {
        let sigma = if dist.scale() < 0.5 { 0.01 } else { 0.1 };
        NoiseSpec { fraction, sigma }
    }
}

/// Per-feature polynomial coefficients for the target function.
#[derive(Clone, Debug)]
struct Coeffs {
    /// cubic, quadratic, linear terms per feature (cubic task) or just
    /// linear (linear task, a3 = a2 = 0)
    a3: Vec<f64>,
    a2: Vec<f64>,
    a1: Vec<f64>,
    bias: f64,
}

/// The Table 1 generator: `n_features` i.i.d. features from `dist`, target
/// from `target_fn` with coefficients drawn at construction (the paper
/// redraws them per repetition — use a fresh seed per repetition).
#[derive(Clone, Debug)]
pub struct SyntheticRegression {
    dist: Distribution,
    target_fn: TargetFn,
    noise: NoiseSpec,
    n_features: usize,
    coeffs: Coeffs,
    rng: Rng,
}

impl SyntheticRegression {
    pub fn new(
        dist: Distribution,
        target_fn: TargetFn,
        noise: NoiseSpec,
        n_features: usize,
        seed: u64,
    ) -> SyntheticRegression {
        let mut rng = Rng::new(seed);
        let mut draw = |_: usize| -> Vec<f64> {
            (0..n_features).map(|_| rng.uniform(-1.0, 1.0)).collect()
        };
        let a1 = draw(0);
        let (a3, a2) = match target_fn {
            TargetFn::Linear => (vec![0.0; n_features], vec![0.0; n_features]),
            TargetFn::Cubic => (draw(0), draw(0)),
        };
        let bias = rng.uniform(-1.0, 1.0);
        let coeffs = Coeffs { a3, a2, a1, bias };
        SyntheticRegression { dist, target_fn, noise, n_features, coeffs, rng }
    }

    /// Noiseless target value for a feature vector.
    pub fn clean_target(&self, x: &[f64]) -> f64 {
        let c = &self.coeffs;
        let mut y = c.bias;
        for (i, &xi) in x.iter().enumerate() {
            y += c.a1[i] * xi + c.a2[i] * xi * xi + c.a3[i] * xi * xi * xi;
        }
        y
    }
}

impl Stream for SyntheticRegression {
    fn next_instance(&mut self) -> Option<Instance> {
        let x: Vec<f64> = (0..self.n_features).map(|_| self.dist.sample(&mut self.rng)).collect();
        let mut y = self.clean_target(&x);
        if self.noise.fraction > 0.0 && self.rng.bool(self.noise.fraction) {
            y += self.rng.normal(0.0, self.noise.sigma);
        }
        Some(Instance { x, y })
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn name(&self) -> String {
        format!(
            "synth[{} {} noise={}%]",
            self.dist.label(),
            self.target_fn.label(),
            self.noise.fraction * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_nine_distributions() {
        assert_eq!(Distribution::table1().len(), 9);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SyntheticRegression::new(
            Distribution::Normal { mu: 0.0, sigma: 1.0 },
            TargetFn::Cubic,
            NoiseSpec::NONE,
            3,
            11,
        );
        let mut b = SyntheticRegression::new(
            Distribution::Normal { mu: 0.0, sigma: 1.0 },
            TargetFn::Cubic,
            NoiseSpec::NONE,
            3,
            11,
        );
        assert_eq!(a.take_vec(10), b.take_vec(10));
    }

    #[test]
    fn linear_target_is_linear() {
        let gen = SyntheticRegression::new(
            Distribution::Uniform { lo: -1.0, hi: 1.0 },
            TargetFn::Linear,
            NoiseSpec::NONE,
            2,
            3,
        );
        // f(x) - f(0) must be additive: f(a+b) - f(0) = (f(a)-f(0)) + (f(b)-f(0))
        let f0 = gen.clean_target(&[0.0, 0.0]);
        let fa = gen.clean_target(&[0.5, 0.0]) - f0;
        let fb = gen.clean_target(&[0.0, -0.25]) - f0;
        let fab = gen.clean_target(&[0.5, -0.25]) - f0;
        assert!((fab - (fa + fb)).abs() < 1e-12);
    }

    #[test]
    fn cubic_target_is_not_linear() {
        let gen = SyntheticRegression::new(
            Distribution::Uniform { lo: -1.0, hi: 1.0 },
            TargetFn::Cubic,
            NoiseSpec::NONE,
            1,
            5,
        );
        let f = |x: f64| gen.clean_target(&[x]);
        let lin_resid = f(0.8) - 2.0 * f(0.4) + f(0.0);
        assert!(lin_resid.abs() > 1e-6, "cubic should have curvature");
    }

    #[test]
    fn distribution_moments() {
        let mut rng = Rng::new(17);
        for dist in Distribution::table1() {
            let n = 50_000;
            let mut s = crate::stats::VarStats::new();
            for _ in 0..n {
                s.update(dist.sample(&mut rng), 1.0);
            }
            let expect_std = dist.scale();
            assert!(
                (s.std() - expect_std).abs() / expect_std < 0.1,
                "{}: std {} vs {}",
                dist.label(),
                s.std(),
                expect_std
            );
        }
    }

    #[test]
    fn noise_fraction_respected() {
        let dist = Distribution::Uniform { lo: -1.0, hi: 1.0 };
        let mut noisy = SyntheticRegression::new(
            dist,
            TargetFn::Linear,
            NoiseSpec { fraction: 0.1, sigma: 10.0 }, // huge sigma so noise is detectable
            1,
            23,
        );
        let coeffs_clone = noisy.clone();
        let mut corrupted = 0;
        for _ in 0..5000 {
            let inst = noisy.next_instance().unwrap();
            if (inst.y - coeffs_clone.clean_target(&inst.x)).abs() > 1e-9 {
                corrupted += 1;
            }
        }
        let frac = corrupted as f64 / 5000.0;
        assert!((frac - 0.1).abs() < 0.03, "fraction={frac}");
    }

    #[test]
    fn noise_sigma_tracks_dispersion() {
        let small = Distribution::Uniform { lo: -0.1, hi: 0.1 };
        let big = Distribution::Uniform { lo: -7.0, hi: 7.0 };
        assert_eq!(NoiseSpec::for_distribution(&small, 0.1).sigma, 0.01);
        assert_eq!(NoiseSpec::for_distribution(&big, 0.1).sigma, 0.1);
    }
}
