//! ASCII line/series plots: the bench harness renders the paper's figures
//! as terminal charts (the data series are also written to CSV/JSON for
//! external plotting).

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series { name: name.into(), points: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render multiple series on a shared log-x axis as an ASCII chart.
/// `log_y` plots log10(y) (the paper's Figure 1 rows 2-4 are log-scaled).
pub fn render_chart(title: &str, series: &[Series], width: usize, height: usize, log_x: bool, log_y: bool) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let tx = |v: f64| if log_x { v.max(1e-300).log10() } else { v };
    let ty = |v: f64| if log_y { v.max(1e-300).log10() } else { v };

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for s in series {
        for &(x, y) in &s.points {
            if y.is_finite() && x.is_finite() {
                xs.push(tx(x));
                ys.push(ty(y));
            }
        }
    }
    if xs.is_empty() {
        return format!("{title}\n  (no finite data)\n");
    }
    let (x_min, x_max) = bounds(&xs);
    let (y_min, y_max) = bounds(&ys);
    let x_span = (x_max - x_min).max(1e-12);
    let y_span = (y_max - y_min).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            if !(y.is_finite() && x.is_finite()) {
                continue;
            }
            let cx = (((tx(x) - x_min) / x_span) * (width - 1) as f64).round() as usize;
            let cy = (((ty(y) - y_min) / y_span) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let y_label = |frac: f64| -> f64 {
        let v = y_min + frac * y_span;
        if log_y {
            10f64.powf(v)
        } else {
            v
        }
    };
    for (i, row) in grid.iter().enumerate() {
        let frac = 1.0 - i as f64 / (height - 1) as f64;
        let label = if i == 0 || i == height - 1 || i == height / 2 {
            format!("{:>10.3e} |", y_label(frac))
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    let x_lo = if log_x { 10f64.powf(x_min) } else { x_min };
    let x_hi = if log_x { 10f64.powf(x_max) } else { x_max };
    out.push_str(&format!("{:>12}{:.3e}{:>pad$}{:.3e}\n", "", x_lo, "", x_hi, pad = width.saturating_sub(18)));
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

fn bounds(vals: &[f64]) -> (f64, f64) {
    let lo = vals.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_marks_and_legend() {
        let mut a = Series::new("qo");
        let mut b = Series::new("ebst");
        for i in 1..=10 {
            a.push(i as f64 * 100.0, i as f64);
            b.push(i as f64 * 100.0, (i * i) as f64);
        }
        let chart = render_chart("t", &[a, b], 40, 10, true, false);
        assert!(chart.contains('*') && chart.contains('o'));
        assert!(chart.contains("*=qo"));
        assert!(chart.contains("o=ebst"));
    }

    #[test]
    fn empty_series_no_panic() {
        let chart = render_chart("t", &[Series::new("x")], 20, 5, false, false);
        assert!(chart.contains("no finite data"));
    }

    #[test]
    fn non_finite_filtered() {
        let mut a = Series::new("x");
        a.push(1.0, f64::NEG_INFINITY);
        a.push(2.0, 1.0);
        let chart = render_chart("t", &[a], 20, 5, false, true);
        assert!(chart.contains('*'));
    }
}
