//! Zero-dependency substrate: the offline vendor set has no `rand`, `serde`,
//! `clap` or `criterion`, so this module provides the small, well-tested
//! pieces the rest of the crate needs.

pub mod b64;
pub mod cli;
pub mod fxhash;
pub mod json;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod timing;

pub use rng::Rng;
