//! Minimal JSON value + writer (no `serde` in the offline vendor set).
//!
//! Only what the bench reports need: construction, escaping, compact and
//! pretty serialization. Numbers serialize via `f64` with special-value
//! handling (`NaN`/`inf` become `null`, JSON has no representation).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic across runs.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — construction bug).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push into an array (panics on non-arrays — construction bug).
    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let mut o = Json::obj();
        o.set("b", 2.0).set("a", 1.5).set("s", "hi");
        assert_eq!(o.to_compact(), r#"{"a":1.5,"b":2,"s":"hi"}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut o = Json::obj();
        o.set("xs", vec![1.0, 2.5, 3.0]);
        let mut inner = Json::obj();
        inner.set("ok", true);
        o.set("inner", inner);
        assert_eq!(
            o.to_compact(),
            r#"{"inner":{"ok":true},"xs":[1,2.5,3]}"#
        );
    }

    #[test]
    fn escaping() {
        let j = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(j.to_compact(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_compact(), "null");
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let mut o = Json::obj();
        o.set("k", vec![1.0]);
        let p = o.to_pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"k\": ["));
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::Num(1_000_000.0).to_compact(), "1000000");
        assert_eq!(Json::Num(0.001).to_compact(), "0.001");
    }
}
