//! Minimal JSON value + writer + parser (no `serde` in the offline
//! vendor set).
//!
//! Construction, escaping, compact and pretty serialization for the bench
//! reports, plus the strict recursive-descent [`Json::parse`] the model
//! codec ([`crate::persist`]) and the serving protocol ([`crate::serve`])
//! need. Numbers serialize via `f64` with special-value handling
//! (`NaN`/`inf` become `null`, JSON has no representation); Rust's `f64`
//! Display prints the shortest string that parses back to the identical
//! bits, so write → parse round-trips numbers exactly — the property the
//! checkpoint codec's bit-for-bit contract rests on.
//!
//! The parser enforces a nesting-depth cap: it runs on bytes received
//! over TCP, and without the cap a few KB of `[[[[…` would overflow the
//! stack of whichever server thread parsed it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic across runs.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — construction bug).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push into an array (panics on non-arrays — construction bug).
    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // integral fast-path; −0.0 must keep its sign bit (the
                    // cast to i64 would drop it), so it takes the Display
                    // route, which prints "-0" and parses back exactly
                    if *v == v.trunc() && v.abs() < 1e15 && !v.is_sign_negative() {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse a complete JSON document (strict: one value, no trailing
    /// garbage, nesting capped at [`MAX_PARSE_DEPTH`]).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let value = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.error("trailing characters after the JSON value"));
        }
        Ok(value)
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(map) => Some(map),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Nesting depth the parser accepts before rejecting the document (the
/// codec's deepest structure is a handful of levels; network input must
/// not be able to pick the recursion depth).
pub const MAX_PARSE_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn error(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.error(&format!("expected {:?}", b as char)))
        }
    }

    /// Consume a keyword (`true`/`false`/`null`) whose first byte matched.
    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        let end = self.pos + word.len();
        if self.bytes.len() >= end && &self.bytes[self.pos..end] == word.as_bytes() {
            self.pos = end;
            Ok(value)
        } else {
            Err(self.error(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_PARSE_DEPTH {
            return Err(self.error("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.error("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.error(&format!("unexpected byte {:?}", b as char))),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.error("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.error("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.error("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.error("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: a low surrogate must follow
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                } else {
                                    return Err(self.error("lone high surrogate"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.error("invalid low surrogate"));
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code)
                                    .ok_or_else(|| self.error("invalid surrogate pair"))?
                            } else {
                                char::from_u32(hi)
                                    .ok_or_else(|| self.error("invalid \\u escape"))?
                            };
                            out.push(c);
                        }
                        other => {
                            return Err(self
                                .error(&format!("invalid escape {:?}", other as char)))
                        }
                    }
                }
                _ => {
                    // multi-byte UTF-8: copy the full code point verbatim
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.error("invalid UTF-8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if self.bytes.len() < end {
            return Err(self.error("truncated \\u escape"));
        }
        let chunk = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| self.error("invalid \\u escape"))?;
        let code = u32::from_str_radix(chunk, 16)
            .map_err(|_| self.error("invalid \\u escape"))?;
        self.pos = end;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let digits_from = self.pos;
        while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
            self.pos += 1;
        }
        if self.pos == digits_from {
            return Err(self.error("expected digits"));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let frac_from = self.pos;
            while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                self.pos += 1;
            }
            if self.pos == frac_from {
                return Err(self.error("expected fraction digits"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            let exp_from = self.pos;
            while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                self.pos += 1;
            }
            if self.pos == exp_from {
                return Err(self.error("expected exponent digits"));
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.error("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.error("invalid number"))
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let mut o = Json::obj();
        o.set("b", 2.0).set("a", 1.5).set("s", "hi");
        assert_eq!(o.to_compact(), r#"{"a":1.5,"b":2,"s":"hi"}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut o = Json::obj();
        o.set("xs", vec![1.0, 2.5, 3.0]);
        let mut inner = Json::obj();
        inner.set("ok", true);
        o.set("inner", inner);
        assert_eq!(
            o.to_compact(),
            r#"{"inner":{"ok":true},"xs":[1,2.5,3]}"#
        );
    }

    #[test]
    fn escaping() {
        let j = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(j.to_compact(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_compact(), "null");
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let mut o = Json::obj();
        o.set("k", vec![1.0]);
        let p = o.to_pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"k\": ["));
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::Num(1_000_000.0).to_compact(), "1000000");
        assert_eq!(Json::Num(0.001).to_compact(), "0.001");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").and_then(Json::as_str), Some("x"));
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\"b\\c\nd\u0041\u00e9""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA\u{e9}"));
        // surrogate pair: U+1F600
        let j = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
        // raw multi-byte UTF-8 passes through
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo"));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "[1] extra", "01x", "--1", "\"\\q\"", "{\"a\" 1}",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_rejects_deep_nesting() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn write_parse_roundtrip_is_exact() {
        let mut o = Json::obj();
        o.set("f", 0.1 + 0.2) // a value with a non-trivial shortest repr
            .set("neg", -1.2345678901234567e-300)
            .set("int", 123456789012345.0_f64)
            .set("s", "line\nbreak\t\"q\" héllo")
            .set("b", true)
            .set("xs", vec![1.5, 2.25, -0.0]);
        for text in [o.to_compact(), o.to_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, o, "round-trip through {text}");
        }
        // -0.0 keeps its sign bit through write → parse
        let j = Json::parse(&Json::Num(-0.0).to_compact()).unwrap();
        assert_eq!(j.as_f64().unwrap().to_bits(), (-0.0f64).to_bits());
    }
}
