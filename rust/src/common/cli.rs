//! Tiny CLI argument parser (`clap` is not in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [--key value | --flag] [positional...]`.
//! `--key=value` is accepted too. Unknown keys are kept and can be
//! validated by the subcommand.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// Panicking accessor for contexts with no error channel (bench
    /// binaries); the CLI proper goes through [`Args::try_usize`] so a
    /// malformed flag becomes usage + nonzero exit instead of a panic.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.try_usize(key, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking variant of [`Args::try_u64`] (see [`Args::usize_or`]).
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.try_u64(key, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Panicking variant of [`Args::try_f64`] (see [`Args::usize_or`]).
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.try_f64(key, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Non-panicking variant of [`Args::usize_or`]: a malformed value is
    /// a recoverable error the CLI turns into usage + nonzero exit.
    pub fn try_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Non-panicking variant of [`Args::u64_or`].
    pub fn try_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Millisecond option as a [`std::time::Duration`] (non-panicking,
    /// like [`Args::try_u64`]) — e.g. `--poll-ms 25`.
    pub fn try_ms(&self, key: &str, default_ms: u64) -> anyhow::Result<std::time::Duration> {
        Ok(std::time::Duration::from_millis(self.try_u64(key, default_ms)?))
    }

    /// Non-panicking variant of [`Args::f64_or`].
    pub fn try_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig1 extra --sizes 100,200 --seed=7 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.opt("sizes"), Some("100,200"));
        assert_eq!(a.opt("seed"), Some("7"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
        assert!(a.opt("verbose").is_none());
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 42 --r 0.5");
        assert_eq!(a.usize_or("n", 0), 42);
        assert_eq!(a.f64_or("r", 1.0), 0.5);
        assert_eq!(a.usize_or("missing", 9), 9);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = parse("x --n abc");
        a.usize_or("n", 0);
    }

    #[test]
    fn try_accessors_return_errors_instead_of_panicking() {
        let a = parse("x --n abc --r 0.5 --k 7");
        assert!(a.try_usize("n", 0).unwrap_err().to_string().contains("--n"));
        assert_eq!(a.try_usize("k", 0).unwrap(), 7);
        assert_eq!(a.try_usize("missing", 9).unwrap(), 9);
        assert_eq!(a.try_f64("r", 0.0).unwrap(), 0.5);
        assert!(a.try_f64("n", 0.0).is_err());
        assert_eq!(a.try_u64("k", 0).unwrap(), 7);
        assert!(a.try_u64("n", 0).is_err());
    }

    #[test]
    fn try_ms_parses_durations() {
        let a = parse("x --poll-ms 250 --bad abc");
        assert_eq!(a.try_ms("poll-ms", 25).unwrap().as_millis(), 250);
        assert_eq!(a.try_ms("missing", 25).unwrap().as_millis(), 25);
        assert!(a.try_ms("bad", 25).is_err());
    }
}
