//! FxHash-style hasher (the rustc-internal multiply-xor hash) for the
//! QO slot table. The std `HashMap` default (SipHash-1-3) is DoS-hardened
//! but ~3× slower on 8-byte integer keys; QO's keys are `i64` bucket
//! codes derived from the data, and the observer is not an adversarial
//! hash-flooding surface inside a tree leaf, so the fast hash is the
//! right trade (this is exactly what `rustc-hash` does; re-implemented
//! here because the offline vendor set lacks the crate).
//!
//! Measured effect: see EXPERIMENTS.md §Perf (QO observe path).

use std::hash::{BuildHasherDefault, Hasher};

/// `BuildHasher` plugging [`FxHasher`] into `HashMap`.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-xor hasher specialised for small integer keys.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for chunk in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(chunk.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add_to_hash(v);
    }

    #[inline]
    fn write_i64(&mut self, v: i64) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add_to_hash(v as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn hashmap_roundtrip() {
        let mut m: HashMap<i64, u32, FxBuildHasher> = HashMap::default();
        for k in -1000i64..1000 {
            m.insert(k, (k * 2) as u32);
        }
        assert_eq!(m.len(), 2000);
        for k in -1000i64..1000 {
            assert_eq!(m[&k], (k * 2) as u32);
        }
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        use std::hash::BuildHasher;
        let bh = FxBuildHasher::default();
        let mut hashes: Vec<u64> = (0i64..10_000).map(|k| bh.hash_one(k)).collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), 10_000, "collisions on sequential keys");
    }

    #[test]
    fn byte_writes_consistent() {
        use std::hash::BuildHasher;
        let bh = FxBuildHasher::default();
        // same bytes -> same hash
        assert_eq!(bh.hash_one([1u8, 2, 3]), bh.hash_one([1u8, 2, 3]));
        assert_ne!(bh.hash_one([1u8, 2, 3]), bh.hash_one([1u8, 2, 4]));
    }
}
