//! Minimal property-testing harness.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so this module
//! provides the subset the test suite needs: run a property over many
//! seeded random cases, and on failure report the failing case index and a
//! reproducible seed. No shrinking — failures print enough context to
//! reproduce deterministically with `case_seed`.

use super::rng::Rng;

/// Run `cases` random checks of `prop`. The property receives a fresh
/// deterministic [`Rng`] per case and returns `Err(description)` to fail.
///
/// Panics with the property name, case index and per-case seed on failure.
pub fn check<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {case}/{cases} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Helper for approximate float equality with a relative + absolute band.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    if a == b {
        return true;
    }
    if !(a.is_finite() && b.is_finite()) {
        return a == b || (a.is_nan() && b.is_nan());
    }
    (a - b).abs() <= atol + rtol * a.abs().max(b.abs())
}

/// `Err` unless `close(a, b, ...)`; formats a useful failure message.
pub fn expect_close(what: &str, a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if close(a, b, rtol, atol) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("sum-commutes", 1, 64, |rng| {
            let (a, b) = (rng.f64(), rng.f64());
            expect_close("a+b", a + b, b + a, 0.0, 0.0)
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 2, 8, |_| Err("nope".into()));
    }

    #[test]
    fn close_handles_special() {
        assert!(close(f64::NAN, f64::NAN, 0.1, 0.1));
        assert!(!close(f64::INFINITY, 1.0, 0.1, 0.1));
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!close(1.0, 1.1, 1e-3, 0.0));
    }
}
