//! ASCII table rendering for CLI reports and bench output.

/// A simple column-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, cell) in cells.iter().enumerate() {
                line.push(' ');
                line.push_str(cell);
                for _ in cell.chars().count()..widths[i] {
                    line.push(' ');
                }
                line.push_str(" |");
            }
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                for _ in 0..w + 2 {
                    s.push('-');
                }
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        let _ = ncol;
        out
    }

    /// Render as a CSV string (for the results/ directory).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float for display with adaptive precision.
pub fn fnum(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let abs = v.abs();
    if abs == 0.0 {
        "0".to_string()
    } else if abs >= 1e6 || abs < 1e-4 {
        format!("{v:.3e}")
    } else if abs >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["ao", "time"]);
        t.row(vec!["e-bst", "1.25"]).row(vec!["qo", "0.01"]);
        let r = t.render();
        assert!(r.contains("| ao    | time |"));
        assert!(r.contains("| e-bst | 1.25 |"));
        assert_eq!(r.lines().count(), 6);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234567.0), "1.235e6");
        assert_eq!(fnum(0.5), "0.5000");
        assert_eq!(fnum(250.0), "250.0");
        assert_eq!(fnum(1e-7), "1.000e-7");
    }
}
