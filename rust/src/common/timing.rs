//! Wall-clock measurement helpers and a small criterion-style bench runner
//! (the `criterion` crate is not in the offline vendor set; `cargo bench`
//! targets use `harness = false` and call [`bench()`](bench())).

use std::time::Instant;

/// Summary statistics of a set of timed iterations, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean > 0.0 {
            items_per_iter / self.mean
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} ± {} (min {}, max {}, n={})",
            human_time(self.mean),
            human_time(self.std),
            human_time(self.min),
            human_time(self.max),
            self.iters
        )
    }
}

/// Render a duration in adaptive units (ns/µs/ms/s).
pub fn human_time(secs: f64) -> String {
    let abs = secs.abs();
    if abs >= 1.0 {
        format!("{secs:.3}s")
    } else if abs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Time one invocation of `f`, returning (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let start = Instant::now();
    let out = f();
    (start.elapsed().as_secs_f64(), out)
}

/// Items-per-second throughput, with the zero-duration convention shared
/// by every fit report (`∞` rather than NaN/panic on a 0-second clock).
pub fn throughput(items: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        items as f64 / seconds
    } else {
        f64::INFINITY
    }
}

/// Criterion-style measurement: `warmup` unrecorded runs, then `iters`
/// recorded runs of `f`. The closure result is returned through a black-box
/// sink so the optimizer cannot delete the work.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let start = Instant::now();
        std::hint::black_box(f());
        samples.push(start.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Summarize raw per-iteration samples (seconds).
pub fn summarize(samples: &[f64]) -> BenchStats {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    BenchStats {
        iters: samples.len(),
        mean,
        std: var.sqrt(),
        min: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max: samples.iter().copied().fold(f64::NEG_INFINITY, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut calls = 0usize;
        let stats = bench(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.mean >= 0.0 && stats.min <= stats.max);
    }

    #[test]
    fn summarize_basic() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(2.5), "2.500s");
        assert_eq!(human_time(0.0025), "2.500ms");
        assert_eq!(human_time(2.5e-6), "2.500µs");
        assert_eq!(human_time(2.5e-9), "2.5ns");
    }

    #[test]
    fn throughput() {
        let s = BenchStats { iters: 1, mean: 0.5, std: 0.0, min: 0.5, max: 0.5 };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
    }
}
