//! Minimal standard base64 (RFC 4648, with `=` padding) — the offline
//! vendor set has no `base64` crate, and the serve layer needs to embed
//! binary checkpoint/delta payloads inside its NDJSON protocol
//! (`format: "binary"` replication, see `docs/FORMATS.md`).

use anyhow::{anyhow, Result};

const ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard padded base64.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity((bytes.len() + 2) / 3 * 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(triple >> 18) as usize & 0x3f] as char);
        out.push(ALPHABET[(triple >> 12) as usize & 0x3f] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(triple >> 6) as usize & 0x3f] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[triple as usize & 0x3f] as char
        } else {
            '='
        });
    }
    out
}

#[inline]
fn decode_char(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode standard padded base64. Strict: length must be a multiple of
/// four, padding only at the end, no whitespace.
pub fn decode(text: &str) -> Result<Vec<u8>> {
    let bytes = text.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("base64 length {} not a multiple of 4", bytes.len()));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, chunk) in bytes.chunks(4).enumerate() {
        let last = (i + 1) * 4 == bytes.len();
        let pad = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !last) {
            return Err(anyhow!("base64: misplaced padding"));
        }
        let mut triple: u32 = 0;
        for (j, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' && j >= 4 - pad {
                0
            } else {
                decode_char(c).ok_or_else(|| anyhow!("base64: invalid byte {c:#04x}"))?
            };
            triple = (triple << 6) | v;
        }
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn roundtrips_all_byte_values() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(1021).collect();
        assert_eq!(decode(&encode(&bytes)).unwrap(), bytes);
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(decode("abc").is_err(), "length not multiple of 4");
        assert!(decode("ab=c").is_err(), "padding inside a chunk");
        assert!(decode("Zg==Zg==").is_err(), "padding before the end");
        assert!(decode("Zm9 ").is_err(), "whitespace");
        assert!(decode("====").is_err(), "all padding");
    }
}
