//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64, with the float
//! and distribution helpers the stream generators need (uniform, normal via
//! Box–Muller, integers, shuffling).
//!
//! Every experiment in the repo is seeded, so runs are reproducible
//! bit-for-bit on the same platform.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; fast and with
/// excellent statistical quality for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Raw generator state — the xoshiro words plus the cached Box–Muller
    /// spare — for checkpointing ([`crate::persist`]). [`Rng::from_state`]
    /// restores a generator that continues the exact same sequence.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Rng {
        Rng { s, spare_normal }
    }

    /// Derive an independent child generator (for per-shard / per-cell
    /// streams) without correlating sequences.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method would be faster; modulo
    /// bias is negligible for the ranges used here, but we reject anyway).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar-free form, caches the spare).
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln is finite.
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma).
    #[inline]
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.standard_normal()
    }

    /// Poisson(λ) by Knuth's inversion: multiply uniforms until the
    /// product drops below e^{-λ}. Exact and O(λ) per draw — fine for the
    /// λ ≤ 10 used by online bagging (Oza & Russell 2001).
    ///
    /// Knuth's limit `e^{-λ}` underflows to 0.0 near λ ≈ 745, after which
    /// the loop only terminates once the uniform product itself underflows
    /// and returns a garbage count. Above [`Self::POISSON_SPLIT_THRESHOLD`]
    /// the draw is split via Poisson(λ) = Poisson(λ/2) + Poisson(λ/2)
    /// (exact: sums of independent Poissons are Poisson), keeping every
    /// inversion far from the underflow regime; above
    /// [`Self::POISSON_NORMAL_THRESHOLD`] (where the split would need
    /// λ/500 inversions, and where λ = ∞ would recurse without bound) the
    /// Normal(λ, λ) approximation takes over, saturating at `u64::MAX`.
    /// λ at or below the split threshold spends exactly the same random
    /// numbers as before, so seeded streams using bagging-scale λ are
    /// unchanged. NaN, zero and negative rates draw 0 events.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda.is_nan() || lambda <= 0.0 {
            return 0;
        }
        if lambda > Self::POISSON_NORMAL_THRESHOLD {
            // Beyond any bagging-scale rate the split trick stops being
            // affordable (λ/500 inversions per draw, each O(λ) work), and
            // λ = ∞ would recurse until the stack dies. Poisson(λ) is
            // asymptotically Normal(λ, λ) with relative error O(λ^{-1/2})
            // < 0.1% here; the cast saturates an overflowing draw to
            // u64::MAX (and ∞ − ∞ = NaN maps there explicitly).
            let draw = self.normal(lambda, lambda.sqrt()).round();
            return if draw.is_nan() { u64::MAX } else { draw.max(0.0) as u64 };
        }
        if lambda > Self::POISSON_SPLIT_THRESHOLD {
            let half = lambda * 0.5;
            return self.poisson(half) + self.poisson(half);
        }
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    /// λ above which [`Self::poisson`] splits the draw; e^{-500} ≈ 7e-218
    /// is still comfortably representable as a normal f64. The recursion
    /// depth is bounded by [`Self::POISSON_NORMAL_THRESHOLD`]:
    /// log2(1e6 / 500) ≈ 11 levels at most.
    pub const POISSON_SPLIT_THRESHOLD: f64 = 500.0;

    /// λ above which [`Self::poisson`] switches to the Normal(λ, λ)
    /// approximation (also the guard that keeps non-finite or absurd λ
    /// from recursing or looping forever).
    pub const POISSON_NORMAL_THRESHOLD: f64 = 1e6;

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform(-2.0, 4.0);
            assert!((-2.0..4.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal(3.0, 2.0);
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
        assert!((var - 4.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(17);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..1000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn poisson_moments_match_lambda() {
        let mut r = Rng::new(21);
        for lambda in [0.5, 1.0, 6.0] {
            let n = 100_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let v = r.poisson(lambda) as f64;
                s += v;
                s2 += v * v;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - lambda).abs() < 0.05 * lambda.max(1.0), "mean={mean} lambda={lambda}");
            assert!((var - lambda).abs() < 0.1 * lambda.max(1.0), "var={var} lambda={lambda}");
        }
    }

    #[test]
    fn poisson_large_lambda_moments_survive_the_underflow_regime() {
        // λ = 1000: e^{-λ} underflows to 0.0, so unsplit Knuth inversion
        // would loop until the product underflows and return garbage; the
        // split recursion must keep mean ≈ var ≈ λ
        let mut r = Rng::new(31);
        let lambda = 1000.0;
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.poisson(lambda) as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - lambda).abs() < 0.02 * lambda, "mean={mean}");
        assert!((var - lambda).abs() < 0.1 * lambda, "var={var}");
    }

    #[test]
    fn poisson_zero_lambda_is_zero() {
        let mut r = Rng::new(22);
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
        assert_eq!(r.poisson(f64::NAN), 0);
    }

    #[test]
    fn poisson_degenerate_lambda_terminates() {
        // λ = ∞ used to recurse until the stack died; it must saturate,
        // and absurd finite rates must come back ≈ λ without the split
        // recursion ever being asked for λ/500 inversions
        let mut r = Rng::new(27);
        assert_eq!(r.poisson(f64::INFINITY), u64::MAX);
        for _ in 0..100 {
            let lambda = 1e12;
            let v = r.poisson(lambda) as f64;
            // 5σ band around λ (σ = sqrt(λ) = 1e6)
            assert!((v - lambda).abs() < 5e6, "draw {v} too far from {lambda}");
        }
        assert!(r.poisson(1e300) > 0, "huge finite rate must still terminate");
    }

    #[test]
    fn state_roundtrip_continues_the_sequence() {
        let mut a = Rng::new(77);
        a.normal(0.0, 1.0); // leave a cached spare in the state
        let (s, spare) = a.state();
        let mut b = Rng::from_state(s, spare);
        for _ in 0..10 {
            assert_eq!(a.normal(0.0, 1.0).to_bits(), b.normal(0.0, 1.0).to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(23);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
