//! `serve/` — an online learn/predict TCP server with model checkpointing
//! and hot-swapped read snapshots. Pure `std::net`; no runtime deps.
//!
//! ## Architecture
//!
//! A single **trainer thread** owns the mutable model and consumes
//! `learn` requests from a bounded channel (the same
//! backpressure-over-`sync_channel` shape as [`crate::coordinator`]: a
//! full queue blocks the producing connection, it never balloons).
//! **Reader threads** (one per TCP connection) answer `predict` /
//! `predict_batch` from an immutable `Arc` **snapshot** of the model that
//! the trainer atomically hot-swaps every `snapshot_every` applied
//! learns. The swap is an `Arc` pointer store behind an `RwLock` held
//! for nanoseconds — reads never wait on training, and training never
//! waits on reads.
//!
//! Snapshots are **structural clones**: model state lives behind `Arc`s
//! (leaf subtrees, observer factories), so publishing is O(touched) —
//! pointer bumps now, copy-on-write later at the next learn that touches
//! a leaf — instead of the encode → decode codec round-trip earlier
//! revisions ran per publication (still available as
//! [`crate::persist::Model::clone_via_codec`]). The canonical checkpoint
//! document is materialized lazily, only when replication or an explicit
//! `snapshot` asks for it ([`publish`]); codec fidelity is re-proven at
//! every materialization, where debug builds also audit the document.
//!
//! ## Wire protocol — newline-delimited JSON
//!
//! One request per line, one JSON response per line, in order:
//!
//! | request | response |
//! |---|---|
//! | `{"cmd":"learn","x":[…],"y":1.5}` | `{"ok":true}` (acks the *enqueue*) |
//! | `{"cmd":"predict","x":[…]}` | `{"ok":true,"prediction":p}` |
//! | `{"cmd":"predict_batch","xs":[[…],…]}` | `{"ok":true,"predictions":[…]}` |
//! | `{"cmd":"snapshot"}` | `{"ok":true,"checkpoint":{…},"version":…}` (a [`crate::persist`] document) |
//! | `{"cmd":"stats"}` | `{"ok":true,"model":…,"learns_applied":…,"snapshot_version":…,"snapshot_age_learns":…,…}` |
//! | `{"cmd":"repl_sync","have":…[,"format":"binary"]}` | `{"ok":true,"version":…,"hash":…,` one of `"up_to_date"/"deltas"/"full"}` (binary: `"full_b64"` / per-delta `"ops_b64"`, see `docs/FORMATS.md`) |
//! | `{"cmd":"metrics"}` | `{"ok":true,"format":"prometheus","text":"…"}` ([`crate::obs`] exposition) |
//! | `{"cmd":"metrics_raw"}` | `{"ok":true,"snapshot":{…}}` (mergeable [`crate::obs::RegistrySnapshot`] — what [`fleet`] scrapes) |
//! | `{"cmd":"health"}` | `{"ok":true,"status":"ok"/"degraded","role":…,"snapshot_version":…,"staleness_learns":…,"mem_bytes":…,"uptime_secs":…,"reasons":[…]}` |
//! | `{"cmd":"trace_splits"[,"limit":n]}` | `{"ok":true,"total":…,"capacity":…,"events":[{"outcome":…,"merit_gap":…,"slots_evaluated":…,"elapsed_ns":…},…]}` (newest first) |
//! | `{"cmd":"trace_repl"[,"limit":n]}` | `{"ok":true,"total":…,"capacity":…,"events":[{"version":…,"learns":…,"span_ns":…,"full":…},…]}` (newest first) |
//! | `{"cmd":"shutdown"}` | `{"ok":true}`, then the server stops |
//!
//! Malformed lines, unknown commands, dimension mismatches and
//! non-finite inputs get `{"ok":false,"error":"…"}` — the connection
//! stays usable. Predictions are serialized with shortest-round-trip
//! float formatting, so the `f64` a client parses is bit-identical to
//! the one the model produced.
//!
//! ## Consistency guarantees
//!
//! * **Learn → snapshot (same connection):** `snapshot` travels through
//!   the same FIFO trainer queue as `learn`, so a checkpoint reflects
//!   every learn the same connection acked before it (and it also
//!   publishes, so subsequent predicts see at least that state).
//! * **Learn → predict (same connection):** predicts are served from the
//!   last *published* snapshot, which trails the live model by at most
//!   `snapshot_every` applied learns — the documented staleness window.
//!   Issue `snapshot` to force publication when a read-your-writes point
//!   is needed.
//! * **Restore:** a fresh server started from a checkpoint returns
//!   bit-identical predictions to the server that produced it (enforced
//!   end-to-end in `rust/tests/serve_e2e.rs`).
//!
//! ## Replication (see [`replicate`])
//!
//! A leader publishes versioned **delta checkpoints** from its snapshot
//! machinery ([`crate::persist::delta`]); follower replicas poll
//! `repl_sync`, apply the exact diffs to their mirrored document, and
//! answer reads bit-identically to the leader at every applied version.
//! With `ServeOptions::shards > 1` the leader's trainer fans micro-batches
//! out over the sharded forest machinery, so one endpoint fronts a
//! sharded ARF/bagging fleet while followers scale the read path.
//!
//! ## Observability (see `docs/OBSERVABILITY.md`)
//!
//! Both roles serve the full metric catalog (`metrics` /
//! `metrics_raw`), structured `health`, and the trace rings
//! (`trace_splits` / `trace_repl`). Followers additionally record live
//! learn→serve **freshness spans** per applied version. The [`fleet`]
//! aggregator discovers a leader's followers, scrapes every node, and
//! merges the histograms *exactly* into one fleet-wide exposition.
//!
//! ## Memory governance (see `docs/MEMORY.md`)
//!
//! With [`ServeOptions::mem_budget`] set, the trainer runs the
//! [`crate::govern`] escalation ladder at every snapshot publication,
//! *before* the structural clone — so read snapshots, staged
//! replication deltas, checkpoints, and the debug-build audit only ever
//! see a model inside the budget. Followers inherit the governed state
//! through ordinary deltas (no protocol change); `stats` reports
//! `mem_bytes` / `mem_budget` / `over_budget`, and an unmeetable budget
//! degrades `health` instead of crashing the server.

pub mod client;
pub mod fleet;
pub mod publish;
pub mod replicate;
pub mod server;

pub use client::ServeClient;
pub use replicate::{Follower, FollowerOptions};
pub use server::{Server, ServeOptions};
