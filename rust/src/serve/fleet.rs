//! Fleet-wide scrape aggregation: one Prometheus endpoint for a whole
//! leader + follower deployment (`qostream fleet`, and the e2e tests).
//!
//! ## What it does
//!
//! Given a seed list of `HOST:PORT` serve endpoints, the aggregator
//!
//! 1. **discovers** the rest of the fleet: every seed that answers
//!    `stats` with a `followers` array (a leader — followers advertise
//!    their serve address on each `repl_sync` poll, see
//!    [`super::publish::Replication::note_follower`]) contributes those
//!    addresses to the target set;
//! 2. **scrapes** each node over the existing NDJSON protocol —
//!    `health` for role/status/staleness and `metrics_raw` for the full
//!    registry as a mergeable [`RegistrySnapshot`];
//! 3. **merges exactly**: histograms travel as raw log2 buckets, so
//!    fleet-level quantiles come from *summed buckets*, not from
//!    averaging per-node quantiles (which is statistically meaningless).
//!    The merged output is bit-identical to capturing one registry that
//!    saw every node's recordings (property-tested in
//!    `rust/tests/fleet_e2e.rs`);
//! 4. **renders** one exposition: the merged registry families followed
//!    by per-node `qostream_node_*` gauges labelled
//!    `{node="HOST:PORT",role="leader|follower"}`, plus
//!    `qostream_fleet_nodes` / `qostream_fleet_nodes_up` totals. An
//!    unreachable node stays in the output as `qostream_node_up 0` —
//!    silently dropping a dead replica is how staleness hides.
//!
//! The text dashboard ([`FleetScrape::dashboard`], `qostream fleet
//! --top`) shows the same per-node view as an ASCII table. The metric
//! catalog, label scheme and scrape topology are documented in
//! `docs/OBSERVABILITY.md`.
//!
//! ## Serving scrapes
//!
//! [`serve_scrapes`] answers plain HTTP `GET` with the fleet exposition
//! (`text/plain; version=0.0.4`), so a stock Prometheus can scrape one
//! aggregator instead of N nodes. The server is deliberately minimal —
//! request head read and discarded, one response per connection — and,
//! like every connection path in `serve/`, it must never panic on peer
//! input (enforced by `LINT_UNWRAP_CONN`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::common::json::Json;
use crate::common::table::{fnum, Table};
use crate::obs::RegistrySnapshot;
use crate::persist::codec::pu64;

use super::client::ServeClient;

/// Registry family names the per-node columns are derived from.
const FRESHNESS_FAMILY: &str = "qostream_repl_freshness_seconds";
const LEARN_RATE_FAMILY: &str = "qostream_serve_learn_rate";

/// One node's scrape result. `up == false` means the node was
/// unreachable or answered garbage — identity fields then keep their
/// zero/`"?"` defaults and `snapshot` is `None`.
#[derive(Clone, Debug)]
pub struct NodeScrape {
    pub addr: String,
    pub up: bool,
    /// `leader` / `follower` as self-reported by `health`.
    pub role: String,
    /// `ok` / `degraded` as self-reported by `health`.
    pub status: String,
    pub snapshot_version: u64,
    pub staleness_learns: u64,
    pub mem_bytes: u64,
    pub uptime_secs: u64,
    /// The node's full registry ([`RegistrySnapshot`]) for exact merging.
    pub snapshot: Option<RegistrySnapshot>,
}

impl NodeScrape {
    fn down(addr: &str) -> NodeScrape {
        NodeScrape {
            addr: addr.to_string(),
            up: false,
            role: "?".to_string(),
            status: "down".to_string(),
            snapshot_version: 0,
            staleness_learns: 0,
            mem_bytes: 0,
            uptime_secs: 0,
            snapshot: None,
        }
    }

    /// Live freshness p99 in seconds from this node's own histogram
    /// (`None` when the node is down or has recorded no applies).
    pub fn freshness_p99_secs(&self) -> Option<f64> {
        let hist = self.snapshot.as_ref()?.summary_hist(FRESHNESS_FAMILY)?;
        if hist.count == 0 {
            return None;
        }
        Some(hist.quantile(0.99) as f64 / 1e9)
    }

    /// Learns/sec over the node's 1m window (`None` when down; 0.0 on a
    /// follower, which never learns).
    pub fn learns_per_sec(&self) -> Option<f64> {
        self.snapshot.as_ref()?.rate(LEARN_RATE_FAMILY, "1m")
    }
}

/// A whole fleet's scrape: per-node rows plus the exactly merged
/// registry (`None` when no node was reachable).
#[derive(Clone, Debug)]
pub struct FleetScrape {
    pub nodes: Vec<NodeScrape>,
    pub merged: Option<RegistrySnapshot>,
    /// Snapshots that could not be merged (family-set drift in a
    /// mixed-version fleet). Surfaced rather than silently dropped.
    pub merge_skipped: usize,
}

/// Expand a seed target list with every follower the seeds' leaders
/// know about. Order is deterministic: seeds first (as given), then
/// discovered followers in leader-reported order; duplicates dropped.
/// Unreachable seeds stay in the list — the scrape marks them down.
pub fn discover(seeds: &[String]) -> Vec<String> {
    let mut targets: Vec<String> = Vec::new();
    let mut push_unique = |targets: &mut Vec<String>, addr: &str| {
        if !addr.is_empty() && !targets.iter().any(|t| t == addr) {
            targets.push(addr.to_string());
        }
    };
    for seed in seeds {
        push_unique(&mut targets, seed);
        let Ok(mut client) = ServeClient::connect(seed.as_str()) else { continue };
        let Ok(stats) = client.stats() else { continue };
        let Some(followers) = stats.get("followers").and_then(Json::as_arr) else {
            continue; // a follower seed (or an old leader): nothing to expand
        };
        for f in followers {
            if let Some(addr) = f.as_str() {
                push_unique(&mut targets, addr);
            }
        }
    }
    targets
}

/// Scrape one node: `health` + `metrics_raw` over one connection. Never
/// errors — an unreachable or malformed node comes back as
/// [`NodeScrape::down`], because the aggregate must keep rendering when
/// part of the fleet is on fire.
pub fn scrape_node(addr: &str) -> NodeScrape {
    match try_scrape(addr) {
        Ok(node) => node,
        Err(_) => NodeScrape::down(addr),
    }
}

fn try_scrape(addr: &str) -> Result<NodeScrape> {
    let mut client = ServeClient::connect(addr)?;
    let health = client.health()?;
    let snapshot = RegistrySnapshot::from_json(&client.metrics_raw()?)?;
    let text = |key: &str| -> String {
        health.get(key).and_then(Json::as_str).unwrap_or("?").to_string()
    };
    let num = |key: &str| -> u64 {
        health.get(key).and_then(|j| pu64(j, key).ok()).unwrap_or(0)
    };
    Ok(NodeScrape {
        addr: addr.to_string(),
        up: true,
        role: text("role"),
        status: text("status"),
        snapshot_version: num("snapshot_version"),
        staleness_learns: num("staleness_learns"),
        mem_bytes: num("mem_bytes"),
        uptime_secs: num("uptime_secs"),
        snapshot: Some(snapshot),
    })
}

/// Scrape every target and merge the reachable registries exactly.
pub fn scrape_fleet(targets: &[String]) -> FleetScrape {
    let nodes: Vec<NodeScrape> = targets.iter().map(|t| scrape_node(t)).collect();
    let mut merged: Option<RegistrySnapshot> = None;
    let mut merge_skipped = 0usize;
    for node in &nodes {
        let Some(snap) = &node.snapshot else { continue };
        merged = Some(match merged.take() {
            None => snap.clone(),
            Some(acc) => match acc.merge(snap) {
                Ok(m) => m,
                Err(_) => {
                    merge_skipped += 1;
                    acc
                }
            },
        });
    }
    FleetScrape { nodes, merged, merge_skipped }
}

/// Escape a Prometheus label value (`\`, `"`, newline).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

impl FleetScrape {
    /// The fleet exposition: merged registry families, then fleet and
    /// per-node gauges. One scrape endpoint for the whole deployment.
    pub fn exposition(&self) -> String {
        let mut out = String::with_capacity(16 * 1024);
        if let Some(merged) = &self.merged {
            out.push_str(&merged.exposition());
        }
        let up = self.nodes.iter().filter(|n| n.up).count();
        out.push_str("# HELP qostream_fleet_nodes Scrape targets in the fleet.\n");
        out.push_str("# TYPE qostream_fleet_nodes gauge\n");
        out.push_str(&format!("qostream_fleet_nodes {}\n", self.nodes.len()));
        out.push_str("# HELP qostream_fleet_nodes_up Targets that answered the scrape.\n");
        out.push_str("# TYPE qostream_fleet_nodes_up gauge\n");
        out.push_str(&format!("qostream_fleet_nodes_up {up}\n"));
        self.node_family(&mut out, "qostream_node_up", "1 when the node answered.", |n| {
            Some(if n.up { "1".to_string() } else { "0".to_string() })
        });
        self.node_family(
            &mut out,
            "qostream_node_staleness_learns",
            "Learns the node's served snapshot trails the live model.",
            |n| n.up.then(|| n.staleness_learns.to_string()),
        );
        self.node_family(
            &mut out,
            "qostream_node_mem_bytes",
            "Resident model size the node reports.",
            |n| n.up.then(|| n.mem_bytes.to_string()),
        );
        self.node_family(
            &mut out,
            "qostream_node_snapshot_version",
            "Snapshot version the node currently serves.",
            |n| n.up.then(|| n.snapshot_version.to_string()),
        );
        self.node_family(
            &mut out,
            "qostream_node_uptime_secs",
            "Node process uptime in seconds.",
            |n| n.up.then(|| n.uptime_secs.to_string()),
        );
        self.node_family(
            &mut out,
            "qostream_node_freshness_p99_seconds",
            "Node-local publish-to-apply freshness p99 (followers only).",
            |n| n.freshness_p99_secs().map(|v| format!("{v}")),
        );
        self.node_family(
            &mut out,
            "qostream_node_learns_per_sec",
            "Learns/sec over the node's 1m window.",
            |n| n.learns_per_sec().map(|v| format!("{v}")),
        );
        out
    }

    /// Render one per-node gauge family; nodes where `value` returns
    /// `None` are skipped (e.g. freshness on a leader).
    fn node_family(
        &self,
        out: &mut String,
        name: &str,
        help: &str,
        value: impl Fn(&NodeScrape) -> Option<String>,
    ) {
        let samples: Vec<(String, String)> = self
            .nodes
            .iter()
            .filter_map(|n| {
                value(n).map(|v| {
                    let labels = format!(
                        "node=\"{}\",role=\"{}\"",
                        escape_label(&n.addr),
                        escape_label(&n.role)
                    );
                    (labels, v)
                })
            })
            .collect();
        if samples.is_empty() {
            return;
        }
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        for (labels, v) in samples {
            out.push_str(&format!("{name}{{{labels}}} {v}\n"));
        }
    }

    /// The `--top` view: one ASCII table row per node.
    pub fn dashboard(&self) -> String {
        let mut t = Table::new(vec![
            "node",
            "role",
            "status",
            "version",
            "stale(learns)",
            "mem_bytes",
            "fresh_p99_s",
            "learns/s",
            "uptime_s",
        ]);
        let or_dash = |v: Option<f64>| v.map(fnum).unwrap_or_else(|| "-".to_string());
        for n in &self.nodes {
            t.row(vec![
                n.addr.clone(),
                n.role.clone(),
                n.status.clone(),
                n.snapshot_version.to_string(),
                n.staleness_learns.to_string(),
                n.mem_bytes.to_string(),
                or_dash(n.freshness_p99_secs()),
                or_dash(n.learns_per_sec()),
                n.uptime_secs.to_string(),
            ]);
        }
        let up = self.nodes.iter().filter(|n| n.up).count();
        let mut out = t.render();
        out.push_str(&format!("nodes: {}  up: {up}", self.nodes.len()));
        if self.merge_skipped > 0 {
            out.push_str(&format!("  UNMERGED: {}", self.merge_skipped));
        }
        out.push('\n');
        out
    }
}

/// Answer HTTP `GET`s on `listener` with a fresh fleet exposition per
/// request. `seeds` is re-discovered on every scrape when
/// `auto_discover` is set, so followers that join later appear without
/// restarting the aggregator. Runs until the listener errors terminally
/// (per-connection errors are swallowed — a broken scraper connection
/// must not kill the endpoint).
pub fn serve_scrapes(listener: TcpListener, seeds: Vec<String>, auto_discover: bool) {
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        let targets = if auto_discover { discover(&seeds) } else { seeds.clone() };
        let body = scrape_fleet(&targets).exposition();
        answer_http(stream, &body).ok();
    }
}

/// Drain one HTTP request head and write a 200 with `body`. The method
/// and path are ignored — every request gets the exposition, which is
/// exactly what a Prometheus scrape config needs and nothing more.
fn answer_http(stream: TcpStream, body: &str) -> Result<()> {
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("setting scrape read timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning scrape conn")?);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading request head")?;
        if n == 0 || line.trim_end().is_empty() {
            break; // end of head (or peer hung up) — answer anyway
        }
    }
    let mut stream = stream;
    let response = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes()).context("writing scrape response")?;
    stream.flush().context("flushing scrape response")?;
    Ok(())
}

/// Read a full HTTP response from `stream` and return its body — test
/// helper for the scrape endpoint (kept here so the e2e tests and any
/// future CLI probe share one implementation).
pub fn read_http_body(stream: TcpStream) -> Result<String> {
    let mut reader = BufReader::new(stream);
    let mut head = String::new();
    let mut content_length: Option<usize> = None;
    loop {
        head.clear();
        let n = reader.read_line(&mut head).context("reading response head")?;
        if n == 0 {
            return Err(anyhow::anyhow!("connection closed before response body"));
        }
        let trimmed = head.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some(v) = trimmed
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(str::trim)
            .and_then(|v| v.parse::<usize>().ok())
        {
            content_length = Some(v);
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(len) => {
            body.resize(len, 0);
            reader.read_exact(&mut body).context("reading response body")?;
        }
        None => {
            reader.read_to_end(&mut body).context("reading response body")?;
        }
    }
    String::from_utf8(body).context("response body is not UTF-8")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Metrics;

    fn fake_node(addr: &str, role: &str, staleness: u64) -> NodeScrape {
        let m = Box::leak(Box::new(Metrics::new()));
        m.serve_learn_ns.record(1_000);
        m.repl_freshness_ns.record(40_000_000); // 40ms
        NodeScrape {
            addr: addr.to_string(),
            up: true,
            role: role.to_string(),
            status: "ok".to_string(),
            snapshot_version: 7,
            staleness_learns: staleness,
            mem_bytes: 1024,
            uptime_secs: 12,
            snapshot: Some(RegistrySnapshot::capture(m)),
        }
    }

    #[test]
    fn exposition_labels_every_node_and_counts_the_fleet() {
        let fleet = FleetScrape {
            nodes: vec![
                fake_node("10.0.0.1:7000", "leader", 0),
                fake_node("10.0.0.2:7001", "follower", 5),
                NodeScrape::down("10.0.0.3:7002"),
            ],
            merged: None,
            merge_skipped: 0,
        };
        let text = fleet.exposition();
        assert!(text.contains("qostream_fleet_nodes 3\n"));
        assert!(text.contains("qostream_fleet_nodes_up 2\n"));
        assert!(text.contains(
            "qostream_node_up{node=\"10.0.0.1:7000\",role=\"leader\"} 1\n"
        ));
        assert!(text.contains(
            "qostream_node_up{node=\"10.0.0.3:7002\",role=\"?\"} 0\n"
        ));
        assert!(text.contains(
            "qostream_node_staleness_learns{node=\"10.0.0.2:7001\",role=\"follower\"} 5\n"
        ));
        // a down node contributes up=0 but no other samples
        assert!(!text.contains("qostream_node_mem_bytes{node=\"10.0.0.3:7002\""));
        // every emitted family carries HELP + TYPE
        for family in ["qostream_node_up", "qostream_node_freshness_p99_seconds"] {
            assert!(text.contains(&format!("# HELP {family} ")));
            assert!(text.contains(&format!("# TYPE {family} gauge\n")));
        }
    }

    #[test]
    fn freshness_p99_reads_the_node_histogram() {
        let node = fake_node("a:1", "follower", 0);
        let p99 = node.freshness_p99_secs().expect("histogram has one sample");
        // one 40ms sample lands in a log2 bucket whose upper bound is
        // < 2x the value; the quantile over-reports inside that bound
        assert!(p99 >= 0.04 && p99 < 0.08, "p99 {p99}");
        assert_eq!(NodeScrape::down("b:2").freshness_p99_secs(), None);
    }

    #[test]
    fn dashboard_renders_a_row_per_node() {
        let fleet = FleetScrape {
            nodes: vec![fake_node("a:1", "leader", 0), NodeScrape::down("b:2")],
            merged: None,
            merge_skipped: 1,
        };
        let text = fleet.dashboard();
        assert!(text.contains("| a:1"));
        assert!(text.contains("| b:2"));
        assert!(text.contains("down"));
        assert!(text.contains("nodes: 2  up: 1  UNMERGED: 1"));
    }

    #[test]
    fn label_escaping_is_prometheus_safe() {
        assert_eq!(escape_label(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape_label("x\ny"), "x\\ny");
    }

    #[test]
    fn http_body_roundtrip_over_a_socketpair() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let body = "qostream_fleet_nodes 1\n".to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            answer_http(stream, &body).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let got = read_http_body(stream).unwrap();
        assert_eq!(got, "qostream_fleet_nodes 1\n");
        server.join().unwrap();
    }

    #[test]
    fn merge_skip_keeps_the_accumulated_registry() {
        // a snapshot with a different family count cannot merge; the
        // fleet keeps what it has and counts the skip
        let good = fake_node("a:1", "leader", 0);
        let mut bad = fake_node("b:2", "follower", 0);
        if let Some(s) = &mut bad.snapshot {
            s.families.pop();
        }
        let nodes = vec![good, bad];
        let mut merged: Option<RegistrySnapshot> = None;
        let mut skipped = 0;
        for n in &nodes {
            let Some(snap) = &n.snapshot else { continue };
            merged = Some(match merged.take() {
                None => snap.clone(),
                Some(acc) => match acc.merge(snap) {
                    Ok(m) => m,
                    Err(_) => {
                        skipped += 1;
                        acc
                    }
                },
            });
        }
        let merged = merged.expect("first snapshot always seeds the merge");
        assert_eq!(skipped, 1);
        assert_eq!(merged.families.len(), crate::obs::CATALOG.len());
    }
}
