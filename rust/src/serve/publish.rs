//! Zero-copy snapshot publication: the leader's structural-clone
//! hot-swap plus the lazily materialized replication log.
//!
//! The publish path used to run the full codec round-trip — encode the
//! live model to its canonical JSON document, audit it, decode it back,
//! swap the decoded clone in as the read snapshot — on every
//! `snapshot_every` boundary, an O(model) tax per publication. Model
//! state is now shared behind `Arc`s (leaf subtrees, observer factories,
//! criteria), so `Model::clone()` is O(nodes) pointer bumps with the
//! deep copies deferred to the next learn that actually touches a leaf
//! (copy-on-write at the single mutation point). The trainer therefore
//! publishes in O(touched) and *stages* the same `Arc` here; the
//! canonical document is only materialized when something actually needs
//! it — a `repl_sync` poll, an explicit `snapshot` request, or the bench
//! suite reading the log.
//!
//! Staging overwrites: only the newest staged state is ever encoded, so
//! a burst of publications between two follower polls costs one codec
//! pass, not one per boundary. Replication stays defined over
//! *materialized* versions ([`DeltaLog`] semantics are unchanged);
//! followers simply observe a coarser version sequence when they poll
//! less often than the leader publishes.
//!
//! This module also owns the `format:"binary"` side of the `repl_sync`
//! negotiation: when a follower asks for it, sync payloads are embedded
//! as base64 [`crate::persist::binary`] envelopes instead of inline JSON
//! (`full_b64` / per-delta `ops_b64`, see `docs/FORMATS.md`). Decoding a
//! binary envelope reproduces the canonical document bit-for-bit, so the
//! follower's hash verification pipeline is format-agnostic.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::common::b64;
use crate::common::json::Json;
use crate::persist::binary;
use crate::persist::delta::{DeltaLog, SyncPayload};
use crate::persist::Model;

use super::server::lock_poisoned;

/// The leader's replication state: staged-but-unencoded model state plus
/// the versioned delta log it materializes into.
pub struct Replication {
    /// Model state staged by the trainer's last publication (paired
    /// with the cumulative acked learns it covers), not yet encoded
    /// into the log (`None` = the log is current). Overwritten by newer
    /// stages; taken under [`Replication::materialize`]'s log lock so
    /// materializers cannot publish out of order.
    staged: Mutex<Option<(Arc<Model>, u64)>>,
    /// The versioned delta log, fed at materialize time.
    log: Mutex<DeltaLog>,
    /// Serve addresses followers advertised on `repl_sync` polls, by
    /// last-seen instant. Fleet tooling discovers a leader's whole
    /// fleet from this (the `followers` array in `stats`); entries not
    /// seen within [`FOLLOWER_TTL`] are pruned.
    followers: Mutex<Vec<(String, Instant)>>,
}

/// How long an advertised follower address stays listed after its last
/// `repl_sync` poll. Generous against slow poll intervals; small enough
/// that a dead follower drops out of discovery within a minute.
pub const FOLLOWER_TTL: Duration = Duration::from_secs(60);

impl Replication {
    pub fn new(log: DeltaLog) -> Replication {
        Replication {
            staged: Mutex::new(None),
            log: Mutex::new(log),
            followers: Mutex::new(Vec::new()),
        }
    }

    /// Record (or refresh) a follower's advertised serve address.
    pub fn note_follower(&self, addr: &str) {
        if addr.is_empty() || addr.len() > 256 {
            return; // advisory field; never let a peer bloat the registry
        }
        let now = Instant::now();
        let mut followers = lock_poisoned(&self.followers);
        match followers.iter_mut().find(|(a, _)| a.as_str() == addr) {
            Some((_, seen)) => *seen = now,
            None => followers.push((addr.to_string(), now)),
        }
        followers.retain(|(_, seen)| now.duration_since(*seen) < FOLLOWER_TTL);
    }

    /// Advertised follower addresses seen within [`FOLLOWER_TTL`].
    pub fn followers(&self) -> Vec<String> {
        let now = Instant::now();
        lock_poisoned(&self.followers)
            .iter()
            .filter(|(_, seen)| now.duration_since(*seen) < FOLLOWER_TTL)
            .map(|(a, _)| a.clone())
            .collect()
    }

    /// Stage freshly published model state (trainer thread) together
    /// with the cumulative acked learns it covers. Cheap — a pointer
    /// store — and never blocks on an encode in progress, which holds
    /// the *other* lock. The publish instant is stamped at materialize
    /// time, when the version becomes observable to followers — that is
    /// the instant freshness spans measure from.
    pub fn stage(&self, model: Arc<Model>, learns: u64) {
        *lock_poisoned(&self.staged) = Some((model, learns));
    }

    /// The delta log as-is, **without** materializing staged state.
    /// Readout for benches/tests; protocol paths want
    /// [`Replication::materialize`].
    pub fn log(&self) -> MutexGuard<'_, DeltaLog> {
        lock_poisoned(&self.log)
    }

    /// Encode any staged model into the log and return the (now current)
    /// log. The log lock is held across take + encode + publish so
    /// concurrent materializers serialize and versions stay monotonic;
    /// the trainer's [`Replication::stage`] only touches the staged slot,
    /// so publishing never waits on an encode here.
    pub fn materialize(&self) -> Result<MutexGuard<'_, DeltaLog>, String> {
        let mut log = lock_poisoned(&self.log);
        // take() in its own statement: an `if let` scrutinee would keep
        // the staged guard alive across the whole block (temporary
        // lifetime extension) and deadlock the error path's re-lock
        let staged = lock_poisoned(&self.staged).take();
        if let Some((model, learns)) = staged {
            let doc = match encode_staged(&model) {
                Ok(doc) => doc,
                Err(e) => {
                    // keep the state for the next attempt unless the
                    // trainer staged something newer meanwhile
                    let mut slot = lock_poisoned(&self.staged);
                    if slot.is_none() {
                        *slot = Some((model, learns));
                    }
                    return Err(e);
                }
            };
            let (_, changed) = log.publish_with(doc, learns, crate::obs::window::now_unix_us());
            if changed {
                if let Some(m) = crate::obs::m() {
                    m.snapshot_bytes_json.add(log.full_bytes() as u64);
                    if let Some(entry) = log.entries().last() {
                        m.serve_delta_publish_bytes.record(entry.delta_bytes as u64);
                    }
                }
            }
        }
        Ok(log)
    }
}

/// Canonical document of a staged model; debug builds audit it before it
/// can reach followers or `snapshot` clients (docs/INVARIANTS.md) — the
/// same gate the eager publish path used to run, moved to materialize
/// time. (Read snapshots are structural clones of the live model and
/// never pass through a document at all.)
fn encode_staged(model: &Model) -> Result<Json, String> {
    let doc = model.to_checkpoint().map_err(|e| e.to_string())?;
    #[cfg(debug_assertions)]
    {
        if let Some(cause) = crate::audit::invariants::explain(&doc) {
            return Err(format!("materialized checkpoint fails audit: {cause}"));
        }
    }
    Ok(doc)
}

/// Embed a sync decision into a `repl_sync` response. `binary` is the
/// follower's negotiated preference: payloads travel as base64
/// [`crate::persist::binary`] envelopes (`full_b64`, per-delta
/// `ops_b64`) instead of inline JSON. Version/hash headers and the
/// `up_to_date` variant are identical in both formats. Call after
/// releasing the log lock — the deep clone / binary encode happens here.
pub fn embed_sync_payload(payload: SyncPayload, binary_format: bool, response: &mut Json) {
    use crate::persist::codec::ju64;
    if !binary_format {
        payload.into_response(response);
        return;
    }
    response.set("format", "binary");
    match payload {
        SyncPayload::UpToDate { version, hash } => {
            response
                .set("version", ju64(version))
                .set("hash", ju64(hash))
                .set("up_to_date", true);
        }
        SyncPayload::Deltas { version, hash, deltas } => {
            response.set("version", ju64(version)).set("hash", ju64(hash));
            let mut out = Vec::new();
            if let Json::Arr(items) = deltas {
                for d in items {
                    let mut e = Json::obj();
                    for key in ["from", "to", "hash", "pub_us", "learns"] {
                        if let Some(v) = d.get(key) {
                            e.set(key, v.clone());
                        }
                    }
                    let ops = d.get("ops").cloned().unwrap_or_else(|| Json::Arr(Vec::new()));
                    let bytes = binary::encode_doc(&ops);
                    if let Some(m) = crate::obs::m() {
                        m.snapshot_bytes_binary.add(bytes.len() as u64);
                    }
                    e.set("ops_b64", b64::encode(&bytes));
                    out.push(e);
                }
            }
            response.set("deltas", Json::Arr(out));
        }
        SyncPayload::Full { version, hash, pub_us, learns, doc } => {
            response
                .set("version", ju64(version))
                .set("hash", ju64(hash))
                .set("pub_us", ju64(pub_us))
                .set("learns", ju64(learns));
            let bytes = binary::encode_doc(&doc);
            if let Some(m) = crate::obs::m() {
                m.snapshot_bytes_binary.add(bytes.len() as u64);
            }
            response.set("full_b64", b64::encode(&bytes));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::delta::doc_hash;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn binary_full_payload_round_trips_bit_for_bit() {
        let doc = parse(r#"{"a":[1,2.5,-0],"b":{"s":"x"}}"#);
        let payload = SyncPayload::Full {
            version: 7,
            hash: doc_hash(&doc),
            pub_us: 1_000,
            learns: 50,
            doc: Arc::new(doc.clone()),
        };
        let mut response = Json::obj();
        embed_sync_payload(payload, true, &mut response);
        assert_eq!(response.get("format").and_then(Json::as_str), Some("binary"));
        assert!(response.get("full").is_none(), "binary responses must not inline JSON");
        let b = response.get("full_b64").and_then(Json::as_str).unwrap();
        let decoded = binary::decode_doc(&b64::decode(b).unwrap()).unwrap();
        assert_eq!(decoded.to_compact(), doc.to_compact());
        assert_eq!(doc_hash(&decoded), doc_hash(&doc));
    }

    #[test]
    fn binary_delta_payload_preserves_chain_fields() {
        let ops = parse(r#"[{"p":["a",0],"v":9}]"#);
        let mut d = Json::obj();
        d.set("from", "3").set("to", "4").set("hash", "12345").set("ops", ops.clone());
        let payload = SyncPayload::Deltas {
            version: 4,
            hash: 12345,
            deltas: Json::Arr(vec![d]),
        };
        let mut response = Json::obj();
        embed_sync_payload(payload, true, &mut response);
        let deltas = response.get("deltas").and_then(Json::as_arr).unwrap();
        assert_eq!(deltas.len(), 1);
        let e = &deltas[0];
        assert_eq!(e.get("from").and_then(Json::as_str), Some("3"));
        assert!(e.get("ops").is_none(), "binary deltas must not inline ops");
        let b = e.get("ops_b64").and_then(Json::as_str).unwrap();
        let decoded = binary::decode_doc(&b64::decode(b).unwrap()).unwrap();
        assert_eq!(decoded.to_compact(), ops.to_compact());
    }

    #[test]
    fn json_format_is_the_untouched_fallback() {
        let doc = parse(r#"{"a":1}"#);
        let payload = SyncPayload::Full {
            version: 1,
            hash: doc_hash(&doc),
            pub_us: 0,
            learns: 0,
            doc: Arc::new(doc.clone()),
        };
        let mut response = Json::obj();
        embed_sync_payload(payload, false, &mut response);
        assert!(response.get("format").is_none());
        assert!(response.get("full_b64").is_none());
        assert_eq!(response.get("full").unwrap().to_compact(), doc.to_compact());
    }

    #[test]
    fn materialize_is_lazy_and_collapses_staged_bursts() {
        use crate::eval::Regressor;
        use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
        use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

        let opts = HtrOptions { grace_period: 8, ..HtrOptions::default() };
        let qo = factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        });
        let mut tree = HoeffdingTreeRegressor::new(2, opts, qo);
        let mut rng = crate::common::Rng::new(0xBEEF);
        let mut learn = |t: &mut HoeffdingTreeRegressor, n: usize| {
            for _ in 0..n {
                let x = [rng.f64(), rng.f64()];
                let y = 3.0 * x[0] - x[1];
                t.learn_one(&x, y);
            }
        };
        learn(&mut tree, 64);
        let mut model = Model::Tree(tree);
        let repl = Replication::new(DeltaLog::new(model.to_checkpoint().unwrap(), 8));
        assert_eq!(repl.log().version(), 0);

        // two stages between materializations: one version, not two
        model.mark_synced();
        if let Model::Tree(t) = &mut model {
            learn(t, 32);
        }
        repl.stage(Arc::new(model.clone()), 96);
        if let Model::Tree(t) = &mut model {
            learn(t, 32);
        }
        repl.stage(Arc::new(model.clone()), 128);
        {
            let log = repl.materialize().unwrap();
            assert_eq!(log.version(), 1, "a staged burst collapses to one version");
            assert_eq!(
                log.doc().to_compact(),
                model.to_checkpoint().unwrap().to_compact(),
                "materialized doc is the newest staged state"
            );
        }
        // nothing staged: materialize is a no-op
        let log = repl.materialize().unwrap();
        assert_eq!(log.version(), 1);
    }

    #[test]
    fn follower_registry_dedupes_and_ignores_junk() {
        let doc = parse(r#"{"a":1}"#);
        let repl = Replication::new(DeltaLog::new(doc, 4));
        assert!(repl.followers().is_empty());

        repl.note_follower("10.0.0.1:7000");
        repl.note_follower("10.0.0.2:7000");
        repl.note_follower("10.0.0.1:7000"); // refresh, not duplicate
        let mut seen = repl.followers();
        seen.sort();
        assert_eq!(seen, vec!["10.0.0.1:7000".to_string(), "10.0.0.2:7000".to_string()]);

        repl.note_follower("");
        repl.note_follower(&"x".repeat(300));
        assert_eq!(repl.followers().len(), 2, "empty/oversized addresses are dropped");
    }
}
