//! Follower read-replicas: replicated serving over delta checkpoints.
//!
//! ## Roles
//!
//! The **leader** is a normal [`super::Server`]: its trainer owns the
//! single write path (optionally sharded over
//! [`crate::coordinator::train_batch_sharded`]) and every published
//! snapshot feeds a versioned [`DeltaLog`]. A **follower**
//! ([`Follower`]) holds no trainer at all: it mirrors the leader's
//! *published* checkpoint document and answers `predict` /
//! `predict_batch` / `stats` (+ `snapshot` of its mirrored document) from
//! an immutable `Arc<Model>` it hot-swaps on every applied version.
//! `learn` requests are rejected — training stays on the leader.
//!
//! ## Wire protocol (rides the leader's existing NDJSON port)
//!
//! The follower polls the leader with the `repl_sync` command:
//!
//! ```text
//! → {"cmd":"repl_sync","have":"7"}
//! ← {"ok":true,"version":"9","hash":"…",
//!    "deltas":[{"from":"7","to":"8","hash":"…","ops":[…]},
//!              {"from":"8","to":"9","hash":"…","ops":[…]}]}
//! ```
//!
//! Responses carry exactly one of `up_to_date`, `deltas`, or `full`.
//! Versions are monotonic (assigned by the leader's [`DeltaLog`]; version
//! 0 is the model the leader started with). `have` omitted means "send
//! a full document" — the bootstrap handshake.
//!
//! **Binary negotiation.** By default
//! ([`FollowerOptions::prefer_binary`]) the follower adds
//! `"format":"binary"` to its polls; a leader that understands it
//! answers with base64 [`crate::persist::binary`] envelopes —
//! `full_b64` instead of `full`, `ops_b64` instead of each delta's
//! `ops` (see `docs/FORMATS.md`). Decoding an envelope reproduces the
//! canonical document **bit-for-bit**, so every verification below
//! (hash checks, audits, byte-identical serving) is format-agnostic.
//! Old leaders simply ignore the field and answer inline JSON — the
//! apply path accepts both shapes, which is the whole fallback story.
//!
//! ## Consistency + resync rules
//!
//! * **Exactness.** Checkpoint text is canonical, so each delta is an
//!   exact structural diff; applying it reproduces the leader's document
//!   **byte-for-byte**. A follower at version v therefore returns
//!   predictions bit-identical to the leader's read snapshot at version v
//!   (enforced per-version in `rust/tests/replicate_e2e.rs`).
//! * **Monotonic handshake.** The follower only applies a delta whose
//!   `from` equals its current version, and versions only move forward.
//! * **Hash verification.** Every delta (and full document) carries the
//!   FxHash of the target's canonical text; the follower verifies after
//!   applying. A mismatch — corruption, divergence, a leader restart —
//!   marks the replica stale and the next poll requests a **full
//!   resync** (`have` omitted).
//! * **Gap detection.** The leader keeps a bounded delta ring
//!   ([`super::ServeOptions::delta_history`]). A follower further behind
//!   than the ring (e.g. it was down across many publications) gets a
//!   full document instead of a chain — same full-resync path.
//! * **Leader loss.** Poll failures never take the replica down: it keeps
//!   serving its last applied version (staleness is visible in `stats`)
//!   and reconnects with backoff.
//! * **Explainable divergence.** A rejected payload is re-run through the
//!   model-invariant auditor ([`crate::audit::invariants`]); when a rule
//!   from `docs/INVARIANTS.md` is broken, its id rides along in the apply
//!   error — so `last_resync_cause` reads like "decoding v9: … [audit:
//!   ARENA_CHILD_ORDER at model.nodes[7].split.left]" instead of a bare
//!   decode symptom. Debug builds additionally audit every *accepted*
//!   document before installing it.
//!
//! ## Freshness + health (see `docs/OBSERVABILITY.md`)
//!
//! Every sync payload carries the wall-clock instant the leader
//! published each version (`pub_us`, unix µs) and the leader's
//! applied-learn count at that publication (`learns`). On apply, the
//! follower records the live **publish→apply span** into the
//! `qostream_repl_freshness_seconds` histogram (lifetime + windowed)
//! and a bounded [`crate::obs::ReplEvent`] ring served by the
//! `trace_repl` command (newest first, optional `limit`). Old leaders
//! without the stamps degrade gracefully: nothing is recorded. The
//! bootstrap full sync is deliberately *not* recorded — its span would
//! measure how long the follower was down, not the learn→serve
//! pipeline. The `health` command reports `ok` / `degraded`
//! (degraded once [`HEALTH_FAILURE_RUN`] consecutive poll rounds fail),
//! and each poll advertises this replica's serve address so the
//! leader's `stats` lists its fleet (`followers`) for discovery by
//! [`super::fleet`].

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::common::b64;
use crate::common::json::Json;
use crate::eval::Regressor;
use crate::persist::binary;
use crate::persist::codec::{field, ju64, pu64};
use crate::persist::delta::{self, DeltaLog};
use crate::persist::Model;

use super::client::ServeClient;
use super::server::{
    current_snapshot, drive_connection, error_response, lock_poisoned,
    metrics_raw_response, metrics_response, ok_response, parse_limit, parse_x,
    trace_repl_response, trace_splits_response, HEALTH_FAILURE_RUN,
};

/// Follower tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct FollowerOptions {
    /// Delay between catch-up polls of the leader.
    pub poll_interval: Duration,
    /// Delay before re-dialing the leader after a connection failure.
    pub reconnect_backoff: Duration,
    /// Ask the leader for `format:"binary"` sync payloads (base64
    /// [`crate::persist::binary`] envelopes instead of inline JSON —
    /// smaller on the wire, same bytes after decoding). Leaders that
    /// predate the binary codec ignore the request and answer JSON; the
    /// apply path accepts both, so this is a preference, not a
    /// requirement.
    pub prefer_binary: bool,
}

impl Default for FollowerOptions {
    fn default() -> FollowerOptions {
        FollowerOptions {
            poll_interval: Duration::from_millis(25),
            reconnect_backoff: Duration::from_millis(200),
            prefer_binary: true,
        }
    }
}

/// State shared between the poller and the serving connections.
struct FollowerShared {
    /// The mirrored canonical checkpoint document, paired with the
    /// version it belongs to (poller-written; `snapshot` requests read
    /// the pair atomically so the response can never mislabel a document
    /// with a version installed concurrently).
    doc: Mutex<(u64, Json)>,
    /// The decoded model serving reads, hot-swapped per applied version.
    snapshot: RwLock<Arc<Model>>,
    version: AtomicU64,
    /// [`delta::doc_hash`] of the mirrored document — compared against
    /// the head hash the leader reports on every poll, so a divergent
    /// replica at the *same* version number (e.g. after a leader restart
    /// from a different checkpoint) is caught and full-resyncs.
    doc_hash: AtomicU64,
    /// The head version the leader reported on the last successful poll.
    leader_version: AtomicU64,
    /// The leader's total applied-learn count, as reported on the last
    /// successful poll (`leader_learns_applied` in the `repl_sync`
    /// response).
    leader_learns: AtomicU64,
    /// The leader's applied-learn count at the moment it published the
    /// version this replica currently serves — recorded when the replica
    /// reaches the leader's head. `leader_learns − learns_at_version` is
    /// the replica's staleness in learns.
    learns_at_version: AtomicU64,
    /// Why the replica last fell back to a full resync (or "bootstrap"
    /// for the initial sync) — the apply error verbatim, so divergence is
    /// diagnosable from one `stats` call.
    last_resync_cause: Mutex<String>,
    deltas_applied: AtomicU64,
    full_resyncs: AtomicU64,
    polls: AtomicU64,
    poll_errors: AtomicU64,
    /// Consecutive poll/apply failures since the last fully successful
    /// sync round — the follower's `health` degradation signal (degraded
    /// at [`HEALTH_FAILURE_RUN`]); `poll_errors` above is the lifetime
    /// total.
    poll_errors_consecutive: AtomicU64,
    predicts: AtomicU64,
    connections: AtomicU64,
    shutdown: AtomicBool,
    /// (version, instant applied) — replication-lag metric for the bench
    /// suite (bounded; see [`APPLY_LOG_CAP`]).
    applied_log: Mutex<Vec<(u64, Instant)>>,
    leader: String,
    /// This replica's own serve address, advertised on every poll so the
    /// leader's `stats` can list its fleet (see
    /// [`super::publish::Replication::note_follower`]).
    self_addr: String,
    name: String,
    kind: &'static str,
    n_features: usize,
    started: Instant,
}

/// Applied-version log bound (the bench reads it; serving never does).
const APPLY_LOG_CAP: usize = 8192;

/// Install a freshly decoded version: document, model, version + hash,
/// lag log.
fn install(shared: &FollowerShared, version: u64, hash: u64, doc: Json, model: Model) {
    *lock_poisoned(&shared.doc) = (version, doc);
    let arc = Arc::new(model);
    match shared.snapshot.write() {
        Ok(mut guard) => *guard = arc,
        Err(poisoned) => *poisoned.into_inner() = arc,
    }
    shared.version.store(version, Ordering::SeqCst);
    shared.doc_hash.store(hash, Ordering::SeqCst);
    let mut log = lock_poisoned(&shared.applied_log);
    if log.len() < APPLY_LOG_CAP {
        log.push((version, Instant::now()));
    }
}

/// Record a live publish→apply freshness span for a version this replica
/// just installed. `pub_us` is the wall-clock instant (unix µs) the
/// leader stamped at publication, carried on the sync payload
/// ([`delta::wire_freshness`]); `None` means an old leader that predates
/// the stamps — nothing is recorded, so the freshness histogram never
/// mixes in garbage. Spans clamp at zero under clock skew; the
/// cross-host accuracy contract (NTP-grade clocks) is spelled out in
/// `docs/OBSERVABILITY.md`.
fn record_freshness(version: u64, pub_us: Option<u64>, learns: Option<u64>, full: bool) {
    let Some(m) = crate::obs::m() else { return };
    let Some(pub_us) = pub_us else { return };
    let span_ns = crate::obs::window::now_unix_us()
        .saturating_sub(pub_us)
        .saturating_mul(1_000);
    m.repl_freshness_ns.record(span_ns);
    m.repl_freshness_ns_window.record(span_ns);
    m.repl_trace.record(crate::obs::ReplEvent {
        version,
        learns: learns.unwrap_or(0),
        span_ns,
        full,
    });
}

/// Enrich a rejection error with the invariant the offending document
/// breaks, when the auditor finds one: `last_resync_cause` then names
/// the broken rule (docs/INVARIANTS.md), not just the decode symptom.
/// Runs only after an apply already failed — never on the accept path.
fn audit_cause(doc: &Json, e: anyhow::Error) -> anyhow::Error {
    match crate::audit::invariants::explain(doc) {
        Some(cause) => anyhow!("{e} [audit: {cause}]"),
        None => e,
    }
}

/// Resolve a sync response's full document, whichever format it arrived
/// in: a base64 binary envelope (`full_b64`, the honored negotiation) or
/// inline canonical JSON (`full`). `None` when the response carries no
/// full document. Binary decoding is strict — envelope hashes verify
/// inside [`binary::decode_doc`] before the document-level hash check
/// even runs.
fn decode_full(response: &Json) -> Result<Option<Json>> {
    if let Some(text) = response.get("full_b64").and_then(Json::as_str) {
        let bytes = b64::decode(text).context("base64 of full_b64")?;
        let doc = binary::decode_doc(&bytes).context("binary envelope of full_b64")?;
        return Ok(Some(doc));
    }
    Ok(response.get("full").cloned())
}

/// Resolve one wire delta's patch ops, binary (`ops_b64`) or inline
/// JSON (`ops`).
fn decode_ops(d: &Json) -> Result<Json> {
    if let Some(text) = d.get("ops_b64").and_then(Json::as_str) {
        let bytes = b64::decode(text).context("base64 of ops_b64")?;
        return binary::decode_doc(&bytes).context("binary envelope of ops_b64");
    }
    Ok(field(d, "ops")?.clone())
}

/// Handle one successful `repl_sync` response. Returns an error when the
/// payload could not be applied — the caller then forces a full resync.
fn apply_sync(shared: &FollowerShared, response: &Json) -> Result<()> {
    let leader_version = pu64(field(response, "version")?, "version")?;
    shared.leader_version.store(leader_version, Ordering::Relaxed);
    // leader-head progress markers (absent when talking to an older
    // leader): how many learns the leader has applied in total, and how
    // many it had applied at its head publication
    let leader_learns = response
        .get("leader_learns_applied")
        .and_then(|j| pu64(j, "leader_learns_applied").ok());
    let learns_at_head = response
        .get("leader_learns_at_head")
        .and_then(|j| pu64(j, "leader_learns_at_head").ok());
    if let Some(n) = leader_learns {
        shared.leader_learns.store(n, Ordering::Relaxed);
    }
    if response.get("up_to_date").is_some() {
        // same version number is not enough: the head hash must match our
        // mirrored document, else we diverged (e.g. the leader restarted
        // from a different checkpoint and landed on our version)
        let head_hash = pu64(field(response, "hash")?, "hash")?;
        if head_hash != shared.doc_hash.load(Ordering::SeqCst) {
            return Err(anyhow!("up_to_date but head hash differs — replica diverged"));
        }
        note_at_head(shared, learns_at_head);
        return Ok(());
    }
    if let Some(full) = decode_full(response)? {
        let hash = pu64(field(response, "hash")?, "hash")?;
        if delta::doc_hash(&full) != hash {
            return Err(audit_cause(&full, anyhow!("full document hash mismatch")));
        }
        // debug builds audit every accepted document before it can serve
        #[cfg(debug_assertions)]
        {
            if let Some(cause) = crate::audit::invariants::explain(&full) {
                return Err(anyhow!("full document fails audit: {cause}"));
            }
        }
        let model = Model::from_checkpoint(&full).map_err(|e| audit_cause(&full, e))?;
        install(shared, leader_version, hash, full, model);
        shared.full_resyncs.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = crate::obs::m() {
            m.repl_full_resyncs.inc();
        }
        let (pub_us, learns) = delta::wire_freshness(response);
        record_freshness(leader_version, pub_us, learns, true);
        note_at_head(shared, learns_at_head);
        return Ok(());
    }
    if let Some(deltas) = response.get("deltas").and_then(Json::as_arr) {
        // apply the chain version by version: every intermediate state is
        // decoded, verified and *served*, so the replica passes through
        // exactly the leader's published sequence
        let (mut version, mut doc) = lock_poisoned(&shared.doc).clone();
        for d in deltas {
            let from = pu64(field(d, "from")?, "from")?;
            let to = pu64(field(d, "to")?, "to")?;
            let hash = pu64(field(d, "hash")?, "hash")?;
            let ops = decode_ops(d)?;
            if from != version || to != version + 1 {
                return Err(anyhow!(
                    "delta covers {from}→{to} but the replica is at {version}"
                ));
            }
            doc = delta::apply(&doc, &ops)
                .map_err(|e| e.context(format!("applying delta {from}→{to}")))?;
            if delta::doc_hash(&doc) != hash {
                return Err(audit_cause(
                    &doc,
                    anyhow!("hash mismatch after applying delta to v{to}"),
                ));
            }
            // debug builds audit every accepted document before it serves
            #[cfg(debug_assertions)]
            {
                if let Some(cause) = crate::audit::invariants::explain(&doc) {
                    return Err(anyhow!("document at v{to} fails audit: {cause}"));
                }
            }
            let model = Model::from_checkpoint(&doc)
                .map_err(|e| audit_cause(&doc, e.context(format!("decoding v{to}"))))?;
            install(shared, to, hash, doc.clone(), model);
            shared.deltas_applied.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = crate::obs::m() {
                m.repl_deltas_applied.inc();
            }
            let (pub_us, learns) = delta::wire_freshness(d);
            record_freshness(to, pub_us, learns, false);
            version = to;
        }
        if version == leader_version {
            note_at_head(shared, learns_at_head);
        }
        return Ok(());
    }
    Err(anyhow!("malformed repl_sync response (no up_to_date/full/deltas)"))
}

/// The replica just reached the leader's head version: pin the leader's
/// applied-learn count at that publication, and refresh the lag gauges.
fn note_at_head(shared: &FollowerShared, learns_at_head: Option<u64>) {
    if let Some(n) = learns_at_head {
        shared.learns_at_version.store(n, Ordering::Relaxed);
    }
    refresh_lag_gauges(shared);
}

/// Mirror the replica's staleness (versions + learns behind the leader
/// head) into the metrics registry.
fn refresh_lag_gauges(shared: &FollowerShared) {
    if let Some(m) = crate::obs::m() {
        m.repl_lag_versions.set(staleness_versions(shared));
        m.repl_lag_learns.set(staleness_learns(shared));
    }
}

/// Versions the replica trails the leader head seen on the last poll.
fn staleness_versions(shared: &FollowerShared) -> u64 {
    shared
        .leader_version
        .load(Ordering::Relaxed)
        .saturating_sub(shared.version.load(Ordering::SeqCst))
}

/// Learns the replica's served model trails the leader's live model: the
/// leader's total applied count minus its count at the publication this
/// replica serves. Zero until the leader reports the progress markers.
fn staleness_learns(shared: &FollowerShared) -> u64 {
    shared
        .leader_learns
        .load(Ordering::Relaxed)
        .saturating_sub(shared.learns_at_version.load(Ordering::Relaxed))
}

/// One failed poll round: bump the lifetime counter and the consecutive
/// run (the latter drives `health` degradation at
/// [`HEALTH_FAILURE_RUN`]; it resets only on a fully applied sync).
fn note_poll_error(shared: &FollowerShared) {
    shared.poll_errors.fetch_add(1, Ordering::Relaxed);
    shared.poll_errors_consecutive.fetch_add(1, Ordering::Relaxed);
}

fn poll_loop(shared: Arc<FollowerShared>, options: FollowerOptions) {
    let mut client: Option<ServeClient> = None;
    let mut force_full = false;
    while !shared.shutdown.load(Ordering::SeqCst) {
        thread::sleep(options.poll_interval);
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        if client.is_none() {
            match ServeClient::connect(shared.leader.as_str()) {
                Ok(c) => client = Some(c),
                Err(_) => {
                    note_poll_error(&shared);
                    thread::sleep(options.reconnect_backoff);
                    continue;
                }
            }
        }
        let have = if force_full {
            None
        } else {
            Some(shared.version.load(Ordering::SeqCst))
        };
        // connected above, but a read-replica must never die on an
        // assertion — a missing client is treated like a dropped leader
        let Some(conn) = client.as_mut() else {
            note_poll_error(&shared);
            thread::sleep(options.reconnect_backoff);
            continue;
        };
        let response = match conn.repl_sync_advertise(
            have,
            options.prefer_binary,
            Some(shared.self_addr.as_str()),
        ) {
            Ok(r) => r,
            Err(_) => {
                // leader gone or mid-restart: drop the connection, keep
                // serving the last applied version, retry with backoff
                note_poll_error(&shared);
                client = None;
                thread::sleep(options.reconnect_backoff);
                continue;
            }
        };
        shared.polls.fetch_add(1, Ordering::Relaxed);
        match apply_sync(&shared, &response) {
            Ok(()) => {
                force_full = false;
                shared.poll_errors_consecutive.store(0, Ordering::Relaxed);
            }
            Err(e) => {
                // divergence/corruption: next poll requests a full resync,
                // and the verbatim apply error becomes the diagnosable
                // last-resync-cause in `stats`
                *lock_poisoned(&shared.last_resync_cause) = e.to_string();
                note_poll_error(&shared);
                force_full = true;
                refresh_lag_gauges(&shared);
            }
        }
    }
}

/// A running follower replica. Stop it with a `shutdown` request on its
/// own port, then [`Follower::join`].
pub struct Follower {
    addr: SocketAddr,
    acceptor: thread::JoinHandle<()>,
    poller: thread::JoinHandle<()>,
    shared: Arc<FollowerShared>,
}

impl Follower {
    /// Bootstrap from `leader_addr` (one blocking full sync — fails
    /// cleanly when the leader is unreachable), bind `bind_addr`, and
    /// start the serving + polling threads.
    pub fn start(
        leader_addr: &str,
        bind_addr: &str,
        options: FollowerOptions,
    ) -> Result<Follower> {
        let mut client = ServeClient::connect(leader_addr)
            .map_err(|e| e.context(format!("dialing leader {leader_addr}")))?;
        let response = client
            .repl_sync_format(None, options.prefer_binary)
            .map_err(|e| e.context("bootstrap repl_sync"))?;
        let version = pu64(field(&response, "version")?, "version")?;
        let full = decode_full(&response)
            .map_err(|e| e.context("bootstrap full document"))?
            .ok_or_else(|| anyhow!("bootstrap expects a full document"))?;
        let hash = pu64(field(&response, "hash")?, "hash")?;
        if delta::doc_hash(&full) != hash {
            return Err(anyhow!("bootstrap document hash mismatch"));
        }
        let model = Model::from_checkpoint(&full)
            .map_err(|e| e.context("decoding bootstrap document"))?;

        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("binding {bind_addr}"))?;
        let addr = listener.local_addr().context("reading bound address")?;

        // a follower is a production serving process too: light up the
        // registry so `metrics` answers from it like on the leader
        crate::obs::enable();
        if let Some(m) = crate::obs::m() {
            m.process_start_seconds.set(crate::obs::window::now_unix_secs());
        }
        let shared = Arc::new(FollowerShared {
            doc: Mutex::new((version, full.clone())),
            name: model.name(),
            kind: model.kind(),
            n_features: model.n_features(),
            snapshot: RwLock::new(Arc::new(model)),
            version: AtomicU64::new(version),
            doc_hash: AtomicU64::new(hash),
            leader_version: AtomicU64::new(version),
            leader_learns: AtomicU64::new(0),
            learns_at_version: AtomicU64::new(0),
            last_resync_cause: Mutex::new("bootstrap".to_string()),
            deltas_applied: AtomicU64::new(0),
            full_resyncs: AtomicU64::new(0),
            polls: AtomicU64::new(0),
            poll_errors: AtomicU64::new(0),
            poll_errors_consecutive: AtomicU64::new(0),
            predicts: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            applied_log: Mutex::new(vec![(version, Instant::now())]),
            leader: leader_addr.to_string(),
            self_addr: addr.to_string(),
            started: Instant::now(),
        });

        let poller = {
            let shared = shared.clone();
            thread::spawn(move || poll_loop(shared, options))
        };

        let acceptor = {
            let shared = shared.clone();
            thread::spawn(move || {
                for conn in listener.incoming() {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let shared = shared.clone();
                    shared.connections.fetch_add(1, Ordering::Relaxed);
                    thread::spawn(move || handle_replica_connection(stream, shared, addr));
                }
            })
        };

        Ok(Follower { addr, acceptor, poller, shared })
    }

    /// The bound address (read the ephemeral port from here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The replica's currently applied version.
    pub fn version(&self) -> u64 {
        self.shared.version.load(Ordering::SeqCst)
    }

    /// Applied `(version, instant)` pairs — the bench suite joins these
    /// against the leader's publish instants for the replication-lag
    /// distribution.
    pub fn applied_log(&self) -> Vec<(u64, Instant)> {
        lock_poisoned(&self.shared.applied_log).clone()
    }

    /// Block until a `shutdown` request stops the replica.
    pub fn join(self) -> Result<()> {
        self.acceptor
            .join()
            .map_err(|_| anyhow!("follower acceptor panicked"))?;
        self.poller.join().map_err(|_| anyhow!("follower poller panicked"))?;
        Ok(())
    }
}

fn handle_replica_connection(
    stream: TcpStream,
    shared: Arc<FollowerShared>,
    self_addr: SocketAddr,
) {
    let stop = drive_connection(stream, |line| respond_replica(line, &shared));
    if stop {
        // flag first, then poke the acceptor loose from accept()
        shared.shutdown.store(true, Ordering::SeqCst);
        TcpStream::connect(self_addr).ok();
    }
}

/// Dispatch one request on a follower connection.
fn respond_replica(line: &str, shared: &FollowerShared) -> (Json, bool) {
    let request = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (error_response(&e), false),
    };
    let Some(cmd) = request.get("cmd").and_then(Json::as_str) else {
        return (error_response("missing \"cmd\""), false);
    };
    match cmd {
        "predict" => {
            let x = match parse_x(request.get("x"), shared.n_features) {
                Ok(x) => x,
                Err(e) => return (error_response(&e), false),
            };
            let model = current_snapshot(&shared.snapshot);
            shared.predicts.fetch_add(1, Ordering::Relaxed);
            let mut o = ok_response();
            o.set("prediction", model.predict(&x));
            (o, false)
        }
        "predict_batch" => {
            let Some(xs) = request.get("xs").and_then(Json::as_arr) else {
                return (error_response("\"xs\" must be an array of arrays"), false);
            };
            let mut batch = Vec::with_capacity(xs.len());
            for item in xs {
                match parse_x(Some(item), shared.n_features) {
                    Ok(x) => batch.push(x),
                    Err(e) => return (error_response(&e), false),
                }
            }
            let model = current_snapshot(&shared.snapshot);
            shared.predicts.fetch_add(batch.len() as u64, Ordering::Relaxed);
            let predictions: Vec<f64> = batch.iter().map(|x| model.predict(x)).collect();
            let mut o = ok_response();
            o.set("predictions", predictions);
            (o, false)
        }
        "snapshot" => {
            // the mirrored document at the currently served version — a
            // follower can seed offline analysis or a fresh leader
            let (version, doc) = lock_poisoned(&shared.doc).clone();
            let mut o = ok_response();
            o.set("checkpoint", doc).set("version", ju64(version));
            (o, false)
        }
        "stats" => {
            let version = shared.version.load(Ordering::SeqCst);
            let leader_version = shared.leader_version.load(Ordering::Relaxed);
            let mut o = ok_response();
            o.set("role", "follower")
                .set("model", shared.name.as_str())
                .set("kind", shared.kind)
                .set("n_features", shared.n_features)
                .set("leader", shared.leader.as_str())
                .set("snapshot_version", ju64(version))
                .set("leader_version_seen", ju64(leader_version))
                .set("staleness_versions", leader_version.saturating_sub(version))
                .set("staleness_learns", staleness_learns(shared))
                .set(
                    "last_resync_cause",
                    lock_poisoned(&shared.last_resync_cause).as_str(),
                )
                .set("mem_bytes", current_snapshot(&shared.snapshot).mem_bytes())
                .set("deltas_applied", shared.deltas_applied.load(Ordering::Relaxed))
                .set("full_resyncs", shared.full_resyncs.load(Ordering::Relaxed))
                .set("polls", shared.polls.load(Ordering::Relaxed))
                .set("poll_errors", shared.poll_errors.load(Ordering::Relaxed))
                .set("predicts", shared.predicts.load(Ordering::Relaxed))
                .set("connections", shared.connections.load(Ordering::Relaxed))
                .set("uptime_ms", shared.started.elapsed().as_millis() as u64)
                .set("uptime_secs", shared.started.elapsed().as_secs());
            (o, false)
        }
        "health" => {
            // structured liveness: degraded when the poller has failed
            // HEALTH_FAILURE_RUN rounds in a row (leader unreachable or
            // every sync rejected) — the replica still serves its last
            // applied version, but it is visibly going stale
            let run = shared.poll_errors_consecutive.load(Ordering::Relaxed);
            let mut reasons: Vec<String> = Vec::new();
            if run >= HEALTH_FAILURE_RUN {
                reasons.push(format!(
                    "leader sync failing (poll_errors_consecutive={run})"
                ));
            }
            let mut o = ok_response();
            o.set("status", if reasons.is_empty() { "ok" } else { "degraded" })
                .set("role", "follower")
                .set("snapshot_version", ju64(shared.version.load(Ordering::SeqCst)))
                .set("staleness_learns", staleness_learns(shared))
                .set("poll_errors_consecutive", run)
                .set("mem_bytes", current_snapshot(&shared.snapshot).mem_bytes())
                .set("uptime_secs", shared.started.elapsed().as_secs())
                .set("reasons", Json::Arr(reasons.into_iter().map(Json::from).collect()));
            (o, false)
        }
        "metrics" => (metrics_response(), false),
        "metrics_raw" => (metrics_raw_response(), false),
        "trace_splits" => match parse_limit(&request) {
            Ok(limit) => (trace_splits_response(limit), false),
            Err(e) => (error_response(&e), false),
        },
        "trace_repl" => match parse_limit(&request) {
            Ok(limit) => (trace_repl_response(limit), false),
            Err(e) => (error_response(&e), false),
        },
        "learn" => (
            error_response("read-only follower: send learns to the leader"),
            false,
        ),
        "repl_sync" => (
            error_response("followers do not serve replication (sync from the leader)"),
            false,
        ),
        "shutdown" => (ok_response(), true),
        other => (error_response(&format!("unknown cmd {other:?}")), false),
    }
}

/// In-process helper for benches/tests: build a [`DeltaLog`]-shaped view
/// of how far a follower lags the leader, as (version, lag) pairs.
/// Returns lags in seconds for every version both sides saw.
pub fn replication_lags(
    leader_log: &DeltaLog,
    follower_applies: &[(u64, Instant)],
) -> Vec<f64> {
    let mut published: Vec<(u64, Instant)> = leader_log
        .entries()
        .map(|e| (e.from + 1, e.published))
        .collect();
    published.sort_unstable_by_key(|&(v, _)| v);
    let mut lags = Vec::new();
    for &(version, applied) in follower_applies {
        if let Ok(idx) = published.binary_search_by_key(&version, |&(v, _)| v) {
            let publish_instant = published[idx].1;
            lags.push(
                applied
                    .checked_duration_since(publish_instant)
                    .unwrap_or(Duration::ZERO)
                    .as_secs_f64(),
            );
        }
    }
    lags
}
