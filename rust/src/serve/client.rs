//! A small blocking NDJSON client for the serve protocol — used by the
//! e2e tests, the `bench_suite` serving scenario and the CLI demo. One
//! request per call, strictly request/response (the protocol allows
//! pipelining; this client keeps it simple).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{anyhow, Context, Result};

use crate::common::json::Json;

/// Blocking client for one server connection.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ServeClient {
    /// Connect to a running server (e.g. `server.addr()`).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr).context("connecting to serve endpoint")?;
        let read_half = stream.try_clone().context("cloning connection")?;
        Ok(ServeClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request line and read one response line; errors when the
    /// server replies `{"ok":false}` (carrying the server's message).
    pub fn request(&mut self, request: &Json) -> Result<Json> {
        self.writer.write_all(request.to_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        let response = Json::parse(line.trim()).map_err(|e| anyhow!("{e}"))?;
        match response.get("ok").and_then(Json::as_bool) {
            Some(true) => Ok(response),
            Some(false) => Err(anyhow!(
                "server error: {}",
                response.get("error").and_then(Json::as_str).unwrap_or("unknown")
            )),
            None => Err(anyhow!("malformed response: {line}")),
        }
    }

    /// Enqueue one training instance (the ack means *queued*, see the
    /// protocol docs).
    pub fn learn(&mut self, x: &[f64], y: f64) -> Result<()> {
        let mut req = Json::obj();
        req.set("cmd", "learn").set("x", x.to_vec()).set("y", y);
        self.request(&req)?;
        Ok(())
    }

    /// Predict from the server's current read snapshot.
    pub fn predict(&mut self, x: &[f64]) -> Result<f64> {
        let mut req = Json::obj();
        req.set("cmd", "predict").set("x", x.to_vec());
        let response = self.request(&req)?;
        response
            .get("prediction")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("response missing \"prediction\""))
    }

    /// Batch predictions, all answered from one consistent snapshot.
    pub fn predict_batch(&mut self, xs: &[Vec<f64>]) -> Result<Vec<f64>> {
        let mut req = Json::obj();
        req.set("cmd", "predict_batch")
            .set("xs", Json::Arr(xs.iter().map(|x| Json::from(x.clone())).collect()));
        let response = self.request(&req)?;
        let preds = response
            .get("predictions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("response missing \"predictions\""))?;
        preds
            .iter()
            .map(|p| p.as_f64().ok_or_else(|| anyhow!("non-numeric prediction")))
            .collect()
    }

    /// Force a snapshot publication and return the checkpoint text:
    /// canonical compact JSON, byte-identical to what the server-side
    /// [`crate::persist::Model::to_text`] produced, and loadable via
    /// [`crate::persist::Model::from_text`].
    pub fn snapshot(&mut self) -> Result<String> {
        let mut req = Json::obj();
        req.set("cmd", "snapshot");
        let response = self.request(&req)?;
        let checkpoint = response
            .get("checkpoint")
            .ok_or_else(|| anyhow!("response missing \"checkpoint\""))?;
        Ok(checkpoint.to_compact())
    }

    /// Replication catch-up: ask the server for everything newer than
    /// version `have` (`None` = bootstrap, returns a full document). The
    /// response carries `version`, `hash`, and one of `up_to_date` /
    /// `deltas` / `full` — see [`super::replicate`] for the protocol.
    pub fn repl_sync(&mut self, have: Option<u64>) -> Result<Json> {
        self.repl_sync_format(have, false)
    }

    /// Like [`ServeClient::repl_sync`], optionally negotiating
    /// `format:"binary"`: payloads then travel as base64 binary
    /// checkpoint envelopes (`full_b64` / per-delta `ops_b64`). Leaders
    /// that predate the binary codec ignore the field and answer inline
    /// JSON — callers must accept both shapes.
    pub fn repl_sync_format(&mut self, have: Option<u64>, binary: bool) -> Result<Json> {
        self.repl_sync_advertise(have, binary, None)
    }

    /// Like [`ServeClient::repl_sync_format`], additionally advertising
    /// the caller's own serve address (`addr`). The leader remembers
    /// recently seen addresses and lists them in its `stats` response
    /// (`followers`), which is how fleet tooling discovers a whole fleet
    /// from one leader endpoint. Purely advisory — old leaders ignore it.
    pub fn repl_sync_advertise(
        &mut self,
        have: Option<u64>,
        binary: bool,
        addr: Option<&str>,
    ) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "repl_sync");
        if let Some(have) = have {
            req.set("have", crate::persist::codec::ju64(have));
        }
        if binary {
            req.set("format", "binary");
        }
        if let Some(addr) = addr {
            req.set("addr", addr);
        }
        self.request(&req)
    }

    /// Server counters and identity.
    pub fn stats(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "stats");
        self.request(&req)
    }

    /// Prometheus text exposition of the server's metrics registry
    /// ([`crate::obs`]). Works on leaders and followers.
    pub fn metrics(&mut self) -> Result<String> {
        let mut req = Json::obj();
        req.set("cmd", "metrics");
        let response = self.request(&req)?;
        response
            .get("text")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("response missing \"text\""))
    }

    /// Structured JSON snapshot of the full metrics registry
    /// ([`crate::obs::snapshot::RegistrySnapshot`] wire form) — what the
    /// fleet aggregator scrapes so it can merge histograms *exactly*
    /// instead of re-parsing rendered quantiles. Works on both roles.
    pub fn metrics_raw(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "metrics_raw");
        let response = self.request(&req)?;
        response
            .get("snapshot")
            .cloned()
            .ok_or_else(|| anyhow!("response missing \"snapshot\""))
    }

    /// Structured liveness/readiness: `status` (`ok` / `degraded`),
    /// `role`, `snapshot_version`, `staleness_learns`, `mem_bytes`,
    /// `uptime_secs` and a human-readable `reasons` array. Works on both
    /// roles (each reports its own degradation signals).
    pub fn health(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "health");
        self.request(&req)
    }

    /// Recent split-attempt trace events plus the lifetime attempt count
    /// (the [`crate::obs`] trace ring). Works on leaders and followers.
    pub fn trace_splits(&mut self) -> Result<Json> {
        self.trace_splits_limit(None)
    }

    /// Like [`ServeClient::trace_splits`], asking for at most `limit`
    /// events (newest first; the server caps it at the ring capacity).
    pub fn trace_splits_limit(&mut self, limit: Option<usize>) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "trace_splits");
        if let Some(limit) = limit {
            req.set("limit", limit);
        }
        self.request(&req)
    }

    /// Recent replication-apply trace events — per applied version: the
    /// version, the leader's learn count at publication and the live
    /// publish→apply freshness span (newest first). Works on both roles;
    /// a leader's ring is simply empty.
    pub fn trace_repl(&mut self, limit: Option<usize>) -> Result<Json> {
        let mut req = Json::obj();
        req.set("cmd", "trace_repl");
        if let Some(limit) = limit {
            req.set("limit", limit);
        }
        self.request(&req)
    }

    /// Stop the server (its [`super::Server::join`] then returns).
    pub fn shutdown(&mut self) -> Result<()> {
        let mut req = Json::obj();
        req.set("cmd", "shutdown");
        self.request(&req)?;
        Ok(())
    }

    /// Send a raw line (protocol-robustness tests) and return the raw
    /// response line.
    pub fn raw_line(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut response = String::new();
        let n = self.reader.read_line(&mut response)?;
        if n == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        Ok(response.trim().to_string())
    }
}
