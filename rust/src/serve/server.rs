//! The TCP server: acceptor + per-connection reader threads + the single
//! trainer thread that owns the model (see the module docs in
//! [`super`] for the architecture and wire protocol).
//!
//! Beyond the base learn/predict protocol this file implements the
//! **leader** side of replication ([`super::replicate`] has the follower):
//! every published snapshot also stages state for the versioned
//! [`DeltaLog`] (materialized lazily, see [`super::publish`]), and the
//! `repl_sync` command answers followers with `up_to_date`, a delta
//! chain, or a full document — as inline JSON or, when the follower
//! negotiates `format:"binary"`, as base64 binary checkpoint envelopes.
//! With `ServeOptions::shards > 1` the trainer
//! drains its queue into micro-batches and pushes them through the
//! sharded forest machinery ([`crate::coordinator::train_batch_sharded`])
//! — one endpoint fronting a sharded fleet, bit-identical to sequential
//! training.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, RwLock};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::common::json::Json;
use crate::coordinator::{train_batch_sharded, ForestCoordinatorConfig};
use crate::eval::Regressor;
use crate::persist::codec::{ju64, pu64};
use crate::persist::delta::DeltaLog;
use crate::persist::Model;
use crate::stream::Instance;

use super::publish::{embed_sync_payload, Replication};

/// Per-line request size cap: network input must not pick our allocation
/// size. Generous enough for large `predict_batch` requests.
const MAX_REQUEST_BYTES: u64 = 16 * 1024 * 1024;

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Applied learns between automatic snapshot publications (0 = only
    /// publish on explicit `snapshot` requests).
    pub snapshot_every: usize,
    /// Bounded trainer-queue depth in learns (backpressure window: a full
    /// queue blocks the sending connection's `learn` ack).
    pub queue_capacity: usize,
    /// Versions retained in the replication delta ring: followers at most
    /// this far behind catch up with deltas, older ones full-resync.
    pub delta_history: usize,
    /// Worker shards the trainer spreads ensemble members over (0 or 1 =
    /// train in the trainer thread). Requires an ensemble model.
    pub shards: usize,
    /// Max learns per sharded micro-batch (amortizes the scoped-thread
    /// spawn per batch). Only consulted when `shards > 1`; the staleness
    /// bound for reads becomes `snapshot_every + shard_batch`.
    pub shard_batch: usize,
    /// Model memory budget in bytes (0 = unbounded). The trainer runs
    /// the [`crate::govern`] escalation ladder right before every
    /// snapshot publication, so read snapshots, replication deltas and
    /// checkpoints only ever expose governed state (`docs/MEMORY.md`).
    pub mem_budget: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            snapshot_every: 512,
            queue_capacity: 1024,
            delta_history: 64,
            shards: 0,
            shard_batch: 256,
            mem_budget: 0,
        }
    }
}

/// What connection handlers send the trainer. FIFO per connection, which
/// is what makes `snapshot` reflect previously acked learns.
enum TrainerMsg {
    Learn(Vec<f64>, f64),
    /// Publish + reply with the checkpoint document and its published
    /// version (or the failure message). Both travel together from the
    /// trainer so the pairing cannot race with later publications; the
    /// document travels as parsed [`Json`] so the handler embeds it
    /// without re-parsing the (potentially multi-MB) text.
    Snapshot(mpsc::Sender<Result<(Json, u64), String>>),
    Shutdown,
}

/// Monotonic counters shared across all threads (lock-free reads for the
/// `stats` command).
#[derive(Default)]
struct ServerStats {
    learns_enqueued: AtomicU64,
    learns_applied: AtomicU64,
    predicts: AtomicU64,
    snapshots: AtomicU64,
    snapshot_failures: AtomicU64,
    /// Snapshot failures since the last successful publication — a run of
    /// these means reads serve an ever-staler model, so it is surfaced as
    /// a gauge (resets to 0 on success) rather than only the lifetime
    /// total above.
    snapshot_failures_consecutive: AtomicU64,
    connections: AtomicU64,
    /// Is the live model over its memory budget even after a full
    /// governance pass (1 = the budget sits below the structural floor —
    /// `health` degrades on this; always 0 when ungoverned)?
    over_budget: AtomicU64,
    /// Version of the last *materialized* publication
    /// ([`DeltaLog::version`]); staged-but-unmaterialized publications
    /// are not yet versioned (see [`super::publish`]).
    snapshot_version: AtomicU64,
    /// `learns_applied` at the moment of the last publication — the
    /// difference to the live counter is the snapshot's age in learns.
    learns_at_snapshot: AtomicU64,
}

/// Record a failed snapshot publication (lifetime total + consecutive
/// run, mirrored to the metrics registry when enabled).
fn note_snapshot_failure(stats: &ServerStats) {
    stats.snapshot_failures.fetch_add(1, Ordering::Relaxed);
    let run = stats.snapshot_failures_consecutive.fetch_add(1, Ordering::Relaxed) + 1;
    if let Some(m) = crate::obs::m() {
        m.serve_snapshot_failures_consecutive.set(run);
    }
}

/// Immutable facts captured before the model moves into the trainer.
struct ModelInfo {
    name: String,
    kind: &'static str,
    n_features: usize,
    snapshot_every: usize,
    shards: usize,
    mem_budget: usize,
    started: Instant,
}

/// Read the current snapshot `Arc` (surviving lock poisoning: the guarded
/// value is just a pointer, always valid).
pub(crate) fn current_snapshot(lock: &RwLock<Arc<Model>>) -> Arc<Model> {
    match lock.read() {
        Ok(guard) => guard.clone(),
        Err(poisoned) => poisoned.into_inner().clone(),
    }
}

/// Lock a mutex, surviving poisoning (every guarded value in the serve
/// layer is left consistent between mutations, so a panicked writer is
/// no reason to refuse reads). Shared with [`super::replicate`].
pub(crate) fn lock_poisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Advance the snapshot bookkeeping to "published right now": the age
/// counter (`snapshot_age_learns` in `stats`) resets, the lifetime
/// snapshot count bumps, and a failure run ends. Shared by the staging
/// publish and the zero-dirty explicit-snapshot path — the latter used
/// to skip this, leaving a forced snapshot's age pointing at the
/// *previous* publication (regression-tested in
/// `rust/tests/serve_e2e.rs`).
fn note_snapshot_published(stats: &ServerStats) {
    stats
        .learns_at_snapshot
        .store(stats.learns_applied.load(Ordering::Relaxed), Ordering::Relaxed);
    stats.snapshots.fetch_add(1, Ordering::Relaxed);
    stats.snapshot_failures_consecutive.store(0, Ordering::Relaxed);
    if let Some(m) = crate::obs::m() {
        m.serve_snapshot_failures_consecutive.set(0);
    }
}

/// Publish the live model as the new read snapshot in O(touched): a
/// structural clone (`Arc` bumps; deep copies are deferred to the next
/// learn that touches a leaf), an `Arc` swap, and a pointer staged for
/// lazy materialization into the replication log ([`super::publish`]).
/// Infallible — the codec round-trip that used to be able to fail here
/// now runs at materialize time.
fn stage_publish(
    model: &mut Model,
    snapshot: &RwLock<Arc<Model>>,
    stats: &ServerStats,
    replication: &Replication,
    governor: &crate::govern::Governor,
) {
    // govern *before* the clone: every state the outside world can see —
    // the read snapshot, the staged replication pointer, checkpoints —
    // is already inside the budget. enforce() is one mem_bytes() walk
    // when the model fits; followers receive the governed state through
    // ordinary deltas (no protocol change, see docs/MEMORY.md).
    if governor.enabled() {
        let report = governor.enforce(model);
        stats
            .over_budget
            .store(u64::from(!report.within_budget), Ordering::Relaxed);
    }
    let started = Instant::now();
    let shared = Arc::new(model.clone());
    match snapshot.write() {
        Ok(mut guard) => *guard = shared.clone(),
        Err(poisoned) => *poisoned.into_inner() = shared.clone(),
    }
    replication.stage(shared, stats.learns_applied.load(Ordering::Relaxed));
    model.mark_synced();
    note_snapshot_published(stats);
    if let Some(m) = crate::obs::m() {
        m.model_mem_bytes.set(model.mem_bytes() as u64);
        m.snapshot_publish_ns.record(started.elapsed().as_nanos() as u64);
    }
}

/// Explicit `snapshot` request: publish (when anything trained since the
/// last publication), materialize the log, and return the canonical
/// checkpoint document with its version.
fn publish_snapshot(
    model: &mut Model,
    snapshot: &RwLock<Arc<Model>>,
    stats: &ServerStats,
    replication: &Replication,
    governor: &crate::govern::Governor,
) -> Result<(Json, u64), String> {
    if model.learns_since_sync() > 0 {
        stage_publish(model, snapshot, stats, replication, governor);
    } else {
        // zero-dirty: the read snapshot already equals the live model,
        // but the bookkeeping still advances — a snapshot request racing
        // a just-crossed publication boundary must reset the snapshot
        // age, not report the previous publication's
        note_snapshot_published(stats);
    }
    let (doc, version) = {
        let log = replication.materialize()?;
        (log.doc_arc(), log.version())
    };
    stats.snapshot_version.store(version, Ordering::Relaxed);
    // the deep clone happens after the log lock is released
    Ok(((*doc).clone(), version))
}

/// Apply one micro-batch to the model: through the sharded forest
/// machinery when configured (and worthwhile), else the sequential learn
/// loop. Both paths are bit-for-bit identical (the sharded contract,
/// property-tested in [`crate::coordinator::forest`]).
fn train_batch(model: &mut Model, batch: &[Instance], shards: usize) {
    if shards > 1 && batch.len() > 1 {
        let config = ForestCoordinatorConfig {
            n_shards: shards,
            batch_size: batch.len(),
            ..Default::default()
        };
        match model {
            Model::Arf(f) => {
                let _ = train_batch_sharded(f, batch, config);
                // member-state mutations (PRNG draws, detectors) happen
                // even when no tree trains, so the touched-state counter
                // must advance by the full batch
                f.note_learns(batch.len() as u64);
                return;
            }
            Model::Bagging(b) => {
                let _ = train_batch_sharded(b, batch, config);
                b.note_learns(batch.len() as u64);
                return;
            }
            // single trees cannot member-shard; start() rejects the combo
            Model::Tree(_) => {}
        }
    }
    for inst in batch {
        model.learn_one(&inst.x, inst.y);
    }
}

/// A running serve instance. Dropping the handle does NOT stop the
/// server; send a `shutdown` request (e.g. [`super::ServeClient::shutdown`])
/// and then [`Server::join`] it.
pub struct Server {
    addr: SocketAddr,
    acceptor: thread::JoinHandle<()>,
    trainer: thread::JoinHandle<Model>,
    replication: Arc<Replication>,
}

impl Server {
    /// Bind `bind_addr` (use port 0 for an ephemeral port) and start the
    /// trainer, acceptor and snapshot machinery. The initial snapshot is
    /// published before the listener accepts, so the very first `predict`
    /// already has a model to read — this also means `start` fails
    /// cleanly when the model is not checkpointable.
    pub fn start(model: Model, bind_addr: &str, options: ServeOptions) -> Result<Server> {
        if options.shards > 1 && matches!(model, Model::Tree(_)) {
            return Err(anyhow!(
                "--shards needs an ensemble model (members shard; a single tree cannot)"
            ));
        }
        // serving is the production path: turn the metrics registry on so
        // every obs::m() gate in the tree/forest/persist layers goes live
        crate::obs::enable();
        if let Some(m) = crate::obs::m() {
            m.process_start_seconds.set(crate::obs::window::now_unix_secs());
        }
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("binding {bind_addr}"))?;
        let addr = listener.local_addr().context("reading bound address")?;

        let stats = Arc::new(ServerStats::default());
        let info = Arc::new(ModelInfo {
            name: model.name(),
            kind: model.kind(),
            n_features: model.n_features(),
            snapshot_every: options.snapshot_every,
            shards: options.shards,
            mem_budget: options.mem_budget,
            started: Instant::now(),
        });
        let doc = model.to_checkpoint().map_err(|e| {
            e.context("publishing the initial snapshot (model not checkpointable?)")
        })?;
        let initial = Model::from_checkpoint(&doc)
            .map_err(|e| e.context("decoding the initial snapshot"))?;
        let replication =
            Arc::new(Replication::new(DeltaLog::new(doc, options.delta_history.max(1))));
        let snapshot = Arc::new(RwLock::new(Arc::new(initial)));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<TrainerMsg>(options.queue_capacity.max(1));

        let trainer = {
            let snapshot = snapshot.clone();
            let stats = stats.clone();
            let replication = replication.clone();
            let snapshot_every = options.snapshot_every as u64;
            let shards = options.shards;
            let governor = crate::govern::Governor::new(options.mem_budget);
            // sequential mode keeps the exact one-learn-per-message
            // schedule; sharded mode amortizes scoped-thread spawns over
            // micro-batches
            let max_batch = if shards > 1 { options.shard_batch.max(1) } else { 1 };
            thread::spawn(move || {
                let mut model = model;
                // a non-Learn message encountered mid-drain is handled
                // after the batch it interrupted (FIFO preserved)
                let mut carry: Option<TrainerMsg> = None;
                'run: loop {
                    let msg = match carry.take() {
                        Some(m) => m,
                        None => match rx.recv() {
                            Ok(m) => m,
                            Err(_) => break 'run,
                        },
                    };
                    match msg {
                        TrainerMsg::Learn(x, y) => {
                            let mut batch = vec![Instance { x, y }];
                            while batch.len() < max_batch {
                                match rx.try_recv() {
                                    Ok(TrainerMsg::Learn(x, y)) => {
                                        batch.push(Instance { x, y })
                                    }
                                    Ok(other) => {
                                        carry = Some(other);
                                        break;
                                    }
                                    Err(_) => break,
                                }
                            }
                            train_batch(&mut model, &batch, shards);
                            let n = batch.len() as u64;
                            let before = stats.learns_applied.fetch_add(n, Ordering::Relaxed);
                            let applied = before + n;
                            // publish when the batch crossed a boundary —
                            // O(touched) now: staging cannot fail, and
                            // encode failures surface at materialize time
                            if snapshot_every > 0
                                && before / snapshot_every != applied / snapshot_every
                            {
                                stage_publish(
                                    &mut model,
                                    &snapshot,
                                    &stats,
                                    &replication,
                                    &governor,
                                );
                            }
                        }
                        TrainerMsg::Snapshot(reply) => {
                            let out = publish_snapshot(
                                &mut model,
                                &snapshot,
                                &stats,
                                &replication,
                                &governor,
                            );
                            if out.is_err() {
                                note_snapshot_failure(&stats);
                            }
                            // a dropped reply just means the client left
                            reply.send(out).ok();
                        }
                        TrainerMsg::Shutdown => break 'run,
                    }
                }
                model
            })
        };

        let acceptor = {
            let shutdown = shutdown.clone();
            let replication = replication.clone();
            thread::spawn(move || {
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let tx = tx.clone();
                    let snapshot = snapshot.clone();
                    let stats = stats.clone();
                    let info = info.clone();
                    let shutdown = shutdown.clone();
                    let replication = replication.clone();
                    stats.connections.fetch_add(1, Ordering::Relaxed);
                    thread::spawn(move || {
                        handle_connection(
                            stream,
                            tx,
                            snapshot,
                            stats,
                            info,
                            shutdown,
                            replication,
                            addr,
                        );
                    });
                }
            })
        };

        Ok(Server { addr, acceptor, trainer, replication })
    }

    /// The bound address (read the ephemeral port from here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The leader's replication state (staged snapshot + versioned delta
    /// log) — the bench suite reads lag and delta/full byte sizes from
    /// here; call [`Replication::materialize`] first for a current view.
    pub fn replication(&self) -> Arc<Replication> {
        self.replication.clone()
    }

    /// Block until a `shutdown` request stops the server; returns the
    /// final trained model (callers can [`Model::save`] it).
    pub fn join(self) -> Result<Model> {
        self.acceptor
            .join()
            .map_err(|_| anyhow!("acceptor thread panicked"))?;
        self.trainer
            .join()
            .map_err(|_| anyhow!("trainer thread panicked"))
    }
}

/// The framed NDJSON connection loop shared by leader and follower
/// ([`super::replicate`]) connections: one capped request line in, one
/// response line out, until the peer hangs up or `respond` asks to stop.
/// Returns whether a stop was requested (the caller runs its own
/// shutdown dance — the leader also has a trainer to wake).
pub(crate) fn drive_connection<F>(stream: TcpStream, mut respond: F) -> bool
where
    F: FnMut(&str) -> (Json, bool),
{
    let Ok(read_half) = stream.try_clone() else { return false };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        let mut line = String::new();
        let n = match (&mut reader).take(MAX_REQUEST_BYTES).read_line(&mut line) {
            Ok(n) => n,
            Err(_) => return false, // includes non-UTF-8 input
        };
        if n == 0 {
            return false; // client closed the connection
        }
        if !line.ends_with('\n') && n as u64 >= MAX_REQUEST_BYTES {
            let _ = write_response(&mut writer, &error_response("request too large"));
            return false;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, stop) = respond(trimmed);
        if write_response(&mut writer, &response).is_err() {
            return false;
        }
        if stop {
            return true;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    stream: TcpStream,
    tx: mpsc::SyncSender<TrainerMsg>,
    snapshot: Arc<RwLock<Arc<Model>>>,
    stats: Arc<ServerStats>,
    info: Arc<ModelInfo>,
    shutdown: Arc<AtomicBool>,
    replication: Arc<Replication>,
    self_addr: SocketAddr,
) {
    let stop = drive_connection(stream, |line| {
        respond(line, &tx, &snapshot, &stats, &info, &replication)
    });
    if stop {
        // order matters: flag first, then wake the trainer, then poke
        // the acceptor loose from accept()
        shutdown.store(true, Ordering::SeqCst);
        tx.send(TrainerMsg::Shutdown).ok();
        TcpStream::connect(self_addr).ok();
    }
}

pub(crate) fn write_response(
    writer: &mut BufWriter<TcpStream>,
    response: &Json,
) -> std::io::Result<()> {
    writer.write_all(response.to_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

pub(crate) fn error_response(message: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", false).set("error", message);
    o
}

pub(crate) fn ok_response() -> Json {
    let mut o = Json::obj();
    o.set("ok", true);
    o
}

/// Answer the `metrics` command: the full Prometheus text exposition of
/// the process-wide registry. Shared by leader and follower connections.
pub(crate) fn metrics_response() -> Json {
    let mut o = ok_response();
    o.set("format", "prometheus").set("text", crate::obs::exposition());
    o
}

/// Consecutive-failure run length at which `health` reports `degraded`
/// (leader: snapshot publication failures; follower: poll errors).
pub(crate) const HEALTH_FAILURE_RUN: u64 = 3;

/// Parse the optional `limit` field of `trace_splits`/`trace_repl`
/// requests. `None` = dump the whole ring; responders additionally cap
/// at the ring's capacity, so `limit` can never oversize a response.
pub(crate) fn parse_limit(request: &Json) -> Result<Option<usize>, String> {
    match request.get("limit") {
        None => Ok(None),
        Some(j) => match j.as_f64() {
            Some(v) if v >= 0.0 && v == v.trunc() && v <= u32::MAX as f64 => {
                Ok(Some(v as usize))
            }
            _ => Err("\"limit\" must be a non-negative integer".to_string()),
        },
    }
}

/// Answer the `trace_splits` command: up to `limit` recent split
/// attempts (outcome, merit gap, slots evaluated, elapsed ns),
/// **newest first**, plus the lifetime attempt count. Shared by leader
/// and follower connections.
pub(crate) fn trace_splits_response(limit: Option<usize>) -> Json {
    let ring = &crate::obs::global().split_trace;
    let take = limit.unwrap_or(ring.capacity()).min(ring.capacity());
    let events: Vec<Json> = ring
        .recent(take)
        .into_iter()
        .map(|e| {
            let mut o = Json::obj();
            o.set("outcome", e.outcome.label())
                .set("merit_gap", e.merit_gap)
                .set("slots_evaluated", e.slots_evaluated)
                .set("elapsed_ns", e.elapsed_ns);
            o
        })
        .collect();
    let mut o = ok_response();
    o.set("total", ring.total())
        .set("capacity", ring.capacity())
        .set("events", Json::Arr(events));
    o
}

/// Answer the `trace_repl` command: up to `limit` recently applied
/// replication versions (version, cumulative leader learns covered,
/// live publish→apply span, full-resync flag), **newest first** — the
/// per-event view behind `qostream_repl_freshness_seconds`. Events are
/// recorded by follower apply ([`super::replicate`]); a leader answers
/// with an empty ring. Shared by both roles so fleet tooling can probe
/// either end with one command.
pub(crate) fn trace_repl_response(limit: Option<usize>) -> Json {
    let ring = &crate::obs::global().repl_trace;
    let take = limit.unwrap_or(ring.capacity()).min(ring.capacity());
    let events: Vec<Json> = ring
        .recent(take)
        .into_iter()
        .map(|e| {
            let mut o = Json::obj();
            o.set("version", ju64(e.version))
                .set("learns", ju64(e.learns))
                .set("span_ns", e.span_ns)
                .set("full", e.full);
            o
        })
        .collect();
    let mut o = ok_response();
    o.set("total", ring.total())
        .set("capacity", ring.capacity())
        .set("events", Json::Arr(events));
    o
}

/// Answer the `metrics_raw` command: the registry as an exactly
/// mergeable [`crate::obs::RegistrySnapshot`] JSON document — what the
/// fleet aggregator consumes (rendered quantiles cannot be merged; raw
/// buckets can, exactly). Shared by leader and follower connections.
pub(crate) fn metrics_raw_response() -> Json {
    let snap = crate::obs::RegistrySnapshot::capture(crate::obs::global());
    let mut o = ok_response();
    o.set("snapshot", snap.to_json());
    o
}

/// Extract and validate one feature vector.
pub(crate) fn parse_x(j: Option<&Json>, n_features: usize) -> Result<Vec<f64>, String> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| "\"x\" must be an array of numbers".to_string())?;
    if arr.len() != n_features {
        return Err(format!("expected {n_features} features, got {}", arr.len()));
    }
    let mut x = Vec::with_capacity(arr.len());
    for v in arr {
        let v = v.as_f64().ok_or_else(|| "\"x\" must contain numbers".to_string())?;
        if !v.is_finite() {
            return Err("\"x\" must be finite".to_string());
        }
        x.push(v);
    }
    Ok(x)
}

/// Dispatch one request line; returns the response and whether the server
/// should stop.
fn respond(
    line: &str,
    tx: &mpsc::SyncSender<TrainerMsg>,
    snapshot: &RwLock<Arc<Model>>,
    stats: &ServerStats,
    info: &ModelInfo,
    replication: &Replication,
) -> (Json, bool) {
    let request = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (error_response(&e), false),
    };
    let Some(cmd) = request.get("cmd").and_then(Json::as_str) else {
        return (error_response("missing \"cmd\""), false);
    };
    match cmd {
        "learn" => {
            let started = crate::obs::m().map(|_| Instant::now());
            let x = match parse_x(request.get("x"), info.n_features) {
                Ok(x) => x,
                Err(e) => return (error_response(&e), false),
            };
            let Some(y) = request.get("y").and_then(Json::as_f64) else {
                return (error_response("\"y\" must be a number"), false);
            };
            if !y.is_finite() {
                return (error_response("\"y\" must be finite"), false);
            }
            // blocking send = backpressure: the ack waits for queue space
            if tx.send(TrainerMsg::Learn(x, y)).is_err() {
                return (error_response("trainer is shut down"), false);
            }
            stats.learns_enqueued.fetch_add(1, Ordering::Relaxed);
            if let (Some(m), Some(t)) = (crate::obs::m(), started) {
                // enqueue latency: includes the backpressure wait, which is
                // exactly what a saturated trainer looks like to clients
                m.serve_learn_ns.record(t.elapsed().as_nanos() as u64);
                m.serve_learn_window.add(1);
            }
            (ok_response(), false)
        }
        "predict" => {
            let started = crate::obs::m().map(|_| Instant::now());
            let x = match parse_x(request.get("x"), info.n_features) {
                Ok(x) => x,
                Err(e) => return (error_response(&e), false),
            };
            let model = current_snapshot(snapshot);
            stats.predicts.fetch_add(1, Ordering::Relaxed);
            let mut o = ok_response();
            o.set("prediction", model.predict(&x));
            if let (Some(m), Some(t)) = (crate::obs::m(), started) {
                let ns = t.elapsed().as_nanos() as u64;
                m.serve_predict_ns.record(ns);
                m.serve_predict_window.add(1);
                m.serve_predict_ns_window.record(ns);
            }
            (o, false)
        }
        "predict_batch" => {
            let Some(xs) = request.get("xs").and_then(Json::as_arr) else {
                return (error_response("\"xs\" must be an array of arrays"), false);
            };
            let mut batch = Vec::with_capacity(xs.len());
            for item in xs {
                match parse_x(Some(item), info.n_features) {
                    Ok(x) => batch.push(x),
                    Err(e) => return (error_response(&e), false),
                }
            }
            // one snapshot for the whole batch: a consistent view even if
            // the trainer swaps mid-request
            let model = current_snapshot(snapshot);
            stats.predicts.fetch_add(batch.len() as u64, Ordering::Relaxed);
            if let Some(m) = crate::obs::m() {
                m.serve_predict_window.add(batch.len() as u64);
            }
            let predictions: Vec<f64> = batch.iter().map(|x| model.predict(x)).collect();
            let mut o = ok_response();
            o.set("predictions", predictions);
            (o, false)
        }
        "snapshot" => {
            let (reply_tx, reply_rx) = mpsc::channel();
            if tx.send(TrainerMsg::Snapshot(reply_tx)).is_err() {
                return (error_response("trainer is shut down"), false);
            }
            match reply_rx.recv() {
                Ok(Ok((checkpoint, version))) => {
                    let mut o = ok_response();
                    o.set("checkpoint", checkpoint).set("version", ju64(version));
                    (o, false)
                }
                Ok(Err(e)) => (error_response(&e), false),
                Err(_) => (error_response("trainer is shut down"), false),
            }
        }
        "repl_sync" => {
            // follower catch-up: answered from the replication log without
            // a trainer round-trip (replication is defined over *published*
            // versions). Materialize first — the trainer publishes by
            // staging, and the log must be current before answering.
            let have = match request.get("have") {
                None => None,
                Some(j) => match pu64(j, "have") {
                    Ok(v) => Some(v),
                    Err(e) => return (error_response(&e.to_string()), false),
                },
            };
            let binary = request.get("format").and_then(Json::as_str) == Some("binary");
            // a polling follower may advertise its own serve address so
            // fleet tooling can discover the whole fleet from the leader
            if let Some(addr) = request.get("addr").and_then(Json::as_str) {
                replication.note_follower(addr);
            }
            let payload = match replication.materialize() {
                Ok(log) => log.sync_payload(have),
                Err(e) => {
                    note_snapshot_failure(stats);
                    return (
                        error_response(&format!("materializing the snapshot: {e}")),
                        false,
                    );
                }
            };
            // full documents embed (deep-clone / binary-encode) outside
            // the log lock, so a bootstrapping follower never stalls the
            // publish path
            let mut o = ok_response();
            embed_sync_payload(payload, binary, &mut o);
            // leader-head progress markers: the follower derives its lag
            // in learns from these (see `super::replicate`) — how many
            // instances the leader has applied in total, and how many it
            // had applied when the head version was published
            let leader_applied = stats.learns_applied.load(Ordering::Relaxed);
            let leader_at_head = stats.learns_at_snapshot.load(Ordering::Relaxed);
            o.set("leader_learns_applied", ju64(leader_applied));
            o.set("leader_learns_at_head", ju64(leader_at_head));
            (o, false)
        }
        "stats" => {
            let applied = stats.learns_applied.load(Ordering::Relaxed);
            let at_snapshot = stats.learns_at_snapshot.load(Ordering::Relaxed);
            let mut o = ok_response();
            o.set("role", "leader")
                .set("model", info.name.as_str())
                .set("kind", info.kind)
                .set("n_features", info.n_features)
                .set("snapshot_every", info.snapshot_every)
                .set("shards", info.shards)
                .set("learns_enqueued", stats.learns_enqueued.load(Ordering::Relaxed))
                .set("learns_applied", applied)
                .set("predicts", stats.predicts.load(Ordering::Relaxed))
                .set("snapshots", stats.snapshots.load(Ordering::Relaxed))
                .set(
                    "snapshot_failures",
                    stats.snapshot_failures.load(Ordering::Relaxed),
                )
                .set(
                    "snapshot_failures_consecutive",
                    stats.snapshot_failures_consecutive.load(Ordering::Relaxed),
                )
                .set(
                    "snapshot_version",
                    ju64(stats.snapshot_version.load(Ordering::Relaxed)),
                )
                .set("snapshot_age_learns", applied.saturating_sub(at_snapshot))
                .set("mem_bytes", current_snapshot(snapshot).mem_bytes())
                .set("mem_budget", info.mem_budget)
                .set(
                    "over_budget",
                    stats.over_budget.load(Ordering::Relaxed) != 0,
                )
                .set("connections", stats.connections.load(Ordering::Relaxed))
                .set("uptime_ms", info.started.elapsed().as_millis() as u64)
                .set("uptime_secs", info.started.elapsed().as_secs())
                .set(
                    "followers",
                    Json::Arr(replication.followers().into_iter().map(Json::from).collect()),
                );
            (o, false)
        }
        "health" => {
            // structured ok/degraded verdict a load-balancer can eject on
            let applied = stats.learns_applied.load(Ordering::Relaxed);
            let at_snapshot = stats.learns_at_snapshot.load(Ordering::Relaxed);
            let run = stats.snapshot_failures_consecutive.load(Ordering::Relaxed);
            let mut reasons = Vec::new();
            if run >= HEALTH_FAILURE_RUN {
                reasons.push(format!(
                    "snapshot publication failing (snapshot_failures_consecutive={run})"
                ));
            }
            if stats.over_budget.load(Ordering::Relaxed) != 0 {
                reasons.push(format!(
                    "model exceeds its memory budget even fully governed \
                     (mem_budget={})",
                    info.mem_budget
                ));
            }
            let mut o = ok_response();
            o.set("status", if reasons.is_empty() { "ok" } else { "degraded" })
                .set("role", "leader")
                .set(
                    "snapshot_version",
                    ju64(stats.snapshot_version.load(Ordering::Relaxed)),
                )
                .set("staleness_learns", applied.saturating_sub(at_snapshot))
                .set("snapshot_failures_consecutive", run)
                .set("mem_bytes", current_snapshot(snapshot).mem_bytes())
                .set("mem_budget", info.mem_budget)
                .set("uptime_secs", info.started.elapsed().as_secs())
                .set("reasons", Json::Arr(reasons.into_iter().map(Json::from).collect()));
            (o, false)
        }
        "metrics" => (metrics_response(), false),
        "metrics_raw" => (metrics_raw_response(), false),
        "trace_splits" => match parse_limit(&request) {
            Ok(limit) => (trace_splits_response(limit), false),
            Err(e) => (error_response(&e), false),
        },
        "trace_repl" => match parse_limit(&request) {
            Ok(limit) => (trace_repl_response(limit), false),
            Err(e) => (error_response(&e), false),
        },
        "shutdown" => (ok_response(), true),
        other => (error_response(&format!("unknown cmd {other:?}")), false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_x_validates_shape_and_values() {
        let good = Json::parse("[1.0, 2.0]").unwrap();
        assert_eq!(parse_x(Some(&good), 2).unwrap(), vec![1.0, 2.0]);
        assert!(parse_x(Some(&good), 3).is_err());
        assert!(parse_x(None, 2).is_err());
        let bad = Json::parse("[1.0, \"x\"]").unwrap();
        assert!(parse_x(Some(&bad), 2).is_err());
        let non_finite = Json::parse("[1.0, null]").unwrap();
        assert!(parse_x(Some(&non_finite), 2).is_err());
    }

    #[test]
    fn responses_have_the_ok_envelope() {
        assert_eq!(ok_response().to_compact(), "{\"ok\":true}");
        let e = error_response("boom");
        assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(e.get("error").and_then(Json::as_str), Some("boom"));
    }
}
