//! The TCP server: acceptor + per-connection reader threads + the single
//! trainer thread that owns the model (see the module docs in
//! [`super`] for the architecture and wire protocol).

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::common::json::Json;
use crate::eval::Regressor;
use crate::persist::Model;

/// Per-line request size cap: network input must not pick our allocation
/// size. Generous enough for large `predict_batch` requests.
const MAX_REQUEST_BYTES: u64 = 16 * 1024 * 1024;

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Applied learns between automatic snapshot publications (0 = only
    /// publish on explicit `snapshot` requests).
    pub snapshot_every: usize,
    /// Bounded trainer-queue depth in learns (backpressure window: a full
    /// queue blocks the sending connection's `learn` ack).
    pub queue_capacity: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { snapshot_every: 512, queue_capacity: 1024 }
    }
}

/// What connection handlers send the trainer. FIFO per connection, which
/// is what makes `snapshot` reflect previously acked learns.
enum TrainerMsg {
    Learn(Vec<f64>, f64),
    /// Publish + reply with the checkpoint document (or the failure
    /// message). The document travels as parsed [`Json`] so the handler
    /// embeds it without re-parsing the (potentially multi-MB) text.
    Snapshot(mpsc::Sender<Result<Json, String>>),
    Shutdown,
}

/// Monotonic counters shared across all threads (lock-free reads for the
/// `stats` command).
#[derive(Default)]
struct ServerStats {
    learns_enqueued: AtomicU64,
    learns_applied: AtomicU64,
    predicts: AtomicU64,
    snapshots: AtomicU64,
    snapshot_failures: AtomicU64,
    connections: AtomicU64,
}

/// Immutable facts captured before the model moves into the trainer.
struct ModelInfo {
    name: String,
    kind: &'static str,
    n_features: usize,
    snapshot_every: usize,
    started: Instant,
}

/// Read the current snapshot `Arc` (surviving lock poisoning: the guarded
/// value is just a pointer, always valid).
fn current_snapshot(lock: &RwLock<Arc<Model>>) -> Arc<Model> {
    match lock.read() {
        Ok(guard) => guard.clone(),
        Err(poisoned) => poisoned.into_inner().clone(),
    }
}

/// Encode the live model, publish the decoded clone as the new read
/// snapshot, and return the checkpoint document.
fn publish_snapshot(
    model: &Model,
    snapshot: &RwLock<Arc<Model>>,
    stats: &ServerStats,
) -> Result<Json, String> {
    let doc = model.to_checkpoint().map_err(|e| e.to_string())?;
    let clone = Model::from_checkpoint(&doc).map_err(|e| e.to_string())?;
    let shared = Arc::new(clone);
    match snapshot.write() {
        Ok(mut guard) => *guard = shared,
        Err(poisoned) => {
            let mut guard = poisoned.into_inner();
            *guard = shared;
        }
    }
    stats.snapshots.fetch_add(1, Ordering::Relaxed);
    Ok(doc)
}

/// A running serve instance. Dropping the handle does NOT stop the
/// server; send a `shutdown` request (e.g. [`super::ServeClient::shutdown`])
/// and then [`Server::join`] it.
pub struct Server {
    addr: SocketAddr,
    acceptor: thread::JoinHandle<()>,
    trainer: thread::JoinHandle<Model>,
}

impl Server {
    /// Bind `bind_addr` (use port 0 for an ephemeral port) and start the
    /// trainer, acceptor and snapshot machinery. The initial snapshot is
    /// published before the listener accepts, so the very first `predict`
    /// already has a model to read — this also means `start` fails
    /// cleanly when the model is not checkpointable.
    pub fn start(model: Model, bind_addr: &str, options: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("binding {bind_addr}"))?;
        let addr = listener.local_addr().context("reading bound address")?;

        let stats = Arc::new(ServerStats::default());
        let info = Arc::new(ModelInfo {
            name: model.name(),
            kind: model.kind(),
            n_features: model.n_features(),
            snapshot_every: options.snapshot_every,
            started: Instant::now(),
        });
        let initial = model.clone_via_codec().map_err(|e| {
            e.context("publishing the initial snapshot (model not checkpointable?)")
        })?;
        let snapshot = Arc::new(RwLock::new(Arc::new(initial)));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<TrainerMsg>(options.queue_capacity.max(1));

        let trainer = {
            let snapshot = snapshot.clone();
            let stats = stats.clone();
            let snapshot_every = options.snapshot_every;
            thread::spawn(move || {
                let mut model = model;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        TrainerMsg::Learn(x, y) => {
                            model.learn_one(&x, y);
                            let applied =
                                stats.learns_applied.fetch_add(1, Ordering::Relaxed) + 1;
                            if snapshot_every > 0
                                && applied % snapshot_every as u64 == 0
                                && publish_snapshot(&model, &snapshot, &stats).is_err()
                            {
                                stats.snapshot_failures.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        TrainerMsg::Snapshot(reply) => {
                            let out = publish_snapshot(&model, &snapshot, &stats);
                            if out.is_err() {
                                stats.snapshot_failures.fetch_add(1, Ordering::Relaxed);
                            }
                            // a dropped reply just means the client left
                            reply.send(out).ok();
                        }
                        TrainerMsg::Shutdown => break,
                    }
                }
                model
            })
        };

        let acceptor = {
            let shutdown = shutdown.clone();
            thread::spawn(move || {
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let tx = tx.clone();
                    let snapshot = snapshot.clone();
                    let stats = stats.clone();
                    let info = info.clone();
                    let shutdown = shutdown.clone();
                    stats.connections.fetch_add(1, Ordering::Relaxed);
                    thread::spawn(move || {
                        handle_connection(stream, tx, snapshot, stats, info, shutdown, addr);
                    });
                }
            })
        };

        Ok(Server { addr, acceptor, trainer })
    }

    /// The bound address (read the ephemeral port from here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until a `shutdown` request stops the server; returns the
    /// final trained model (callers can [`Model::save`] it).
    pub fn join(self) -> Result<Model> {
        self.acceptor
            .join()
            .map_err(|_| anyhow!("acceptor thread panicked"))?;
        self.trainer
            .join()
            .map_err(|_| anyhow!("trainer thread panicked"))
    }
}

fn handle_connection(
    stream: TcpStream,
    tx: mpsc::SyncSender<TrainerMsg>,
    snapshot: Arc<RwLock<Arc<Model>>>,
    stats: Arc<ServerStats>,
    info: Arc<ModelInfo>,
    shutdown: Arc<AtomicBool>,
    self_addr: SocketAddr,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        let mut line = String::new();
        let n = match (&mut reader).take(MAX_REQUEST_BYTES).read_line(&mut line) {
            Ok(n) => n,
            Err(_) => break, // includes non-UTF-8 input
        };
        if n == 0 {
            break; // client closed the connection
        }
        if !line.ends_with('\n') && n as u64 >= MAX_REQUEST_BYTES {
            let _ = write_response(&mut writer, &error_response("request too large"));
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, stop) = respond(trimmed, &tx, &snapshot, &stats, &info);
        if write_response(&mut writer, &response).is_err() {
            break;
        }
        if stop {
            // order matters: flag first, then wake the trainer, then poke
            // the acceptor loose from accept()
            shutdown.store(true, Ordering::SeqCst);
            tx.send(TrainerMsg::Shutdown).ok();
            TcpStream::connect(self_addr).ok();
            break;
        }
    }
}

fn write_response(writer: &mut BufWriter<TcpStream>, response: &Json) -> std::io::Result<()> {
    writer.write_all(response.to_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn error_response(message: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", false).set("error", message);
    o
}

fn ok_response() -> Json {
    let mut o = Json::obj();
    o.set("ok", true);
    o
}

/// Extract and validate one feature vector.
fn parse_x(j: Option<&Json>, n_features: usize) -> Result<Vec<f64>, String> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| "\"x\" must be an array of numbers".to_string())?;
    if arr.len() != n_features {
        return Err(format!("expected {n_features} features, got {}", arr.len()));
    }
    let mut x = Vec::with_capacity(arr.len());
    for v in arr {
        let v = v.as_f64().ok_or_else(|| "\"x\" must contain numbers".to_string())?;
        if !v.is_finite() {
            return Err("\"x\" must be finite".to_string());
        }
        x.push(v);
    }
    Ok(x)
}

/// Dispatch one request line; returns the response and whether the server
/// should stop.
fn respond(
    line: &str,
    tx: &mpsc::SyncSender<TrainerMsg>,
    snapshot: &RwLock<Arc<Model>>,
    stats: &ServerStats,
    info: &ModelInfo,
) -> (Json, bool) {
    let request = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (error_response(&e), false),
    };
    let Some(cmd) = request.get("cmd").and_then(Json::as_str) else {
        return (error_response("missing \"cmd\""), false);
    };
    match cmd {
        "learn" => {
            let x = match parse_x(request.get("x"), info.n_features) {
                Ok(x) => x,
                Err(e) => return (error_response(&e), false),
            };
            let Some(y) = request.get("y").and_then(Json::as_f64) else {
                return (error_response("\"y\" must be a number"), false);
            };
            if !y.is_finite() {
                return (error_response("\"y\" must be finite"), false);
            }
            // blocking send = backpressure: the ack waits for queue space
            if tx.send(TrainerMsg::Learn(x, y)).is_err() {
                return (error_response("trainer is shut down"), false);
            }
            stats.learns_enqueued.fetch_add(1, Ordering::Relaxed);
            (ok_response(), false)
        }
        "predict" => {
            let x = match parse_x(request.get("x"), info.n_features) {
                Ok(x) => x,
                Err(e) => return (error_response(&e), false),
            };
            let model = current_snapshot(snapshot);
            stats.predicts.fetch_add(1, Ordering::Relaxed);
            let mut o = ok_response();
            o.set("prediction", model.predict(&x));
            (o, false)
        }
        "predict_batch" => {
            let Some(xs) = request.get("xs").and_then(Json::as_arr) else {
                return (error_response("\"xs\" must be an array of arrays"), false);
            };
            let mut batch = Vec::with_capacity(xs.len());
            for item in xs {
                match parse_x(Some(item), info.n_features) {
                    Ok(x) => batch.push(x),
                    Err(e) => return (error_response(&e), false),
                }
            }
            // one snapshot for the whole batch: a consistent view even if
            // the trainer swaps mid-request
            let model = current_snapshot(snapshot);
            stats.predicts.fetch_add(batch.len() as u64, Ordering::Relaxed);
            let predictions: Vec<f64> = batch.iter().map(|x| model.predict(x)).collect();
            let mut o = ok_response();
            o.set("predictions", predictions);
            (o, false)
        }
        "snapshot" => {
            let (reply_tx, reply_rx) = mpsc::channel();
            if tx.send(TrainerMsg::Snapshot(reply_tx)).is_err() {
                return (error_response("trainer is shut down"), false);
            }
            match reply_rx.recv() {
                Ok(Ok(checkpoint)) => {
                    let mut o = ok_response();
                    o.set("checkpoint", checkpoint);
                    (o, false)
                }
                Ok(Err(e)) => (error_response(&e), false),
                Err(_) => (error_response("trainer is shut down"), false),
            }
        }
        "stats" => {
            let mut o = ok_response();
            o.set("model", info.name.as_str())
                .set("kind", info.kind)
                .set("n_features", info.n_features)
                .set("snapshot_every", info.snapshot_every)
                .set("learns_enqueued", stats.learns_enqueued.load(Ordering::Relaxed))
                .set("learns_applied", stats.learns_applied.load(Ordering::Relaxed))
                .set("predicts", stats.predicts.load(Ordering::Relaxed))
                .set("snapshots", stats.snapshots.load(Ordering::Relaxed))
                .set(
                    "snapshot_failures",
                    stats.snapshot_failures.load(Ordering::Relaxed),
                )
                .set("connections", stats.connections.load(Ordering::Relaxed))
                .set("uptime_ms", info.started.elapsed().as_millis() as u64);
            (o, false)
        }
        "shutdown" => (ok_response(), true),
        other => (error_response(&format!("unknown cmd {other:?}")), false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_x_validates_shape_and_values() {
        let good = Json::parse("[1.0, 2.0]").unwrap();
        assert_eq!(parse_x(Some(&good), 2).unwrap(), vec![1.0, 2.0]);
        assert!(parse_x(Some(&good), 3).is_err());
        assert!(parse_x(None, 2).is_err());
        let bad = Json::parse("[1.0, \"x\"]").unwrap();
        assert!(parse_x(Some(&bad), 2).is_err());
        let non_finite = Json::parse("[1.0, null]").unwrap();
        assert!(parse_x(Some(&non_finite), 2).is_err());
    }

    #[test]
    fn responses_have_the_ok_envelope() {
        assert_eq!(ok_response().to_compact(), "{\"ok\":true}");
        let e = error_response("boom");
        assert_eq!(e.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(e.get("error").and_then(Json::as_str), Some("boom"));
    }
}
